"""Hierarchical symbolic tensors and meta-operations (paper §3.1).

A :class:`Tensor` is *symbolic*: its shape and strides are expression trees
(:mod:`.symbols`), not numbers, so all six meta-operations of paper Table 1
(``tile``, ``expand``, ``squeeze``, ``permute``, ``flatten``, ``ravel`` —
plus ``unsqueeze``, an extension needed by broadcast-style arrangements such
as rope) are *compile-time* manipulations: no data moves.

Internally an arranged tensor is represented as

* ``levels`` — the hierarchy: a list of levels, each level a list of
  :class:`Dim` (size expression + a unique index variable).  Level 0 is the
  outermost level; the innermost level is the tile the application function
  manipulates.  ``Tensor.dtype`` returns a *view* one level down, so the
  paper's ``t.dtype = t.dtype.squeeze(0)`` idiom works unchanged.
* ``indices`` — one expression per **source dimension**, written in terms
  of the dims' index variables.  This is the source-to-target mapping of
  paper §3.2.2 in closed form: binding the level-0 variables to program ids
  (tile-to-program mapping), intermediate-level variables to loop indices,
  and innermost variables to intra-tile offsets yields, for every element
  of a tile, its coordinate in the source tensor.

Every meta-operation is a pure function from this representation to a new
one, implemented as substitution over the ``indices`` expressions:

=========  ==================================================================
tile       ``v -> outer * stride + inner`` per dim (conv-style ``strides=``
           supported; default stride equals the tile size — paper §3.1.3)
expand     broadcast: fresh variable that no index expression references
squeeze    ``v -> 0`` and the dim disappears
permute    reorders dims (index expressions untouched)
flatten    merged variables become a mixed-radix decomposition of one fresh
           variable — this is what makes implicit-GEMM conv2d expressible
ravel      concatenates all levels into one (hierarchy only; indices kept)
=========  ==================================================================
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

from .symbols import Expr, Exprish, Symbol, fresh_var


@dataclasses.dataclass(frozen=True)
class Dim:
    """One dimension of one level: a size expression and its index variable."""

    size: Expr
    var: str

    def with_size(self, size: Exprish) -> "Dim":
        return Dim(Expr.wrap(size), self.var)


def _fresh_dim(size: Exprish, prefix: str = "i") -> Dim:
    return Dim(Expr.wrap(size), fresh_var(prefix))


class Tensor:
    """A (possibly hierarchical) symbolic tensor.

    ``Tensor(ndim, name=...)`` constructs a flat source tensor whose shape
    and stride attributes are fresh symbols (paper Listing 2).  Meta-
    operations return new tensors sharing the same source.

    Parameters
    ----------
    ndim:
        number of source dimensions (0 allowed: a scalar parameter).
    name:
        parameter name; defaults to ``tensor_<n>``.
    dtype:
        element dtype *name* ("float32", ...); informational.
    other:
        padding value used by the generated launch function when a source
        dimension must be padded to a tile multiple (the pad-and-crop
        equivalent of Triton's ``other=`` on masked loads).
    shape_options:
        accepted for API parity with the paper's Listing 8 (``constexpr``
        shapes); recorded but not required by this backend.
    """

    _COUNTER = [0]

    def __init__(
        self,
        ndim: Optional[int] = None,
        name: Optional[str] = None,
        dtype: str = "float32",
        other: float = 0.0,
        shape_options: Optional[dict] = None,
        *,
        _internal: Optional[dict] = None,
    ):
        if _internal is not None:
            self.__dict__.update(_internal)
            return
        if ndim is None:
            raise TypeError("Tensor() requires ndim")
        Tensor._COUNTER[0] += 1
        self.name = name or f"tensor_{Tensor._COUNTER[0]}"
        self.source_ndim = ndim
        self.element_dtype = dtype
        self.other = other
        self.shape_options = dict(shape_options or {})
        self.source_shape = tuple(
            Symbol(f"{self.name}_size_{d}", constexpr=bool(self.shape_options.get("constexpr")))
            for d in range(ndim)
        )
        # Stride symbols exist for API parity (paper Listing 2); codegen
        # derives physical strides from the padded contiguous layout instead.
        self.source_strides = tuple(Symbol(f"{self.name}_stride_{d}") for d in range(ndim))
        dims = [_fresh_dim(self.source_shape[d], f"{self.name}{d}") for d in range(ndim)]
        self.levels: list[list[Dim]] = [dims]
        self.indices: list[Expr] = [Expr(ast_name(d.var)) for d in dims]
        # expressions that must evaluate to 1 at specialization time
        # (squeeze/expand of symbolically-sized dims — e.g. cdiv(C_in, C_filt)
        # in the implicit-GEMM conv arrangement, paper Listing 8)
        self.checks: list[Expr] = []
        self._level_offset = 0

    # -- construction of derived tensors --------------------------------------

    def _derive(self, levels, indices, level_offset=None, extra_checks=None) -> "Tensor":
        new = Tensor(
            _internal=dict(
                name=self.name,
                source_ndim=self.source_ndim,
                element_dtype=self.element_dtype,
                other=self.other,
                shape_options=self.shape_options,
                source_shape=self.source_shape,
                source_strides=self.source_strides,
                levels=[list(level) for level in levels],
                indices=list(indices),
                checks=list(self.checks) + list(extra_checks or []),
                _level_offset=self._level_offset if level_offset is None else level_offset,
            )
        )
        return new

    # -- inspection ------------------------------------------------------------

    @property
    def ndim(self) -> int:
        return len(self.levels[self._level_offset])

    @property
    def num_levels(self) -> int:
        return len(self.levels)

    @property
    def shape(self) -> tuple[Expr, ...]:
        """Shape of the *current* level (paper: ``arranged.shape[...]``)."""
        return tuple(d.size for d in self.levels[self._level_offset])

    @property
    def strides(self) -> tuple[Expr, ...]:
        if self._level_offset == 0 and len(self.levels) == 1:
            return tuple(self.source_strides)
        raise AttributeError("strides are only defined on flat source tensors")

    @property
    def dtype(self):
        """One level down (a view), or the element dtype at the innermost level."""
        if self._level_offset + 1 < len(self.levels):
            return self._derive(self.levels, self.indices, self._level_offset + 1)
        return self.element_dtype

    @dtype.setter
    def dtype(self, value):
        """Accept the paper idiom ``t.dtype = t.dtype.squeeze(0)``."""
        if isinstance(value, Tensor):
            if value.name != self.name:
                raise ValueError("dtype assignment must derive from the same tensor")
            self.levels = [list(level) for level in value.levels]
            self.indices = list(value.indices)
            self.checks = list(value.checks)
        else:
            self.element_dtype = value

    def __repr__(self):
        lv = " | ".join(
            "(" + ", ".join(str(d.size) for d in level) + ")" for level in self.levels
        )
        return f"Tensor<{self.name}: {lv}; level={self._level_offset}>"

    # -- internal helpers -------------------------------------------------------

    def _current(self) -> list[Dim]:
        return self.levels[self._level_offset]

    def _substitute(self, mapping: dict[str, Exprish]) -> list[Expr]:
        return [expr.substitute(mapping) for expr in self.indices]

    def _norm_dim(self, dim: int, n: Optional[int] = None) -> int:
        n = self.ndim if n is None else n
        if dim < 0:
            dim += n
        if not 0 <= dim < n:
            raise IndexError(f"dim {dim} out of range for {n}-d level")
        return dim

    # -- meta-operations (paper Table 1) ----------------------------------------

    def tile(
        self,
        tile_shape: Sequence[Exprish],
        strides: Optional[Sequence[Exprish]] = None,
        dilation: Optional[Sequence[Exprish]] = None,
    ) -> "Tensor":
        """Form a hierarchical tensor (paper §3.1.3).

        ``tile_shape[d] == -1`` means "the whole dimension".  ``strides``
        controls the interval at which tiles are generated — analogous to
        the stride of a convolution; ``-1`` (the default) means "equal to
        the tile size", the non-overlapping case the paper identifies as
        the common one.  ``dilation`` spaces the elements *within* a tile.
        """
        current = self._current()
        if len(tile_shape) != len(current):
            raise ValueError(
                f"tile shape has {len(tile_shape)} dims, level has {len(current)}"
            )
        strides = list(strides) if strides is not None else [-1] * len(current)
        dilation = list(dilation) if dilation is not None else [1] * len(current)
        if len(strides) != len(current) or len(dilation) != len(current):
            raise ValueError("strides/dilation must match the level rank")

        outer: list[Dim] = []
        inner: list[Dim] = []
        mapping: dict[str, Exprish] = {}
        for dim, t, s, dl in zip(current, tile_shape, strides, dilation):
            t = dim.size if _is_neg_one(t) else Expr.wrap(t)
            s = t if _is_neg_one(s) else Expr.wrap(s)
            dl = Expr.wrap(dl)
            # span of one tile: (t - 1) * dilation + 1
            span = (t - 1) * dl + 1
            # number of tiles: floor((S - span) / s) + 1, which collapses to
            # ceil(S / t) in the default non-overlapping case (Algorithm 1)
            # under pad-and-crop.
            if s == t and dl == Expr.wrap(1):
                outer_size = dim.size.cdiv(t)
            else:
                outer_size = (dim.size - span) // s + 1
            o = _fresh_dim(outer_size, "o")
            i = _fresh_dim(t, "t")
            mapping[dim.var] = (
                Expr(ast_name(o.var)) * s + Expr(ast_name(i.var)) * dl
            )
            outer.append(o)
            inner.append(i)

        off = self._level_offset
        levels = self.levels[:off] + [outer, inner] + self.levels[off + 1 :]
        return self._derive(levels, self._substitute(mapping))

    def expand(self, shape: Sequence[Exprish]) -> "Tensor":
        """Expand singleton dimensions (broadcast); ``-1`` keeps a dim."""
        current = self._current()
        if len(shape) != len(current):
            raise ValueError("expand shape must match the level rank")
        mapping: dict[str, Exprish] = {}
        dims: list[Dim] = []
        deferred: list[Expr] = []
        for dim, new_size in zip(current, shape):
            if _is_neg_one(new_size):
                dims.append(dim)
                continue
            if dim.size.is_constant:
                if dim.size.constant() != 1:
                    raise ValueError(
                        f"cannot expand non-singleton dim of size {dim.size}"
                    )
            else:
                deferred.append(dim.size)
            mapping[dim.var] = 0  # broadcast: the fresh var never feeds indices
            dims.append(_fresh_dim(new_size, "e"))
        levels = list(self.levels)
        levels[self._level_offset] = dims
        return self._derive(levels, self._substitute(mapping), extra_checks=deferred)

    def squeeze(self, dim: Union[int, Sequence[int]]) -> "Tensor":
        """Remove singleton dimensions."""
        dims_to_drop = sorted(
            {self._norm_dim(d) for d in (dim if isinstance(dim, (tuple, list)) else (dim,))}
        )
        current = self._current()
        mapping: dict[str, Exprish] = {}
        kept: list[Dim] = []
        deferred: list[Expr] = []
        for idx, d in enumerate(current):
            if idx in dims_to_drop:
                if d.size.is_constant:
                    if d.size.constant() != 1:
                        raise ValueError(f"cannot squeeze dim {idx} of size {d.size}")
                else:
                    # symbolically unknown: must evaluate to 1 at launch
                    # (e.g. cdiv(C_in, C_filter) in implicit-GEMM conv)
                    deferred.append(d.size)
                mapping[d.var] = 0
            else:
                kept.append(d)
        levels = list(self.levels)
        levels[self._level_offset] = kept
        return self._derive(levels, self._substitute(mapping), extra_checks=deferred)

    def unsqueeze(self, dim: int) -> "Tensor":
        """Insert a singleton dimension (extension; needed by e.g. rope)."""
        current = self._current()
        dim = dim + len(current) + 1 if dim < 0 else dim
        if not 0 <= dim <= len(current):
            raise IndexError(f"unsqueeze dim {dim} out of range")
        dims = list(current)
        dims.insert(dim, _fresh_dim(1, "u"))
        levels = list(self.levels)
        levels[self._level_offset] = dims
        return self._derive(levels, self.indices)

    def permute(self, order: Sequence[int]) -> "Tensor":
        """Permute the dimensions of the current level."""
        current = self._current()
        norm = [self._norm_dim(d) for d in order]
        if sorted(norm) != list(range(len(current))):
            raise ValueError(f"invalid permutation {order}")
        levels = list(self.levels)
        levels[self._level_offset] = [current[d] for d in norm]
        return self._derive(levels, self.indices)

    def flatten(self, start_dim: int = 0, end_dim: Optional[int] = None) -> "Tensor":
        """Merge dims ``[start_dim, end_dim)`` of the current level into one.

        The merged index variables are replaced by the mixed-radix
        decomposition of a single fresh variable, so arbitrary (even
        non-contiguous) source layouts remain addressable — this is the
        step that lets implicit-GEMM conv2d present an (N·P·Q, C·R·S) view.
        """
        current = self._current()
        n = len(current)
        start = self._norm_dim(start_dim)
        end = n if end_dim is None else (end_dim + n if end_dim < 0 else end_dim)
        if not start < end <= n:
            raise ValueError(f"invalid flatten range [{start}, {end})")
        merged = current[start:end]
        total = merged[0].size
        for d in merged[1:]:
            total = total * d.size
        flat = _fresh_dim(total, "f")
        w = Expr(ast_name(flat.var))
        mapping: dict[str, Exprish] = {}
        trailing = Expr.wrap(1)
        for d in reversed(merged):
            component = (w // trailing) % d.size if trailing != Expr.wrap(1) else w % d.size
            mapping[d.var] = component
            trailing = trailing * d.size
        # outermost component needs no modulo: it is bounded by construction
        first = merged[0]
        rest = trailing // first.size
        mapping[first.var] = w // rest if rest != Expr.wrap(1) else w
        dims = current[:start] + [flat] + current[end:]
        levels = list(self.levels)
        levels[self._level_offset] = dims
        return self._derive(levels, self._substitute(mapping))

    def ravel(self) -> "Tensor":
        """Flatten *all levels* (from the current one down) into one level
        (paper §3.1.3: unlike ``flatten``, ``ravel`` collapses hierarchy)."""
        off = self._level_offset
        merged: list[Dim] = []
        for level in self.levels[off:]:
            merged.extend(level)
        levels = self.levels[:off] + [merged]
        return self._derive(levels, self.indices)

    # -- validation helpers used by the code generator ---------------------------

    def names_of_level(self, level: int) -> list[str]:
        return [d.var for d in self.levels[level]]

    def innermost(self) -> list[Dim]:
        return self.levels[-1]


def ast_name(name: str):
    import ast as _ast

    return _ast.Name(id=name, ctx=_ast.Load())


def _is_neg_one(value: Exprish) -> bool:
    if isinstance(value, int):
        return value == -1
    if isinstance(value, Expr) and value.is_constant:
        return value.constant() == -1
    return False
