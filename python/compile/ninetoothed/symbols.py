"""AST-backed symbolic expressions — the substrate of tensor-oriented
metaprogramming (paper §3.1.2).

The paper observes that the symbolic expression trees involved in common
tensor meta-operations are a subset of the abstract syntax trees of
high-level languages, and therefore wraps Python's ``ast`` nodes directly
instead of inventing a fresh CAS.  We do the same: every :class:`Expr`
holds an ``ast.expr`` node; arithmetic on :class:`Expr` objects builds
bigger AST nodes; evaluation compiles the tree once and executes it under
a binding environment (which may contain JAX tracers — the same expression
tree that sizes the grid at launch time computes offsets inside the
generated Pallas kernel).

Three operations beyond plain arithmetic matter for code generation:

* :meth:`Expr.substitute` — capture-free replacement of names, used by the
  meta-operations (``tile`` replaces a dim's index variable with
  ``outer * stride + inner``; ``flatten`` replaces merged variables with a
  mixed-radix decomposition of a fresh variable).
* :meth:`Expr.bounds` — interval arithmetic over the tree, used by the
  generated launch function to derive padding extents (the pad-and-crop
  equivalent of Triton's masks, see DESIGN.md §2).
* ``str(expr)`` — a parseable rendering consumed by the Rust mirror of the
  algebra (``rust/src/symbolic``).
"""

from __future__ import annotations

import ast
from typing import Iterable, Mapping, Union

Exprish = Union["Expr", int]

_COMPILE_CACHE: dict[str, object] = {}


def _cdiv(a, b):
    """Ceiling division helper available inside evaluated expressions."""
    return -(-a // b)


_EVAL_FUNCS = {"cdiv": _cdiv, "min": min, "max": max}


def _to_node(value: Exprish) -> ast.expr:
    if isinstance(value, Expr):
        return value.node
    if isinstance(value, bool):  # bool is an int subclass; reject explicitly
        raise TypeError("boolean is not a valid symbolic value")
    if isinstance(value, int):
        if value < 0:
            return ast.UnaryOp(op=ast.USub(), operand=ast.Constant(value=-value))
        return ast.Constant(value=value)
    raise TypeError(f"cannot convert {value!r} to a symbolic expression")


def _const_of(node: ast.expr):
    """Return the integer value of a constant node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    if (
        isinstance(node, ast.UnaryOp)
        and isinstance(node.op, ast.USub)
        and isinstance(node.operand, ast.Constant)
        and isinstance(node.operand.value, int)
    ):
        return -node.operand.value
    return None


class Expr:
    """A symbolic integer expression wrapping a Python ``ast`` node."""

    __slots__ = ("node",)

    def __init__(self, node: Union[ast.expr, Exprish]):
        if isinstance(node, ast.expr):
            self.node = node
        else:
            self.node = _to_node(node)

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def wrap(value: Exprish) -> "Expr":
        return value if isinstance(value, Expr) else Expr(_to_node(value))

    def _bin(self, other: Exprish, op: ast.operator, swap: bool = False) -> "Expr":
        lhs, rhs = (_to_node(other), self.node) if swap else (self.node, _to_node(other))
        folded = _fold(lhs, op, rhs)
        return Expr(folded)

    def __add__(self, other):
        return self._bin(other, ast.Add())

    def __radd__(self, other):
        return self._bin(other, ast.Add(), swap=True)

    def __sub__(self, other):
        return self._bin(other, ast.Sub())

    def __rsub__(self, other):
        return self._bin(other, ast.Sub(), swap=True)

    def __mul__(self, other):
        return self._bin(other, ast.Mult())

    def __rmul__(self, other):
        return self._bin(other, ast.Mult(), swap=True)

    def __floordiv__(self, other):
        return self._bin(other, ast.FloorDiv())

    def __rfloordiv__(self, other):
        return self._bin(other, ast.FloorDiv(), swap=True)

    def __mod__(self, other):
        return self._bin(other, ast.Mod())

    def __rmod__(self, other):
        return self._bin(other, ast.Mod(), swap=True)

    def __neg__(self):
        return Expr(0) - self

    def cdiv(self, other: Exprish) -> "Expr":
        """Ceiling division — the tiling size rule of paper Algorithm 1."""
        a, b = _const_of(self.node), _const_of(_to_node(other))
        if a is not None and b is not None and b != 0:
            return Expr(_cdiv(a, b))
        # structural identity: cdiv(x, x) == 1 for positive x (all sizes are
        # positive); keeps full-dim tiles (`tile((1, -1))`) singleton so the
        # paper's expand-after-tile idiom type-checks symbolically.
        if ast.unparse(self.node) == ast.unparse(_to_node(other)):
            return Expr(1)
        call = ast.Call(
            func=ast.Name(id="cdiv", ctx=ast.Load()),
            args=[self.node, _to_node(other)],
            keywords=[],
        )
        return Expr(call)

    # -- interrogation -------------------------------------------------------

    @property
    def is_constant(self) -> bool:
        return _const_of(self.node) is not None

    def constant(self) -> int:
        value = _const_of(self.node)
        if value is None:
            raise ValueError(f"{self} is not constant")
        return value

    def free_symbols(self) -> set[str]:
        return {
            n.id
            for n in ast.walk(self.node)
            if isinstance(n, ast.Name) and n.id not in _EVAL_FUNCS
        }

    # -- transformation ------------------------------------------------------

    def substitute(self, mapping: Mapping[str, Exprish]) -> "Expr":
        if not mapping:
            return self
        nodes = {name: _to_node(value) for name, value in mapping.items()}

        class _Sub(ast.NodeTransformer):
            def visit_Name(self, node: ast.Name):
                repl = nodes.get(node.id)
                return ast.copy_location(_copy_node(repl), node) if repl is not None else node

        new = _Sub().visit(_copy_node(self.node))
        return Expr(_refold(new))

    def evaluate(self, env: Mapping[str, object]):
        """Evaluate under ``env``; values may be ints or JAX tracers."""
        src = str(self)
        code = _COMPILE_CACHE.get(src)
        if code is None:
            code = compile(ast.Expression(body=_with_locations(self.node)), "<expr>", "eval")
            _COMPILE_CACHE[src] = code
        scope = dict(_EVAL_FUNCS)
        scope.update(env)
        return eval(code, {"__builtins__": {}}, scope)  # noqa: S307 — our own AST

    def bounds(self, ranges: Mapping[str, tuple[int, int]]) -> tuple[int, int]:
        """Interval [lo, hi] of the expression given variable ranges.

        Conservative (never narrower than the true range).  Used to compute
        the padded extent each source dimension must provide so every
        generated load is in bounds — the pad-and-crop substitute for
        Triton's masked loads.
        """
        return _bounds(self.node, ranges)

    # -- misc ----------------------------------------------------------------

    def __str__(self) -> str:
        return ast.unparse(self.node)

    def __repr__(self) -> str:
        return f"Expr({self})"

    def __eq__(self, other):
        if isinstance(other, (Expr, int)):
            return str(self) == str(Expr.wrap(other))
        return NotImplemented

    def __hash__(self):
        return hash(str(self))

    def __int__(self):
        return self.constant()

    def __index__(self):
        return self.constant()


class Symbol(Expr):
    """A named symbol (paper Listing 2 / §4.1).

    ``constexpr=True`` marks meta-parameters whose value must be known at
    kernel-specialization time (block sizes).  ``default`` lets the launch
    function pick a value when the caller does not supply one.
    """

    __slots__ = ("name", "constexpr", "default")

    def __init__(self, name: str, constexpr: bool = False, default: int | None = None):
        if not name.isidentifier():
            raise ValueError(f"invalid symbol name: {name!r}")
        super().__init__(ast.Name(id=name, ctx=ast.Load()))
        self.name = name
        self.constexpr = constexpr
        self.default = default

    def __repr__(self):
        return f"Symbol({self.name!r})"


_BLOCK_COUNTER = [0]


def block_size(default: int | None = None) -> Symbol:
    """A fresh constexpr block-size meta-parameter (paper Listing 5)."""
    _BLOCK_COUNTER[0] += 1
    return Symbol(f"_ntc_block_{_BLOCK_COUNTER[0]}", constexpr=True, default=default)


# -- internals ----------------------------------------------------------------


def _copy_node(node: ast.expr) -> ast.expr:
    # ast nodes are mutable; deep-copy through parse/unparse-free path.
    import copy

    return copy.deepcopy(node)


def _with_locations(node: ast.expr) -> ast.expr:
    node = _copy_node(node)
    for n in ast.walk(node):
        n.lineno = getattr(n, "lineno", 1) or 1
        n.col_offset = getattr(n, "col_offset", 0) or 0
        n.end_lineno = getattr(n, "end_lineno", 1) or 1
        n.end_col_offset = getattr(n, "end_col_offset", 0) or 0
    return node


def _fold(lhs: ast.expr, op: ast.operator, rhs: ast.expr) -> ast.expr:
    """Constant folding + identity elimination at construction time.

    Keeps expression trees small after the heavy substitutions performed by
    ``tile``/``flatten`` (e.g. ``v -> 0`` from ``squeeze`` collapses whole
    products).
    """
    a, b = _const_of(lhs), _const_of(rhs)
    if a is not None and b is not None:
        if isinstance(op, ast.Add):
            return _to_node(a + b)
        if isinstance(op, ast.Sub):
            return _to_node(a - b)
        if isinstance(op, ast.Mult):
            return _to_node(a * b)
        if isinstance(op, ast.FloorDiv) and b != 0:
            return _to_node(a // b)
        if isinstance(op, ast.Mod) and b != 0:
            return _to_node(a % b)
    if isinstance(op, ast.Add):
        if a == 0:
            return rhs
        if b == 0:
            return lhs
    if isinstance(op, ast.Sub) and b == 0:
        return lhs
    if isinstance(op, ast.Mult):
        if a == 0 or b == 0:
            return ast.Constant(value=0)
        if a == 1:
            return rhs
        if b == 1:
            return lhs
    if isinstance(op, ast.FloorDiv) and b == 1:
        return lhs
    if isinstance(op, ast.Mod) and b == 1:
        return ast.Constant(value=0)
    return ast.BinOp(left=lhs, op=op, right=rhs)


def _refold(node: ast.expr) -> ast.expr:
    """Re-run folding bottom-up after a substitution."""
    if isinstance(node, ast.BinOp):
        left = _refold(node.left)
        right = _refold(node.right)
        return _fold(left, node.op, right)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        operand = _refold(node.operand)
        value = _const_of(operand)
        if value is not None:
            return _to_node(-value)
        return ast.UnaryOp(op=ast.USub(), operand=operand)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        args = [_refold(a) for a in node.args]
        consts = [_const_of(a) for a in args]
        if all(c is not None for c in consts):
            if node.func.id == "cdiv" and consts[1] != 0:
                return _to_node(_cdiv(consts[0], consts[1]))
            if node.func.id == "min":
                return _to_node(min(*consts))
            if node.func.id == "max":
                return _to_node(max(*consts))
        return ast.Call(func=node.func, args=args, keywords=[])
    return node


def _bounds(node: ast.expr, ranges: Mapping[str, tuple[int, int]]) -> tuple[int, int]:
    value = _const_of(node)
    if value is not None:
        return (value, value)
    if isinstance(node, ast.Name):
        if node.id not in ranges:
            raise KeyError(f"no range for symbol {node.id!r}")
        return ranges[node.id]
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        lo, hi = _bounds(node.operand, ranges)
        return (-hi, -lo)
    if isinstance(node, ast.BinOp):
        alo, ahi = _bounds(node.left, ranges)
        blo, bhi = _bounds(node.right, ranges)
        if isinstance(node.op, ast.Add):
            return (alo + blo, ahi + bhi)
        if isinstance(node.op, ast.Sub):
            return (alo - bhi, ahi - blo)
        if isinstance(node.op, ast.Mult):
            products = (alo * blo, alo * bhi, ahi * blo, ahi * bhi)
            return (min(products), max(products))
        if isinstance(node.op, ast.FloorDiv):
            if blo <= 0:
                raise ValueError(f"cannot bound division by {blo}..{bhi}")
            candidates = (alo // blo, alo // bhi, ahi // blo, ahi // bhi)
            return (min(candidates), max(candidates))
        if isinstance(node.op, ast.Mod):
            if blo <= 0:
                raise ValueError(f"cannot bound modulo by {blo}..{bhi}")
            if alo >= 0:
                return (0, min(ahi, bhi - 1))
            return (-(bhi - 1), bhi - 1)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        parts = [_bounds(a, ranges) for a in node.args]
        if node.func.id == "cdiv":
            (alo, ahi), (blo, bhi) = parts
            if blo <= 0:
                raise ValueError("cannot bound cdiv by nonpositive divisor")
            candidates = (_cdiv(alo, blo), _cdiv(alo, bhi), _cdiv(ahi, blo), _cdiv(ahi, bhi))
            return (min(candidates), max(candidates))
        if node.func.id == "min":
            return (min(p[0] for p in parts), min(p[1] for p in parts))
        if node.func.id == "max":
            return (max(p[0] for p in parts), max(p[1] for p in parts))
    raise ValueError(f"cannot bound expression node {ast.dump(node)}")


_VAR_COUNTER = [0]


def fresh_var(prefix: str = "i") -> str:
    """A fresh, globally-unique index-variable name."""
    _VAR_COUNTER[0] += 1
    return f"_ntv_{prefix}_{_VAR_COUNTER[0]}"
