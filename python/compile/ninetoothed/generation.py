"""The code generator (paper §3.2): serial → parallel transformation.

``make(arrangement, application, tensors)`` integrates an *arrangement* (a
compile-time function from symbolic tensors to arranged hierarchical
tensors) with an *application* (a serial function over tiles) into a
parallel Pallas kernel plus an auto-generated launch function:

1. **Tile-to-program mapping** (§3.2.1).  The outermost levels of all
   arranged parameters must agree in shape; that shape *is* the Pallas grid
   (the auto-generated equivalent of Triton's ``grid`` lambda), and the
   level-0 index variables are bound to ``pl.program_id(...)``.

2. **Serial-code rewrite.**  The application function's AST is transformed
   — assignments to parameter names become stores (``output = x`` becomes
   ``__nt_store__(output, x)``), the same AST-level rewrite the paper's
   generator performs when emitting Triton.  All other statements are kept
   verbatim: step 4 of the Triton workflow ("perform the computation") is
   inherently serial and needs no abstraction.

3. **Source-to-target mapping** (§3.2.2).  Each parameter carries one index
   expression per source dimension (built by the meta-operations).  Binding
   intermediate-level variables to loop indices and innermost variables to
   intra-tile iotas evaluates, for every element of a tile, its source
   coordinate; the dot product with the (padded, contiguous) strides yields
   the flat offsets used to generate the loads and stores the user never
   writes.

4. **Launch generation** (§3.2.1 end).  The launch function reads shapes
   from the runtime arguments, pads every source dimension to the extent
   the arrangement can touch (interval arithmetic over the index
   expressions — the pad-and-crop equivalent of Triton's masks, see
   DESIGN.md §2), launches the grid, and crops the outputs.
"""

from __future__ import annotations

import ast
import functools
import inspect
import textwrap
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .symbols import Expr, Symbol
from .tensor import Tensor

__all__ = ["make", "Kernel", "TileProxy"]


# ---------------------------------------------------------------------------
# Application AST rewrite
# ---------------------------------------------------------------------------


class _StoreRewriter(ast.NodeTransformer):
    """Rewrite assignments to kernel parameters into store calls."""

    def __init__(self, params: Sequence[str]):
        self.params = set(params)
        self.stored: set[str] = set()

    def _store_call(self, name: str, value: ast.expr) -> ast.stmt:
        self.stored.add(name)
        return ast.Expr(
            value=ast.Call(
                func=ast.Name(id="__nt_store__", ctx=ast.Load()),
                args=[ast.Name(id=name, ctx=ast.Load()), value],
                keywords=[],
            )
        )

    def _store_item_call(self, name: str, index: ast.expr, value: ast.expr) -> ast.stmt:
        self.stored.add(name)
        return ast.Expr(
            value=ast.Call(
                func=ast.Name(id="__nt_store_item__", ctx=ast.Load()),
                args=[ast.Name(id=name, ctx=ast.Load()), index, value],
                keywords=[],
            )
        )

    def visit_Assign(self, node: ast.Assign):
        self.generic_visit(node)
        if len(node.targets) == 1:
            target = node.targets[0]
            if isinstance(target, ast.Name) and target.id in self.params:
                return self._store_call(target.id, node.value)
            if (
                isinstance(target, ast.Subscript)
                and isinstance(target.value, ast.Name)
                and target.value.id in self.params
            ):
                return self._store_item_call(target.value.id, target.slice, node.value)
        return node

    def visit_AugAssign(self, node: ast.AugAssign):
        self.generic_visit(node)
        if isinstance(node.target, ast.Name) and node.target.id in self.params:
            combined = ast.BinOp(
                left=ast.Name(id=node.target.id, ctx=ast.Load()),
                op=node.op,
                right=node.value,
            )
            return self._store_call(node.target.id, combined)
        return node


def _transform_application(application: Callable, param_names: Sequence[str]):
    """Compile the store-rewritten application; returns (code, stored names)."""
    src = textwrap.dedent(inspect.getsource(application))
    tree = ast.parse(src)
    fndef = tree.body[0]
    if not isinstance(fndef, (ast.FunctionDef, ast.AsyncFunctionDef)):
        raise TypeError("application must be a plain function")
    fndef.decorator_list = []
    rewriter = _StoreRewriter(param_names)
    rewriter.visit(fndef)
    fndef.name = "__nt_application__"
    ast.fix_missing_locations(tree)
    code = compile(tree, f"<ninetoothed:{application.__name__}>", "exec")
    if not rewriter.stored:
        raise ValueError(
            f"application {application.__name__!r} never assigns to a parameter; "
            "at least one output store is required"
        )
    return code, rewriter.stored, src


# ---------------------------------------------------------------------------
# Tile proxies: the lazy loads of the generated kernel
# ---------------------------------------------------------------------------


class TileProxy:
    """A view of one parameter inside one program.

    Starts at the level just below the program (tile-to-program) level;
    ``proxy[k]`` drills one level down (the paper's ``[...]`` access for
    >2-level hierarchies); arithmetic at the innermost level materializes a
    jnp value via the generated gather load.
    """

    __slots__ = ("_spec", "_level", "_bindings", "_cache")

    def __init__(self, spec: "_ParamSpec", level: int, bindings: dict):
        self._spec = spec
        self._level = level
        self._bindings = bindings
        self._cache = None

    # -- structure ----------------------------------------------------------

    @property
    def shape(self):
        if self._level >= len(self._spec.level_shapes):
            return ()
        return self._spec.level_shapes[self._level]

    @property
    def dtype(self):
        return self._spec.dtype

    def __getitem__(self, index):
        spec = self._spec
        if self._level >= spec.num_levels - 1:
            # innermost level: slice the materialized tile
            return self._nt_materialize()[index]
        level_vars = spec.level_vars[self._level]
        if not isinstance(index, tuple):
            index = (index,)
        if len(index) != len(level_vars):
            raise IndexError(
                f"level {self._level} of {spec.name} has {len(level_vars)} dims, "
                f"got {len(index)} indices"
            )
        bindings = dict(self._bindings)
        for var, idx in zip(level_vars, index):
            bindings[var] = idx
        return TileProxy(spec, self._level + 1, bindings)

    # -- materialization (the generated load) --------------------------------

    def _offsets(self, bindings: dict):
        return self._spec.offsets(bindings)

    def _nt_materialize(self):
        if self._cache is not None:
            return self._cache
        spec = self._spec
        if self._level != spec.num_levels - 1:
            raise ValueError(
                f"parameter {spec.name!r}: cannot materialize level {self._level} "
                f"of {spec.num_levels}; index into the remaining levels first"
            )
        if spec.fast_plan is not None:
            value = spec.fast_load(dict(self._bindings))
            self._cache = value
            return value
        bindings = dict(self._bindings)
        block_shape = spec.level_shapes[-1]
        for axis, var in enumerate(spec.level_vars[-1]):
            bindings[var] = _iota(block_shape, axis)
        offsets = spec.offsets(bindings)
        offsets = jnp.broadcast_to(offsets, block_shape) if block_shape else offsets
        flat = spec.ref[...].reshape(-1)
        value = flat[offsets.reshape(-1)].reshape(block_shape)
        self._cache = value
        return value

    # -- arithmetic: materialize then defer to jnp ----------------------------

    def _binop(self, other, op, swap=False):
        a = self._nt_materialize()
        b = other._nt_materialize() if isinstance(other, TileProxy) else other
        return op(b, a) if swap else op(a, b)

    def __add__(self, o):
        return self._binop(o, jnp.add)

    def __radd__(self, o):
        return self._binop(o, jnp.add, swap=True)

    def __sub__(self, o):
        return self._binop(o, jnp.subtract)

    def __rsub__(self, o):
        return self._binop(o, jnp.subtract, swap=True)

    def __mul__(self, o):
        return self._binop(o, jnp.multiply)

    def __rmul__(self, o):
        return self._binop(o, jnp.multiply, swap=True)

    def __truediv__(self, o):
        return self._binop(o, jnp.divide)

    def __rtruediv__(self, o):
        return self._binop(o, jnp.divide, swap=True)

    def __neg__(self):
        return -self._nt_materialize()

    def __matmul__(self, o):
        b = o._nt_materialize() if isinstance(o, TileProxy) else o
        return jnp.dot(self._nt_materialize(), b, preferred_element_type=jnp.float32)

    def astype(self, dtype):
        return self._nt_materialize().astype(dtype)


class _ScalarProxy:
    """A 0-d parameter: each program sees the same scalar value."""

    __slots__ = ("ref",)

    def __init__(self, ref):
        self.ref = ref

    def _nt_materialize(self):
        return self.ref[...].reshape(())

    def _binop(self, other, op, swap=False):
        a = self._nt_materialize()
        b = other._nt_materialize() if hasattr(other, "_nt_materialize") else other
        return op(b, a) if swap else op(a, b)

    __add__ = lambda s, o: s._binop(o, jnp.add)  # noqa: E731
    __radd__ = lambda s, o: s._binop(o, jnp.add, swap=True)  # noqa: E731
    __sub__ = lambda s, o: s._binop(o, jnp.subtract)  # noqa: E731
    __rsub__ = lambda s, o: s._binop(o, jnp.subtract, swap=True)  # noqa: E731
    __mul__ = lambda s, o: s._binop(o, jnp.multiply)  # noqa: E731
    __rmul__ = lambda s, o: s._binop(o, jnp.multiply, swap=True)  # noqa: E731
    __truediv__ = lambda s, o: s._binop(o, jnp.divide)  # noqa: E731
    __rtruediv__ = lambda s, o: s._binop(o, jnp.divide, swap=True)  # noqa: E731


def _iota(shape, axis):
    if not shape:
        return jnp.int32(0)
    return jax.lax.broadcasted_iota(jnp.int32, shape, axis)


# ---------------------------------------------------------------------------
# Specialization: symbolic arrangement -> concrete kernel plan
# ---------------------------------------------------------------------------


class _ParamSpec:
    """One parameter of one specialized kernel instance."""

    def __init__(self, name, arranged: Tensor, bindings: dict[str, int], dtype, pad_value):
        self.name = name
        self.dtype = dtype
        self.pad_value = pad_value
        self.is_scalar = arranged.source_ndim == 0
        self.ref = None  # bound during kernel trace

        # deferred singleton checks from squeeze/expand of symbolic dims
        for check in arranged.checks:
            value = int(check.evaluate(bindings))
            if value != 1:
                raise ValueError(
                    f"parameter {name!r}: arrangement requires {check} == 1, "
                    f"got {value} — the arrangement is invalid (paper §3.2.1)"
                )

        # Concrete per-level shapes and index-variable names.
        self.level_shapes = [
            tuple(int(d.size.evaluate(bindings)) for d in level) for level in arranged.levels
        ]
        self.level_vars = [[d.var for d in level] for level in arranged.levels]
        self.num_levels = len(self.level_shapes)

        # Specialize the source-dim index expressions: after substituting all
        # shape/meta symbols, the only free names left are index variables.
        self.index_exprs = [e.substitute(bindings) for e in arranged.indices]

        # Padded extent per source dim via interval arithmetic (DESIGN.md §2).
        ranges = {}
        for shapes, names in zip(self.level_shapes, self.level_vars):
            for size, var in zip(shapes, names):
                ranges[var] = (0, max(size - 1, 0))
        self.orig_shape = tuple(
            int(s.evaluate(bindings)) for s in arranged.source_shape
        )
        extents = []
        for d, expr in enumerate(self.index_exprs):
            if expr.is_constant:
                hi = expr.constant()
            else:
                _, hi = expr.bounds(ranges)
            extents.append(max(hi + 1, self.orig_shape[d]))
        self.padded_shape = tuple(extents)
        strides = []
        acc = 1
        for size in reversed(self.padded_shape):
            strides.append(acc)
            acc *= size
        self.strides = tuple(reversed(strides))

        # Compiled evaluators, one per source dim, taking the binding env.
        self._evaluators = [expr.evaluate for expr in self.index_exprs]

        # Affine fast path (perf pass, EXPERIMENTS.md §Perf): when every
        # source-dim index expression is `start(outer/loop vars) + block_var`
        # with unit coefficient and each block variable used in exactly one
        # dim, the tile is a contiguous rectangle and the load lowers to
        # `lax.dynamic_slice` instead of a flat gather (likewise the store
        # to `lax.dynamic_update_slice`).  Tiled-but-unflattened
        # arrangements (mm, sdpa, rope, rowwise) all hit this; implicit-GEMM
        # conv2d keeps the gather path (mixed-radix index decomposition).
        self.fast_plan = None if self.is_scalar else self._plan_fast_path()

    def _plan_fast_path(self):
        block_vars = list(self.level_vars[-1]) if self.level_shapes else []
        block_sizes = list(self.level_shapes[-1]) if self.level_shapes else []
        if self.num_levels < 2:
            return None
        zero_block = {v: 0 for v in block_vars}
        starts = []  # per source dim: start-expr evaluator
        dim_var = []  # per source dim: block var name or None
        used: set[str] = set()
        for expr in self.index_exprs:
            start = expr.substitute(zero_block)
            free = expr.free_symbols() & set(block_vars)
            if not free:
                starts.append(start.evaluate)
                dim_var.append(None)
                continue
            if len(free) != 1:
                return None
            (var,) = free
            if var in used:
                return None
            # structural check: expr == start + var exactly
            from .symbols import Expr as _Expr
            from .tensor import ast_name as _ast_name

            if str(start + _Expr(_ast_name(var))) != str(expr):
                return None
            used.add(var)
            starts.append(start.evaluate)
            dim_var.append(var)
        # any block var appearing in an index expression has either been
        # consumed (single-var, unit-coefficient) or we bailed above; vars
        # absent from every expression are broadcast dims and need no slice
        # slice sizes per source dim; mapped dims in source order
        var_size = dict(zip(block_vars, block_sizes))
        sizes = [var_size[v] if v is not None else 1 for v in dim_var]
        mapped_dims = [d for d, v in enumerate(dim_var) if v is not None]
        # transpose permutation: block axes (var order) <- sliced axes (dim order)
        perm = []
        for v in block_vars:
            if v in used:
                d = dim_var.index(v)
                perm.append(mapped_dims.index(d))
        return {
            "starts": starts,
            "dim_var": dim_var,
            "sizes": sizes,
            "mapped_dims": mapped_dims,
            "perm": perm,
            "block_vars": block_vars,
        }

    def fast_load(self, bindings: dict):
        """dynamic_slice load for the affine fast path; block-shaped result."""
        plan = self.fast_plan
        starts = [jnp.asarray(f(bindings), jnp.int32) for f in plan["starts"]]
        sliced = jax.lax.dynamic_slice(self.ref[...], starts, plan["sizes"])
        # drop unmapped (size-1) dims, reorder to block-axis order
        squeezed = sliced.reshape([plan["sizes"][d] for d in plan["mapped_dims"]])
        if plan["perm"] != sorted(plan["perm"]):
            squeezed = jnp.transpose(squeezed, plan["perm"])
        return squeezed.reshape(self.level_shapes[-1])

    def fast_store(self, bindings: dict, value):
        """dynamic_update_slice store for the affine fast path."""
        plan = self.fast_plan
        starts = [jnp.asarray(f(bindings), jnp.int32) for f in plan["starts"]]
        block = jnp.broadcast_to(value, self.level_shapes[-1]).astype(self.dtype)
        # invert the load's axis mapping: block axes (var order) -> dim order
        if plan["perm"] != sorted(plan["perm"]):
            inverse = [plan["perm"].index(i) for i in range(len(plan["perm"]))]
            block = jnp.transpose(block, inverse)
        block = block.reshape(plan["sizes"])
        self.ref[...] = jax.lax.dynamic_update_slice(self.ref[...], block, starts)

    @property
    def grid_shape(self):
        return self.level_shapes[0] if self.level_shapes else ()

    def offsets(self, bindings: dict):
        total = 0
        for evaluate, stride in zip(self._evaluators, self.strides):
            total = total + evaluate(bindings) * stride
        return total


class Kernel:
    """The integrated compute kernel plus its generated launch function.

    Calling the kernel with concrete arrays (and ``meta`` keyword values for
    the constexpr symbols) specializes, compiles and runs it; compiled
    specializations are cached by (shapes, dtypes, meta).  The call returns
    the output array(s) — JAX is functional, so the caller-provided output
    buffer contributes only its shape and dtype (see ``examples`` for the
    PyTorch-style wrappers).
    """

    def __init__(self, arrangement, application, tensors, name: Optional[str] = None):
        self.arrangement = arrangement
        self.application = application
        self.tensors = tuple(tensors)
        self.name = name or application.__name__
        sig = inspect.signature(application)
        self.param_names = [
            p.name
            for p in sig.parameters.values()
            if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
        ]
        if len(self.param_names) != len(self.tensors):
            raise ValueError(
                f"application takes {len(self.param_names)} tensors, "
                f"make() received {len(self.tensors)}"
            )
        self._code, self.output_params, self.application_source = _transform_application(
            application, self.param_names
        )
        # meta-parameter kwargs: `arrangement(..., BLOCK_SIZE_M=block_size())`
        # introduces an anonymous symbol; callers refer to it by the
        # arrangement's keyword name, so map kwarg name -> symbol name
        self.meta_map: dict[str, str] = {}
        for p in inspect.signature(arrangement).parameters.values():
            if isinstance(p.default, Symbol):
                self.meta_map[p.name] = p.default.name
        self.arranged = tuple(self.arrangement(*self.tensors))
        if len(self.arranged) != len(self.tensors):
            raise ValueError("arrangement must return one arranged tensor per parameter")
        self._check_outermost_consistency()
        self._cache: dict = {}

    # -- the paper's §3.2.1 correctness principle -----------------------------

    def _check_outermost_consistency(self):
        """Arranged non-scalar parameters must agree on the outermost level
        *rank* symbolically; sizes are re-checked numerically per launch."""
        ranks = {
            len(a.levels[0])
            for a, t in zip(self.arranged, self.tensors)
            if t.source_ndim > 0
        }
        if len(ranks) > 1:
            raise ValueError(
                f"kernel {self.name!r}: outermost levels of the arranged parameters "
                f"have mismatched ranks {sorted(ranks)} — the arrangement is invalid "
                "(paper §3.2.1)"
            )

    # -- symbol binding --------------------------------------------------------

    def _bindings(self, args, meta):
        bindings: dict[str, int] = {}
        for tensor, arg in zip(self.tensors, args):
            if tensor.source_ndim != len(arg.shape):
                raise ValueError(
                    f"parameter {tensor.name!r} expects {tensor.source_ndim} dims, "
                    f"got array of shape {arg.shape}"
                )
            for sym, size in zip(tensor.source_shape, arg.shape):
                bindings[sym.name] = int(size)
        for key, value in meta.items():
            bindings[self.meta_map.get(key, key)] = int(value)
        # defaults for constexpr meta-symbols the caller did not supply
        free: set[str] = set()
        index_vars: set[str] = set()
        for arranged in self.arranged:
            for level in arranged.levels:
                for dim in level:
                    free |= dim.size.free_symbols()
                    index_vars.add(dim.var)
            for expr in arranged.indices:
                free |= expr.free_symbols()
        for name in sorted(free - bindings.keys() - index_vars):
            default = _SYMBOL_DEFAULTS.get(name)
            if default is None:
                raise ValueError(
                    f"kernel {self.name!r}: no value for symbol {name!r} "
                    "(pass it as a keyword argument)"
                )
            bindings[name] = default
        return bindings

    # -- specialization ----------------------------------------------------------

    def _specialize(self, shapes, dtypes, meta_items):
        meta = dict(meta_items)
        fake_args = [jax.ShapeDtypeStruct(s, d) for s, d in zip(shapes, dtypes)]
        bindings = self._bindings(fake_args, meta)

        specs = [
            _ParamSpec(name, arranged, bindings, dtype, tensor.other)
            for name, arranged, tensor, dtype in zip(
                self.param_names, self.arranged, self.tensors, dtypes
            )
        ]

        grids = {s.name: s.grid_shape for s in specs if not s.is_scalar}
        distinct = {g for g in grids.values()}
        if len(distinct) > 1:
            raise ValueError(
                f"kernel {self.name!r}: outermost-level shapes disagree: {grids} "
                "— the arrangement is invalid (paper §3.2.1)"
            )
        grid = distinct.pop() if distinct else ()
        grid = grid if grid else (1,)

        in_specs = [s for s in specs if s.name not in self.output_params]
        out_specs = [s for s in specs if s.name in self.output_params]
        code = self._code
        app_globals = dict(self.application.__globals__)

        def kernel_body(*refs):
            for spec, ref in zip(in_specs + out_specs, refs):
                spec.ref = ref
            pids = [pl.program_id(i) for i in range(len(grid))]
            proxies = {}
            for spec in specs:
                if spec.is_scalar:
                    proxies[spec.name] = _ScalarProxy(spec.ref)
                    continue
                bound = {var: pid for var, pid in zip(spec.level_vars[0], pids)}
                proxies[spec.name] = TileProxy(spec, 1, bound)

            def store(proxy, value):
                _do_store(proxy, value)

            def store_item(proxy, index, value):
                _do_store(proxy, value, index)

            scope = dict(app_globals)
            scope["__nt_store__"] = store
            scope["__nt_store_item__"] = store_item
            exec(code, scope)  # noqa: S102 — our own transformed AST
            scope["__nt_application__"](*(proxies[n] for n in self.param_names))

        out_shape = [
            jax.ShapeDtypeStruct(s.padded_shape, s.dtype) for s in out_specs
        ]

        call = pl.pallas_call(
            kernel_body,
            grid=grid,
            out_shape=out_shape,
            interpret=True,
        )

        def launch(*arrays):
            padded = []
            for spec, arr in zip(specs, arrays):
                if spec.name in self.output_params:
                    continue
                if spec.is_scalar:
                    padded.append(jnp.asarray(arr).reshape(()))
                    continue
                pad = [
                    (0, p - s) for p, s in zip(spec.padded_shape, arr.shape)
                ]
                if any(hi for _, hi in pad):
                    arr = jnp.pad(arr, pad, constant_values=spec.pad_value)
                padded.append(arr)
            results = call(*padded)
            cropped = []
            for spec, res in zip(out_specs, results):
                if res.shape != spec.orig_shape:
                    res = res[tuple(slice(0, s) for s in spec.orig_shape)]
                cropped.append(res)
            return cropped[0] if len(cropped) == 1 else tuple(cropped)

        launch.grid = grid
        launch.specs = specs
        return launch

    def specialize(self, *args, **meta):
        """Return the cached compiled launch function for these arguments."""
        shapes = tuple(tuple(a.shape) for a in args)
        dtypes = tuple(jnp.asarray(a).dtype if not hasattr(a, "dtype") else a.dtype for a in args)
        key = (shapes, dtypes, tuple(sorted(meta.items())))
        launch = self._cache.get(key)
        if launch is None:
            launch = self._specialize(shapes, dtypes, tuple(sorted(meta.items())))
            self._cache[key] = launch
        return launch

    def __call__(self, *args, **meta):
        args = tuple(jnp.asarray(a) for a in args)
        launch = self.specialize(*args, **meta)
        return launch(*args)

    # -- auto-tuning (paper §5.2.1 mentions NineToothed's auto-tuner) -----------

    def autotune(self, *args, candidates: dict, repeats: int = 3, **fixed_meta):
        """Pick the fastest meta-parameter assignment by measurement.

        ``candidates`` maps meta-parameter names to lists of values; the
        full cross product is timed (``repeats`` runs after one warmup)
        and the best assignment is returned along with its mean runtime.

        >>> best, secs = kernel.autotune(a, b, out,
        ...     candidates={"BLOCK_SIZE_M": [32, 64], "BLOCK_SIZE_N": [32, 64]})
        """
        import itertools
        import time

        names = list(candidates)
        best_meta, best_time = None, float("inf")
        for values in itertools.product(*(candidates[n] for n in names)):
            meta = dict(fixed_meta)
            meta.update(zip(names, values))
            try:
                out = self(*args, **meta)
            except ValueError:
                continue  # e.g. block larger than a dim the arrangement rejects
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(self(*args, **meta))
            elapsed = (time.perf_counter() - t0) / repeats
            if elapsed < best_time:
                best_meta, best_time = meta, elapsed
        if best_meta is None:
            raise ValueError(f"kernel {self.name!r}: no viable candidate assignment")
        return best_meta, best_time

    # -- metadata export for the Rust mirror (arrange/ in rust) -----------------

    def export_metadata(self) -> dict:
        params = []
        for name, arranged, tensor in zip(self.param_names, self.arranged, self.tensors):
            params.append(
                {
                    "name": name,
                    "source_ndim": tensor.source_ndim,
                    "is_output": name in self.output_params,
                    "levels": [
                        [{"size": str(d.size), "var": d.var} for d in level]
                        for level in arranged.levels
                    ],
                    "indices": [str(e) for e in arranged.indices],
                    "pad_value": tensor.other,
                }
            )
        return {"kernel": self.name, "params": params}


def _do_store(proxy, value, index=None):
    """The generated store: scatter a tile into its target region."""
    if isinstance(proxy, _ScalarProxy):
        raise ValueError("cannot store to a scalar parameter")
    if not isinstance(proxy, TileProxy):
        raise TypeError(f"store target must be a kernel parameter, got {type(proxy)}")
    spec = proxy._spec
    if proxy._level != spec.num_levels - 1:
        raise ValueError(
            f"store to {spec.name!r} must target the innermost level; "
            f"index into the remaining levels first"
        )
    if hasattr(value, "_nt_materialize"):
        value = value._nt_materialize()
    if spec.fast_plan is not None and index is None:
        spec.fast_store(dict(proxy._bindings), value)
        return
    bindings = dict(proxy._bindings)
    block_shape = spec.level_shapes[-1]
    for axis, var in enumerate(spec.level_vars[-1]):
        bindings[var] = _iota(block_shape, axis)
    offsets = spec.offsets(bindings)
    offsets = jnp.broadcast_to(offsets, block_shape) if block_shape else offsets
    if hasattr(value, "_nt_materialize"):
        value = value._nt_materialize()
    value = jnp.asarray(value, dtype=spec.dtype)
    if index is not None:
        offsets = offsets[index]
        value = jnp.broadcast_to(value, offsets.shape)
    else:
        value = jnp.broadcast_to(value, block_shape)
    ref = spec.ref
    current = ref[...]
    updated = (
        current.reshape(-1)
        .at[offsets.reshape(-1)]
        .set(value.reshape(-1))
        .reshape(current.shape)
    )
    ref[...] = updated


# Registry of symbol defaults so the launch function can auto-pick block
# sizes the caller omitted (the paper's `block_size()` meta-parameters).
_SYMBOL_DEFAULTS: dict[str, int] = {}

_original_symbol_init = Symbol.__init__


def _symbol_init(self, name, constexpr=False, default=None):
    _original_symbol_init(self, name, constexpr=constexpr, default=default)
    if default is not None:
        _SYMBOL_DEFAULTS[name] = default


Symbol.__init__ = _symbol_init


def make(arrangement, application, tensors, name: Optional[str] = None) -> Kernel:
    """Integrate an arrangement and an application into a compute kernel
    (paper §3.2.3)."""
    return Kernel(arrangement, application, tensors, name=name)
