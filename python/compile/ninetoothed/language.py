"""``ntl`` — the in-kernel language (paper Listings 6, 8).

Application functions manipulate *tiles*.  A tile is either a
:class:`~.generation.TileProxy` (a lazy view into a source tensor that
materializes to a jnp array on first use — the generated equivalent of a
Triton ``tl.load``) or an already-materialized jnp array.  Every function
here accepts both, mirroring ``triton.language``'s role in Triton kernels.

All reductions default to f32 accumulation, matching both Triton's ``tl.dot``
behaviour and the MXU's native accumulate type on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

float32 = jnp.float32
float16 = jnp.float16
bfloat16 = jnp.bfloat16
int32 = jnp.int32


def _m(value):
    """Materialize a tile proxy (or pass a jnp value through)."""
    materialize = getattr(value, "_nt_materialize", None)
    return materialize() if materialize is not None else value


def zeros(shape, dtype=jnp.float32):
    shape = tuple(int(s) for s in shape)
    return jnp.zeros(shape, dtype)


def full(shape, value, dtype=jnp.float32):
    shape = tuple(int(s) for s in shape)
    return jnp.full(shape, value, dtype)


def arange(start, stop=None, dtype=jnp.int32):
    return jnp.arange(start, stop, dtype=dtype)


def dot(a, b, out_dtype=jnp.float32):
    """Tile matmul — lowers to the MXU (``jnp.dot``) on real hardware."""
    return jnp.dot(_m(a), _m(b), preferred_element_type=out_dtype)


def trans(a):
    return jnp.swapaxes(_m(a), -1, -2)


def exp(a):
    return jnp.exp(_m(a))


def exp2(a):
    return jnp.exp2(_m(a))


def log(a):
    return jnp.log(_m(a))


def sqrt(a):
    return jnp.sqrt(_m(a))


def rsqrt(a):
    return jax.lax.rsqrt(_m(a))


def sigmoid(a):
    return jax.nn.sigmoid(_m(a))


def silu(a):
    a = _m(a)
    return a * jax.nn.sigmoid(a)


def maximum(a, b):
    return jnp.maximum(_m(a), _m(b))


def minimum(a, b):
    return jnp.minimum(_m(a), _m(b))


def where(cond, a, b):
    return jnp.where(_m(cond), _m(a), _m(b))


def sum(a, axis=None, keepdims=False):  # noqa: A001 — mirrors tl.sum
    return jnp.sum(_m(a), axis=axis, keepdims=keepdims)


def max(a, axis=None, keepdims=False):  # noqa: A001 — mirrors tl.max
    return jnp.max(_m(a), axis=axis, keepdims=keepdims)


def min(a, axis=None, keepdims=False):  # noqa: A001 — mirrors tl.min
    return jnp.min(_m(a), axis=axis, keepdims=keepdims)


def cast(a, dtype):
    return _m(a).astype(dtype)


def cos(a):
    return jnp.cos(_m(a))


def sin(a):
    return jnp.sin(_m(a))


def cat(tensors, axis=-1):
    return jnp.concatenate([_m(t) for t in tensors], axis=axis)


def reshape(a, shape):
    return jnp.reshape(_m(a), tuple(int(s) for s in shape))
