"""ninetoothed-pallas: a reproduction of the NineToothed DSL
(Huang et al., 2025) targeting JAX/Pallas instead of Triton.

Public API (mirrors the paper's listings):

>>> import ninetoothed
>>> from ninetoothed import Tensor, Symbol, block_size
>>> kernel = ninetoothed.make(arrangement, application, tensors)
"""

from . import language  # noqa: F401  (imported as `ntl` by kernels)
from .generation import Kernel, TileProxy, make
from .symbols import Expr, Symbol, block_size
from .tensor import Dim, Tensor

__all__ = [
    "Dim",
    "Expr",
    "Kernel",
    "Symbol",
    "Tensor",
    "TileProxy",
    "block_size",
    "language",
    "make",
]

__version__ = "0.1.0"
