"""Pure-jnp oracles for all ten paper kernels (evaluation §5.1).

These are the correctness references: every NineToothed-generated kernel
and every hand-written Pallas baseline is checked against these with
``assert_allclose`` in ``python/tests``.  They are also lowered to HLO as
the "PyTorch" supplementary reference series of Fig 6/7 (the framework's
own operator implementations).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def add(input, other):
    return input + other


def addmm(input, mat1, mat2, beta=1.0, alpha=1.0):
    beta = jnp.asarray(beta, dtype=jnp.float32)
    alpha = jnp.asarray(alpha, dtype=jnp.float32)
    mm_ = jnp.dot(mat1, mat2, preferred_element_type=jnp.float32)
    return (beta * input.astype(jnp.float32) + alpha * mm_).astype(input.dtype)


def bmm(input, other):
    return jnp.matmul(input, other, preferred_element_type=jnp.float32).astype(input.dtype)


def conv2d(input, filter):
    """Basic 2D convolution: stride 1, no padding (paper §4.3)."""
    out = jax.lax.conv_general_dilated(
        input.astype(jnp.float32),
        filter.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out.astype(input.dtype)


def mm(input, other):
    return jnp.dot(input, other, preferred_element_type=jnp.float32).astype(input.dtype)


def rms_norm(input, eps=1e-6):
    x = input.astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps)).astype(input.dtype)


def rope(input, cos, sin):
    """Rotary position embedding, half-rotation (Llama) convention.

    input: (B, S, H, D); cos/sin: (S, D/2).
    """
    x = input.astype(jnp.float32)
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos.astype(jnp.float32)[None, :, None, :]
    s = sin.astype(jnp.float32)[None, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(input.dtype)


def sdpa(query, key, value):
    """Scaled dot-product attention, non-causal (paper task 8).

    query/key/value: (B, H, S, D).
    """
    q = query.astype(jnp.float32)
    k = key.astype(jnp.float32)
    v = value.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v)
    return out.astype(query.dtype)


def silu(input):
    x = input.astype(jnp.float32)
    return (x * jax.nn.sigmoid(x)).astype(input.dtype)


def softmax(input):
    """Row-wise softmax over the last dim of a 2D tensor."""
    x = input.astype(jnp.float32)
    return jax.nn.softmax(x, axis=-1).astype(input.dtype)


ALL = {
    "add": add,
    "addmm": addmm,
    "bmm": bmm,
    "conv2d": conv2d,
    "mm": mm,
    "rms_norm": rms_norm,
    "rope": rope,
    "sdpa": sdpa,
    "silu": silu,
    "softmax": softmax,
}
