"""Kernel library: NineToothed DSL implementations (``nt``), hand-written
Pallas baselines (``baseline`` — the "Triton" comparator role of paper §5),
and pure-jnp oracles (``ref``)."""
