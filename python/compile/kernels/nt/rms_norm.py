"""RMS normalization in NineToothed (paper task 6)."""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor

EPS = 1e-6


def arrangement(input, output):
    return input.tile((1, -1)), output.tile((1, -1))


def application(input, output):
    x = ntl.cast(input, ntl.float32)
    mean_square = ntl.sum(x * x) / x.shape[-1]
    output = x * ntl.rsqrt(mean_square + EPS)  # noqa: F841


tensors = (Tensor(2), Tensor(2))

kernel = ninetoothed.make(arrangement, application, tensors, name="rms_norm")
