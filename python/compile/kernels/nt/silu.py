"""SiLU activation in NineToothed (paper task 9)."""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Symbol, Tensor

BLOCK_SIZE = Symbol("BLOCK_SIZE", constexpr=True, default=1024)


def arrangement(input, output, BLOCK_SIZE=BLOCK_SIZE):
    return input.tile((BLOCK_SIZE,)), output.tile((BLOCK_SIZE,))


def application(input, output):
    output = ntl.silu(input)  # noqa: F841


tensors = (Tensor(1), Tensor(1))

kernel = ninetoothed.make(arrangement, application, tensors, name="silu")
