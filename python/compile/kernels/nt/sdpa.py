"""Scaled dot-product attention in NineToothed (paper task 8).

FlashAttention-2-style single-pass algorithm: each program owns one query
row-block; the key/value column-blocks are visited in an online-softmax
loop with running maximum and denominator.  The arrangement expresses
exactly the tiling a hand-written FA2 kernel uses: queries tiled by rows,
keys/values tiled by rows then grouped so each program sees every block.
"""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor, block_size


def arrangement(
    query,
    key,
    value,
    output,
    BLOCK_SIZE_M=block_size(64),
    BLOCK_SIZE_N=block_size(64),
):
    query_arranged = query.tile((1, 1, BLOCK_SIZE_M, -1))
    query_arranged.dtype = query_arranged.dtype.squeeze((0, 1))

    key_arranged = key.tile((1, 1, BLOCK_SIZE_N, -1))
    key_arranged.dtype = key_arranged.dtype.squeeze((0, 1))
    key_arranged = key_arranged.tile((1, 1, -1, 1))
    key_arranged = key_arranged.expand((-1, -1, query_arranged.shape[2], -1))
    key_arranged.dtype = key_arranged.dtype.squeeze((0, 1, 3))

    value_arranged = value.tile((1, 1, BLOCK_SIZE_N, -1))
    value_arranged.dtype = value_arranged.dtype.squeeze((0, 1))
    value_arranged = value_arranged.tile((1, 1, -1, 1))
    value_arranged = value_arranged.expand((-1, -1, query_arranged.shape[2], -1))
    value_arranged.dtype = value_arranged.dtype.squeeze((0, 1, 3))

    output_arranged = output.tile((1, 1, BLOCK_SIZE_M, -1))
    output_arranged.dtype = output_arranged.dtype.squeeze((0, 1))

    return query_arranged, key_arranged, value_arranged, output_arranged


def application(query, key, value, output):
    scale = 1.0 / query.shape[-1] ** 0.5
    q = ntl.cast(query, ntl.float32) * scale

    m = ntl.full((query.shape[0],), float("-inf"), dtype=ntl.float32)
    l = ntl.zeros((query.shape[0],), dtype=ntl.float32)  # noqa: E741
    acc = ntl.zeros((query.shape[0], query.shape[1]), dtype=ntl.float32)

    for j in range(key.shape[0]):
        scores = ntl.dot(q, ntl.trans(key[j]))
        m_new = ntl.maximum(m, ntl.max(scores, axis=1))
        p = ntl.exp(scores - m_new[:, None])
        alpha = ntl.exp(m - m_new)
        l = l * alpha + ntl.sum(p, axis=1)  # noqa: E741
        acc = acc * alpha[:, None] + ntl.dot(p, ntl.cast(value[j], ntl.float32))
        m = m_new

    output = acc / l[:, None]  # noqa: F841


tensors = (Tensor(4), Tensor(4), Tensor(4), Tensor(4))

kernel = ninetoothed.make(arrangement, application, tensors, name="sdpa")
