"""2D convolution in NineToothed via implicit GEMM (paper Listing 8).

The arrangement maps NCHW convolution onto the already-defined matrix
multiplication: the input is tiled with a filter-shaped window, ravelled
and flattened into an (N*P*Q, C*R*S) view, the filter into (C*R*S, K), and
the output into (N*P*Q, K) — after which mm's arrangement *and* mm's
application are reused verbatim.
"""

import ninetoothed
from ninetoothed import Tensor

from kernels.nt import mm


def arrangement(input, filter, output):
    input_arranged = input.tile((1, *filter.shape[1:]), strides=(-1, -1, 1, 1))
    input_arranged = input_arranged.squeeze(1)
    input_arranged.dtype = input_arranged.dtype.squeeze(0)
    input_arranged = input_arranged.ravel()
    input_arranged = input_arranged.flatten(end_dim=3).flatten(start_dim=1)

    filter_arranged = filter.flatten(start_dim=1)
    filter_arranged = filter_arranged.permute((1, 0))

    output_arranged = output.permute((0, 2, 3, 1)).flatten(end_dim=3)

    return mm.arrangement(input_arranged, filter_arranged, output_arranged)


shape_options = {"constexpr": True}

tensors = tuple(Tensor(4, shape_options=shape_options) for _ in range(3))

kernel = ninetoothed.make(arrangement, mm.application, tensors, name="conv2d")
