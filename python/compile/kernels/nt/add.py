"""Vector addition in NineToothed (paper Listing 3)."""

import ninetoothed
import ninetoothed.language as ntl  # noqa: F401
from ninetoothed import Symbol, Tensor

BLOCK_SIZE = Symbol("BLOCK_SIZE", constexpr=True, default=1024)


def arrangement(input, other, output, BLOCK_SIZE=BLOCK_SIZE):
    input_arranged = input.tile((BLOCK_SIZE,))
    other_arranged = other.tile((BLOCK_SIZE,))
    output_arranged = output.tile((BLOCK_SIZE,))

    return input_arranged, other_arranged, output_arranged


def application(input, other, output):
    output = input + other  # noqa: F841


tensors = tuple(Tensor(1) for _ in range(3))

kernel = ninetoothed.make(arrangement, application, tensors, name="add")
