"""LayerNorm (no affine) in NineToothed — extension kernel: a second
row-wise reduction built by reusing the rms_norm arrangement verbatim
(arrange-and-apply modularity, paper §3.2)."""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor

from kernels.nt import rms_norm

EPS = 1e-6


def application(input, output):
    x = ntl.cast(input, ntl.float32)
    mean = ntl.sum(x) / x.shape[-1]
    centered = x - mean
    var = ntl.sum(centered * centered) / x.shape[-1]
    output = centered * ntl.rsqrt(var + EPS)  # noqa: F841


tensors = (Tensor(2), Tensor(2))

kernel = ninetoothed.make(rms_norm.arrangement, application, tensors, name="layer_norm")
