"""Row-wise softmax in NineToothed (paper task 10).

Each program owns one full row; padding uses ``other=-inf`` so padded
columns vanish under ``exp`` (the pad-and-crop analogue of Triton's
``other=-float("inf")`` masked load).
"""

import math

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor


def arrangement(input, output):
    return input.tile((1, -1)), output.tile((1, -1))


def application(input, output):
    numerator = ntl.exp(ntl.cast(input, ntl.float32) - ntl.max(input))
    output = numerator / ntl.sum(numerator)  # noqa: F841


tensors = (Tensor(2, other=-math.inf), Tensor(2))

kernel = ninetoothed.make(arrangement, application, tensors, name="softmax")
