"""addmm in NineToothed: out = beta * input + alpha * (mat1 @ mat2).

Reuses the matrix-multiplication arrangement (the arrange-and-apply
modularity argument of paper §3.2); only the added-matrix tiling and the
final combination differ.
"""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor, block_size

from kernels.nt import mm


def arrangement(
    input,
    mat1,
    mat2,
    beta,
    alpha,
    output,
    BLOCK_SIZE_M=block_size(64),
    BLOCK_SIZE_N=block_size(64),
    BLOCK_SIZE_K=block_size(64),
):
    input_arranged = input.tile((BLOCK_SIZE_M, BLOCK_SIZE_N))
    mat1_arranged, mat2_arranged, output_arranged = mm.arrangement(
        mat1, mat2, output, BLOCK_SIZE_M, BLOCK_SIZE_N, BLOCK_SIZE_K
    )

    return input_arranged, mat1_arranged, mat2_arranged, beta, alpha, output_arranged


def application(input, mat1, mat2, beta, alpha, output):
    accumulator = ntl.zeros(output.shape, dtype=ntl.float32)

    for k in range(mat1.shape[0]):
        accumulator += ntl.dot(mat1[k], mat2[k])

    output = beta * input + alpha * accumulator  # noqa: F841


tensors = (Tensor(2), Tensor(2), Tensor(2), Tensor(0), Tensor(0), Tensor(2))

kernel = ninetoothed.make(arrangement, application, tensors, name="addmm")
