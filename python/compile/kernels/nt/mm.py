"""Matrix multiplication in NineToothed (paper Listings 5-7)."""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor, block_size


def arrangement(
    input,
    other,
    output,
    BLOCK_SIZE_M=block_size(64),
    BLOCK_SIZE_N=block_size(64),
    BLOCK_SIZE_K=block_size(64),
):
    output_arranged = output.tile((BLOCK_SIZE_M, BLOCK_SIZE_N))

    input_arranged = input.tile((BLOCK_SIZE_M, BLOCK_SIZE_K))
    input_arranged = input_arranged.tile((1, -1))
    input_arranged = input_arranged.expand((-1, output_arranged.shape[1]))
    input_arranged.dtype = input_arranged.dtype.squeeze(0)

    other_arranged = other.tile((BLOCK_SIZE_K, BLOCK_SIZE_N))
    other_arranged = other_arranged.tile((-1, 1))
    other_arranged = other_arranged.expand((output_arranged.shape[0], -1))
    other_arranged.dtype = other_arranged.dtype.squeeze(1)

    return input_arranged, other_arranged, output_arranged


def application(input, other, output):
    accumulator = ntl.zeros(output.shape, dtype=ntl.float32)

    for k in range(input.shape[0]):
        accumulator += ntl.dot(input[k], other[k])

    output = accumulator  # noqa: F841


tensors = (Tensor(2), Tensor(2), Tensor(2))

kernel = ninetoothed.make(arrangement, application, tensors, name="mm")
