"""Attention with an additive score bias, in NineToothed.

Extension of the paper-task sdpa kernel used by the end-to-end model
(paper §5.3.2): the (S_q, S_k) ``bias`` tensor is added to the attention
scores before the online softmax, which expresses causal masking at
prefill time and padded-KV-cache masking at decode time with the same
kernel.  The bias arrangement mirrors mm's input arrangement — tiled,
grouped into a per-program loop level, and broadcast over batch and heads
with ``unsqueeze``/``expand`` — demonstrating arrangement reuse across
kernels (the modularity claim of paper §3.2).
"""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor, block_size


def arrangement(
    query,
    key,
    value,
    bias,
    output,
    BLOCK_SIZE_M=block_size(64),
    BLOCK_SIZE_N=block_size(64),
):
    query_arranged = query.tile((1, 1, BLOCK_SIZE_M, -1))
    query_arranged.dtype = query_arranged.dtype.squeeze((0, 1))

    key_arranged = key.tile((1, 1, BLOCK_SIZE_N, -1))
    key_arranged.dtype = key_arranged.dtype.squeeze((0, 1))
    key_arranged = key_arranged.tile((1, 1, -1, 1))
    key_arranged = key_arranged.expand((-1, -1, query_arranged.shape[2], -1))
    key_arranged.dtype = key_arranged.dtype.squeeze((0, 1, 3))

    value_arranged = value.tile((1, 1, BLOCK_SIZE_N, -1))
    value_arranged.dtype = value_arranged.dtype.squeeze((0, 1))
    value_arranged = value_arranged.tile((1, 1, -1, 1))
    value_arranged = value_arranged.expand((-1, -1, query_arranged.shape[2], -1))
    value_arranged.dtype = value_arranged.dtype.squeeze((0, 1, 3))

    bias_arranged = bias.tile((BLOCK_SIZE_M, BLOCK_SIZE_N))
    bias_arranged = bias_arranged.tile((1, -1))
    bias_arranged.dtype = bias_arranged.dtype.squeeze(0)
    bias_arranged = bias_arranged.unsqueeze(0).unsqueeze(0)
    bias_arranged = bias_arranged.expand(
        (query_arranged.shape[0], query_arranged.shape[1], -1, -1)
    )

    output_arranged = output.tile((1, 1, BLOCK_SIZE_M, -1))
    output_arranged.dtype = output_arranged.dtype.squeeze((0, 1))

    return query_arranged, key_arranged, value_arranged, bias_arranged, output_arranged


def application(query, key, value, bias, output):
    scale = 1.0 / query.shape[-1] ** 0.5
    q = ntl.cast(query, ntl.float32) * scale

    m = ntl.full((query.shape[0],), float("-inf"), dtype=ntl.float32)
    l = ntl.zeros((query.shape[0],), dtype=ntl.float32)  # noqa: E741
    acc = ntl.zeros((query.shape[0], query.shape[1]), dtype=ntl.float32)

    for j in range(key.shape[0]):
        scores = ntl.dot(q, ntl.trans(key[j])) + ntl.cast(bias[j], ntl.float32)
        m_new = ntl.maximum(m, ntl.max(scores, axis=1))
        p = ntl.exp(scores - m_new[:, None])
        alpha = ntl.exp(m - m_new)
        l = l * alpha + ntl.sum(p, axis=1)  # noqa: E741
        acc = acc * alpha[:, None] + ntl.dot(p, ntl.cast(value[j], ntl.float32))
        m = m_new

    output = acc / ntl.maximum(l, 1e-20)[:, None]  # noqa: F841


# bias pads with a large negative value so padded keys and padded query
# rows are masked out (finite, not -inf, to keep the online softmax nan-free)
tensors = (Tensor(4), Tensor(4), Tensor(4), Tensor(2, other=-1e30), Tensor(4))

kernel = ninetoothed.make(arrangement, application, tensors, name="sdpa_bias")
