"""GELU activation in NineToothed (extension kernel beyond the paper's
task list — demonstrates that new element-wise operators cost one line of
application code, the paper's §2 prototyping argument)."""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Symbol, Tensor

BLOCK_SIZE = Symbol("GELU_BLOCK", constexpr=True, default=1024)


def arrangement(input, output, GELU_BLOCK=BLOCK_SIZE):
    return input.tile((GELU_BLOCK,)), output.tile((GELU_BLOCK,))


def application(input, output):
    x = ntl.cast(input, ntl.float32)
    # tanh approximation of GELU
    inner = 0.7978845608028654 * (x + 0.044715 * x * x * x)
    output = 0.5 * x * (1.0 + (ntl.exp(2.0 * inner) - 1.0) / (ntl.exp(2.0 * inner) + 1.0))  # noqa: F841


tensors = (Tensor(1), Tensor(1))

kernel = ninetoothed.make(arrangement, application, tensors, name="gelu")
