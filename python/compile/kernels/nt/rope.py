"""Rotary position embedding in NineToothed (paper task 7).

Half-rotation (Llama) convention.  ``input`` is (B, S, H, D); the cos/sin
tables are (S, D/2) and broadcast over batch and heads by ``unsqueeze`` +
``expand`` in the arrangement.
"""

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Tensor


def arrangement(input, cos, sin, output):
    input_arranged = input.tile((1, 1, 1, -1))
    input_arranged.dtype = input_arranged.dtype.squeeze((0, 1, 2))

    cos_arranged = cos.tile((1, -1))
    cos_arranged = cos_arranged.unsqueeze(0).unsqueeze(2)
    cos_arranged = cos_arranged.expand(
        (input_arranged.shape[0], -1, input_arranged.shape[2], -1)
    )
    cos_arranged.dtype = cos_arranged.dtype.squeeze(0)

    sin_arranged = sin.tile((1, -1))
    sin_arranged = sin_arranged.unsqueeze(0).unsqueeze(2)
    sin_arranged = sin_arranged.expand(
        (input_arranged.shape[0], -1, input_arranged.shape[2], -1)
    )
    sin_arranged.dtype = sin_arranged.dtype.squeeze(0)

    output_arranged = output.tile((1, 1, 1, -1))
    output_arranged.dtype = output_arranged.dtype.squeeze((0, 1, 2))

    return input_arranged, cos_arranged, sin_arranged, output_arranged


def application(input, cos, sin, output):
    half = input.shape[-1] // 2
    x1 = ntl.cast(input, ntl.float32)[:half]
    x2 = ntl.cast(input, ntl.float32)[half:]
    c = ntl.cast(cos, ntl.float32)
    s = ntl.cast(sin, ntl.float32)
    output = ntl.cat((x1 * c - x2 * s, x2 * c + x1 * s), axis=-1)  # noqa: F841


tensors = (Tensor(4), Tensor(2), Tensor(2), Tensor(4))

kernel = ninetoothed.make(arrangement, application, tensors, name="rope")
