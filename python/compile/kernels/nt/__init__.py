"""The ten paper kernels implemented in the NineToothed DSL (paper §4/§5.1)."""

from kernels.nt import (  # noqa: F401
    add,
    addmm,
    bmm,
    conv2d,
    mm,
    rms_norm,
    rope,
    sdpa,
    sdpa_bias,
    silu,
    softmax,
)

KERNELS = {
    "add": add.kernel,
    "addmm": addmm.kernel,
    "bmm": bmm.kernel,
    "conv2d": conv2d.kernel,
    "mm": mm.kernel,
    "rms_norm": rms_norm.kernel,
    "rope": rope.kernel,
    "sdpa": sdpa.kernel,
    "sdpa_bias": sdpa_bias.kernel,
    "silu": silu.kernel,
    "softmax": softmax.kernel,
}
