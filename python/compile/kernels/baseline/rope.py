"""Rotary position embedding, hand-written Pallas comparator.

Half-rotation (Llama) convention over (B, S, H, D) with (S, D/2) tables;
one program per (batch, position, head) triple, explicit slice loads for
the two halves — the manual bookkeeping the NineToothed arrangement
replaces with ``unsqueeze``/``expand``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import crop_to


# --- metrics:begin ---
def rope_kernel(x_ref, cos_ref, sin_ref, out_ref, *, d):
    pid_b = pl.program_id(0)
    pid_s = pl.program_id(1)
    pid_h = pl.program_id(2)
    half = d // 2
    idx = (pl.dslice(pid_b, 1), pl.dslice(pid_s, 1), pl.dslice(pid_h, 1))
    x1 = x_ref[idx + (pl.dslice(0, half),)].astype(jnp.float32)
    x2 = x_ref[idx + (pl.dslice(half, half),)].astype(jnp.float32)
    cos = cos_ref[pl.dslice(pid_s, 1), pl.dslice(0, half)].astype(jnp.float32)
    sin = sin_ref[pl.dslice(pid_s, 1), pl.dslice(0, half)].astype(jnp.float32)
    cos = cos[:, None, None, :]
    sin = sin[:, None, None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out_ref[idx + (pl.dslice(0, half),)] = out1.astype(out_ref.dtype)
    out_ref[idx + (pl.dslice(half, half),)] = out2.astype(out_ref.dtype)


def launch(x, cos, sin, out):
    b, s, h, d = x.shape
    result = pl.pallas_call(
        functools.partial(rope_kernel, d=d),
        grid=(b, s, h),
        out_shape=jax.ShapeDtypeStruct(x.shape, out.dtype),
        interpret=True,
    )(x, cos, sin)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, cos, sin, out, **_meta):
    return launch(x, cos, sin, out)
