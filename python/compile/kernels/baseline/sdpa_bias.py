"""Attention with additive score bias, hand-written Pallas comparator."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to

BLOCK_M = 64
BLOCK_N = 64


# --- metrics:begin ---
def sdpa_bias_kernel(q_ref, k_ref, v_ref, b_ref, o_ref, *, block_m, block_n, d):
    pid_b = pl.program_id(0)
    pid_h = pl.program_id(1)
    pid_m = pl.program_id(2)
    offs_m = pid_m * block_m
    seq = k_ref.shape[2]
    scale = 1.0 / d**0.5

    bh = (pl.dslice(pid_b, 1), pl.dslice(pid_h, 1))
    q = q_ref[bh + (pl.dslice(offs_m, block_m), pl.dslice(0, d))]
    q = q.reshape(block_m, d).astype(jnp.float32) * scale

    m_i = jnp.full((block_m,), -jnp.inf, jnp.float32)
    l_i = jnp.zeros((block_m,), jnp.float32)
    acc = jnp.zeros((block_m, d), jnp.float32)

    for j in range(seq // block_n):
        offs_n = j * block_n
        k = k_ref[bh + (pl.dslice(offs_n, block_n), pl.dslice(0, d))]
        k = k.reshape(block_n, d).astype(jnp.float32)
        v = v_ref[bh + (pl.dslice(offs_n, block_n), pl.dslice(0, d))]
        v = v.reshape(block_n, d).astype(jnp.float32)
        bias = b_ref[pl.dslice(offs_m, block_m), pl.dslice(offs_n, block_n)].astype(jnp.float32)
        scores = jnp.dot(q, k.T) + bias
        m_new = jnp.maximum(m_i, jnp.max(scores, axis=1))
        p = jnp.exp(scores - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_i = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jnp.dot(p, v)
        m_i = m_new

    out = acc / jnp.maximum(l_i, 1e-20)[:, None]
    o_ref[bh + (pl.dslice(offs_m, block_m), pl.dslice(0, d))] = out.reshape(1, 1, block_m, d).astype(o_ref.dtype)


def launch(q, k, v, bias, out, block_m=BLOCK_M, block_n=BLOCK_N):
    b, h, sq, d = q.shape
    q_p = pad_to(q, (1, 1, block_m, 1))
    k_p = pad_to(k, (1, 1, block_n, 1))
    v_p = pad_to(v, (1, 1, block_n, 1))
    # pad the bias with -inf-like values so padded keys never contribute
    bias_p = pad_to(bias, (block_m, block_n), value=-1e30)
    grid = (b, h, cdiv(sq, block_m))
    result = pl.pallas_call(
        functools.partial(sdpa_bias_kernel, block_m=block_m, block_n=block_n, d=d),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(q_p.shape, out.dtype),
        interpret=True,
    )(q_p, k_p, v_p, bias_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(q, k, v, bias, out, BLOCK_SIZE_M=BLOCK_M, BLOCK_SIZE_N=BLOCK_N):
    return launch(q, k, v, bias, out, block_m=BLOCK_SIZE_M, block_n=BLOCK_SIZE_N)
