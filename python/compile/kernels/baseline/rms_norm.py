"""RMS normalization, hand-written Pallas comparator."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import crop_to, pad_to

EPS = 1e-6


# --- metrics:begin ---
def rms_norm_kernel(x_ref, out_ref, *, block_n, n, eps):
    pid = pl.program_id(0)
    row = x_ref[pl.dslice(pid, 1), pl.dslice(0, block_n)].astype(jnp.float32)
    # padded tail is zero, so the sum over block_n equals the sum over n
    mean_square = jnp.sum(row * row) / n
    out = row * jax.lax.rsqrt(mean_square + eps)
    out_ref[pl.dslice(pid, 1), pl.dslice(0, block_n)] = out.astype(out_ref.dtype)


def launch(x, out, eps=EPS):
    m, n = x.shape
    x_p = pad_to(x, (1, 8))
    block_n = x_p.shape[1]
    result = pl.pallas_call(
        functools.partial(rms_norm_kernel, block_n=block_n, n=n, eps=eps),
        grid=(m,),
        out_shape=jax.ShapeDtypeStruct(x_p.shape, out.dtype),
        interpret=True,
    )(x_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, out, **_meta):
    return launch(x, out)
