"""addmm, hand-written Pallas (explicit-parallel comparator).

out = beta * input + alpha * (mat1 @ mat2) — the Triton-style version
duplicates the full matmul kernel body and adds the scaled combination;
there is no arrangement to reuse, which is exactly the redundancy argument
of paper §3.2.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to

BLOCK_M = 64
BLOCK_N = 64
BLOCK_K = 64


# --- metrics:begin ---
def addmm_kernel(inp_ref, a_ref, b_ref, beta_ref, alpha_ref, c_ref, *, block_m, block_n, block_k):
    pid_m = pl.program_id(0)
    pid_n = pl.program_id(1)
    offs_m = pid_m * block_m
    offs_n = pid_n * block_n
    k_size = a_ref.shape[1]
    acc = jnp.zeros((block_m, block_n), jnp.float32)
    for k in range(k_size // block_k):
        offs_k = k * block_k
        a = a_ref[pl.dslice(offs_m, block_m), pl.dslice(offs_k, block_k)]
        b = b_ref[pl.dslice(offs_k, block_k), pl.dslice(offs_n, block_n)]
        acc += jnp.dot(a, b, preferred_element_type=jnp.float32)
    inp = inp_ref[pl.dslice(offs_m, block_m), pl.dslice(offs_n, block_n)]
    beta = beta_ref[...].reshape(())
    alpha = alpha_ref[...].reshape(())
    out = beta * inp.astype(jnp.float32) + alpha * acc
    c_ref[pl.dslice(offs_m, block_m), pl.dslice(offs_n, block_n)] = out.astype(c_ref.dtype)


def launch(inp, a, b, beta, alpha, out, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    m, k = a.shape
    _, n = b.shape
    grid = (cdiv(m, block_m), cdiv(n, block_n))
    inp_p = pad_to(inp, (block_m, block_n))
    a_p = pad_to(a, (block_m, block_k))
    b_p = pad_to(b, (block_k, block_n))
    beta = jnp.asarray(beta, jnp.float32).reshape(())
    alpha = jnp.asarray(alpha, jnp.float32).reshape(())
    result = pl.pallas_call(
        functools.partial(addmm_kernel, block_m=block_m, block_n=block_n, block_k=block_k),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], b_p.shape[1]), out.dtype),
        interpret=True,
    )(inp_p, a_p, b_p, beta, alpha)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(inp, a, b, beta, alpha, out, BLOCK_SIZE_M=BLOCK_M, BLOCK_SIZE_N=BLOCK_N, BLOCK_SIZE_K=BLOCK_K):
    return launch(inp, a, b, beta, alpha, out, block_m=BLOCK_SIZE_M, block_n=BLOCK_SIZE_N, block_k=BLOCK_SIZE_K)
