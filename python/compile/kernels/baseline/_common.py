"""Shared launch-glue for the hand-written Pallas baseline kernels.

The baselines play the role Triton plays in the paper's evaluation: the
explicitly-parallel comparator.  Like Triton kernels they must handle
out-of-range accesses themselves; on this stack that is done by padding
inputs to block multiples before the ``pallas_call`` and cropping outputs
after (the interpret-mode equivalent of ``tl.load(..., mask=..., other=...)``
— see DESIGN.md §2), so each kernel's body performs the same in-bounds
block loads a masked Triton kernel performs on its padded last block.
"""

from __future__ import annotations

import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x, multiples, value=0.0):
    """Pad each dim of ``x`` up to a multiple of ``multiples[d]``."""
    pads = []
    needs = False
    for size, mult in zip(x.shape, multiples):
        target = cdiv(size, mult) * mult
        pads.append((0, target - size))
        needs = needs or target != size
    return jnp.pad(x, pads, constant_values=value) if needs else x


def crop_to(x, shape):
    if tuple(x.shape) == tuple(shape):
        return x
    return x[tuple(slice(0, s) for s in shape)]
