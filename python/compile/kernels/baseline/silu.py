"""SiLU activation, hand-written Pallas (explicit-parallel comparator)."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to

BLOCK_SIZE = 1024


# --- metrics:begin ---
def silu_kernel(x_ref, out_ref, *, block_size):
    pid = pl.program_id(0)
    offs = pid * block_size
    x = x_ref[pl.dslice(offs, block_size)].astype(jnp.float32)
    out = x * jax.nn.sigmoid(x)
    out_ref[pl.dslice(offs, block_size)] = out.astype(out_ref.dtype)


def launch(x, out, block_size=BLOCK_SIZE):
    n = x.shape[0]
    grid = (cdiv(n, block_size),)
    x_p = pad_to(x, (block_size,))
    result = pl.pallas_call(
        functools.partial(silu_kernel, block_size=block_size),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(x_p.shape, out.dtype),
        interpret=True,
    )(x_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, out, BLOCK_SIZE=BLOCK_SIZE):
    return launch(x, out, block_size=BLOCK_SIZE)
