"""Row-wise softmax, hand-written Pallas comparator.

One program per row; the row is padded to the block size with ``-inf``
(exactly the role of ``tl.load(..., other=-float('inf'))`` in the Triton
version) so padded columns contribute ``exp(-inf) == 0``.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to


# --- metrics:begin ---
def softmax_kernel(x_ref, out_ref, *, block_n):
    pid = pl.program_id(0)
    row = x_ref[pl.dslice(pid, 1), pl.dslice(0, block_n)].astype(jnp.float32)
    row = row - jnp.max(row)
    numerator = jnp.exp(row)
    out = numerator / jnp.sum(numerator)
    out_ref[pl.dslice(pid, 1), pl.dslice(0, block_n)] = out.astype(out_ref.dtype)


def launch(x, out):
    m, n = x.shape
    x_p = pad_to(x, (1, 8), value=-math.inf)
    block_n = x_p.shape[1]
    result = pl.pallas_call(
        functools.partial(softmax_kernel, block_n=block_n),
        grid=(m,),
        out_shape=jax.ShapeDtypeStruct(x_p.shape, out.dtype),
        interpret=True,
    )(x_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, out, **_meta):
    return launch(x, out)
