"""2D convolution via implicit GEMM, hand-written Pallas comparator.

This is the explicit version of what paper Listing 8 expresses in six
meta-operations: each program owns a (BLOCK_M, BLOCK_N) tile of the
(N*P*Q, K) output GEMM and performs the pointer arithmetic by hand —
decomposing the GEMM row index into (n, p, q), the GEMM reduction index
into (c, r, s), and combining them into flat input offsets.  The length
and opacity of this kernel relative to the NineToothed version is the
paper's central code-complexity argument.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to

BLOCK_M = 16
BLOCK_N = 16
BLOCK_K = 16


# --- metrics:begin ---
def conv2d_kernel(x_ref, w_ref, out_ref, *, dims, block_m, block_n, block_k):
    n_sz, c_sz, h_sz, w_sz, k_sz, r_sz, s_sz, p_sz, q_sz = dims
    pid_m = pl.program_id(0)
    pid_n = pl.program_id(1)
    gemm_m = n_sz * p_sz * q_sz
    gemm_k = c_sz * r_sz * s_sz

    rows = pid_m * block_m + jnp.arange(block_m)
    cols = pid_n * block_n + jnp.arange(block_n)

    # decompose GEMM row index -> (n, p, q)
    n_idx = rows // (p_sz * q_sz)
    pq = rows % (p_sz * q_sz)
    p_idx = pq // q_sz
    q_idx = pq % q_sz

    x_flat = x_ref[...].reshape(-1)
    w_flat = w_ref[...].reshape(-1)

    acc = jnp.zeros((block_m, block_n), jnp.float32)
    for kb in range(cdiv(gemm_k, block_k)):
        red = kb * block_k + jnp.arange(block_k)
        # decompose GEMM reduction index -> (c, r, s)
        c_idx = red // (r_sz * s_sz)
        rs = red % (r_sz * s_sz)
        r_idx = rs // s_sz
        s_idx = rs % s_sz
        # flat input offsets: x[n, c, p + r, q + s]
        x_offs = (
            n_idx[:, None] * (c_sz * h_sz * w_sz)
            + c_idx[None, :] * (h_sz * w_sz)
            + (p_idx[:, None] + r_idx[None, :]) * w_sz
            + (q_idx[:, None] + s_idx[None, :])
        )
        valid = (rows[:, None] < gemm_m) & (red[None, :] < gemm_k)
        x_offs = jnp.where(valid, x_offs, 0)
        a = jnp.where(valid, x_flat[x_offs.reshape(-1)].reshape(block_m, block_k), 0.0)
        # flat filter offsets: w[k, c, r, s] viewed as (C*R*S, K) via transpose
        w_offs = cols[None, :] * (c_sz * r_sz * s_sz) + red[:, None]
        w_valid = (red[:, None] < gemm_k) & (cols[None, :] < k_sz)
        w_offs = jnp.where(w_valid, w_offs, 0)
        b = jnp.where(w_valid, w_flat[w_offs.reshape(-1)].reshape(block_k, block_n), 0.0)
        acc += jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))

    # scatter the tile into out[n, k, p, q]
    out_offs = (
        n_idx[:, None] * (k_sz * p_sz * q_sz)
        + cols[None, :] * (p_sz * q_sz)
        + p_idx[:, None] * q_sz
        + q_idx[:, None]
    )
    out_valid = (rows[:, None] < gemm_m) & (cols[None, :] < k_sz)
    # invalid lanes get an out-of-range offset and are dropped by the scatter
    out_offs = jnp.where(out_valid, out_offs, jnp.iinfo(jnp.int32).max)
    cur = out_ref[...]
    flat = cur.reshape(-1)
    out_ref[...] = (
        flat.at[out_offs.reshape(-1)]
        .set(acc.astype(cur.dtype).reshape(-1), mode="drop")
        .reshape(cur.shape)
    )


def launch(x, w, out, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    n_sz, c_sz, h_sz, w_sz = x.shape
    k_sz, _, r_sz, s_sz = w.shape
    p_sz, q_sz = h_sz - r_sz + 1, w_sz - s_sz + 1
    dims = (n_sz, c_sz, h_sz, w_sz, k_sz, r_sz, s_sz, p_sz, q_sz)
    grid = (cdiv(n_sz * p_sz * q_sz, block_m), cdiv(k_sz, block_n))
    result = pl.pallas_call(
        functools.partial(
            conv2d_kernel, dims=dims, block_m=block_m, block_n=block_n, block_k=block_k
        ),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((n_sz, k_sz, p_sz, q_sz), out.dtype),
        interpret=True,
    )(x, w)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, w, out, BLOCK_SIZE_M=BLOCK_M, BLOCK_SIZE_N=BLOCK_N, BLOCK_SIZE_K=BLOCK_K):
    return launch(x, w, out, block_m=BLOCK_SIZE_M, block_n=BLOCK_SIZE_N, block_k=BLOCK_SIZE_K)
