"""Vector addition, hand-written Pallas (explicit-parallel comparator).

Structured exactly like the Triton add kernel of paper Listing 1/Table 2:
obtain the program id, compute the block offsets, load, compute, store.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to

BLOCK_SIZE = 1024


# --- metrics:begin ---
def add_kernel(x_ref, y_ref, out_ref, *, block_size):
    pid = pl.program_id(0)
    offs = pid * block_size
    x = x_ref[pl.dslice(offs, block_size)]
    y = y_ref[pl.dslice(offs, block_size)]
    out = x + y
    out_ref[pl.dslice(offs, block_size)] = out


def launch(x, y, out, block_size=BLOCK_SIZE):
    n = x.shape[0]
    grid = (cdiv(n, block_size),)
    x_p = pad_to(x, (block_size,))
    y_p = pad_to(y, (block_size,))
    import functools

    result = pl.pallas_call(
        functools.partial(add_kernel, block_size=block_size),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct(x_p.shape, out.dtype),
        interpret=True,
    )(x_p, y_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(x, y, out, BLOCK_SIZE=BLOCK_SIZE):
    return launch(x, y, out, block_size=BLOCK_SIZE)
