"""Batched matrix multiplication, hand-written Pallas comparator."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from kernels.baseline._common import cdiv, crop_to, pad_to

BLOCK_M = 64
BLOCK_N = 64
BLOCK_K = 64


# --- metrics:begin ---
def bmm_kernel(a_ref, b_ref, c_ref, *, block_m, block_n, block_k):
    pid_b = pl.program_id(0)
    pid_m = pl.program_id(1)
    pid_n = pl.program_id(2)
    offs_m = pid_m * block_m
    offs_n = pid_n * block_n
    k_size = a_ref.shape[2]
    acc = jnp.zeros((block_m, block_n), jnp.float32)
    for k in range(k_size // block_k):
        offs_k = k * block_k
        a = a_ref[pl.dslice(pid_b, 1), pl.dslice(offs_m, block_m), pl.dslice(offs_k, block_k)][0]
        b = b_ref[pl.dslice(pid_b, 1), pl.dslice(offs_k, block_k), pl.dslice(offs_n, block_n)][0]
        acc += jnp.dot(a, b, preferred_element_type=jnp.float32)
    c_ref[pl.dslice(pid_b, 1), pl.dslice(offs_m, block_m), pl.dslice(offs_n, block_n)] = acc[None].astype(c_ref.dtype)


def launch(a, b, out, block_m=BLOCK_M, block_n=BLOCK_N, block_k=BLOCK_K):
    bsz, m, k = a.shape
    _, _, n = b.shape
    grid = (bsz, cdiv(m, block_m), cdiv(n, block_n))
    a_p = pad_to(a, (1, block_m, block_k))
    b_p = pad_to(b, (1, block_k, block_n))
    result = pl.pallas_call(
        functools.partial(bmm_kernel, block_m=block_m, block_n=block_n, block_k=block_k),
        grid=grid,
        out_shape=jax.ShapeDtypeStruct((bsz, a_p.shape[1], b_p.shape[2]), out.dtype),
        interpret=True,
    )(a_p, b_p)
    return crop_to(result, out.shape)
# --- metrics:end ---


def kernel(a, b, out, BLOCK_SIZE_M=BLOCK_M, BLOCK_SIZE_N=BLOCK_N, BLOCK_SIZE_K=BLOCK_K):
    return launch(a, b, out, block_m=BLOCK_SIZE_M, block_n=BLOCK_SIZE_N, block_k=BLOCK_SIZE_K)
