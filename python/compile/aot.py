"""AOT export: lower every kernel task and the model steps to HLO text.

This is the single build-time Python entry point (``make artifacts``).
Python never runs on the request path: the Rust coordinator loads the
emitted ``artifacts/*.hlo.txt`` through the PJRT C API and executes them
directly.

Interchange format is HLO **text**, not serialized HloModuleProto — jax
>= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Emitted:

* ``<kernel>.<variant>.hlo.txt``   — one per Fig 6 task per variant
  (variants: ``nt`` = NineToothed-generated, ``baseline`` = hand-written
  Pallas, ``ref`` = pure jnp / the "PyTorch" series)
* ``model.<step>.<variant>.hlo.txt`` — prefill + decode step per variant
* ``weights.bin``                  — flat little-endian f32 weight blob
* ``golden/*.bin``                 — input/output pairs for Rust runtime
  integration tests
* ``manifest.json``                — everything the Rust side needs:
  argument shapes/dtypes, weight table, model config, Fig 6 task list with
  FLOP estimates, and the full arrangement metadata (levels + index
  expressions) of every NineToothed kernel for the Rust algebra mirror.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

import model as model_mod
from kernels import ref as ref_mod
from kernels.baseline import KERNELS as BASELINE_KERNELS
from kernels.nt import KERNELS as NT_KERNELS

# ---------------------------------------------------------------------------
# Fig 6 task table (paper §5.3.1).  Default shapes are scaled so the whole
# sweep runs in minutes on the CPU-interpret substrate; ``--full`` restores
# the paper's shapes (see DESIGN.md §6).  float32 substitutes float16.
# ---------------------------------------------------------------------------


def task_table(full: bool):
    if full:
        n_vec, mat, bsz = 16777216, 4096, 4
        conv = ((4, 512, 14, 14), (512, 512, 3, 3))
        rope_shape, sdpa_shape = (4, 1024, 48, 64), (4, 48, 1024, 64)
        bmm_shape = (4, 2048, 2048)
    else:
        n_vec, mat, bsz = 65536, 256, 2
        conv = ((2, 64, 14, 14), (64, 64, 3, 3))
        rope_shape, sdpa_shape = (2, 128, 8, 64), (2, 8, 128, 64)
        bmm_shape = (2, 128, 128)

    tasks = {}

    tasks["add"] = dict(
        args=[(n_vec,), (n_vec,)],
        meta=dict(BLOCK_SIZE=1024),
        flops=n_vec,
    )
    tasks["addmm"] = dict(
        args=[(mat, mat), (mat, mat), (mat, mat), (), ()],
        meta=dict(BLOCK_SIZE_M=64, BLOCK_SIZE_N=64, BLOCK_SIZE_K=64),
        flops=2 * mat**3 + 2 * mat**2,
    )
    tasks["bmm"] = dict(
        args=[bmm_shape, bmm_shape],
        meta=dict(BLOCK_SIZE_M=64, BLOCK_SIZE_N=64, BLOCK_SIZE_K=64),
        flops=2 * bmm_shape[0] * bmm_shape[1] ** 3,
    )
    n_, c_, h_, w_ = conv[0]
    k_, _, r_, s_ = conv[1]
    p_, q_ = h_ - r_ + 1, w_ - s_ + 1
    tasks["conv2d"] = dict(
        args=[conv[0], conv[1]],
        meta=dict(BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32),
        flops=2 * n_ * k_ * p_ * q_ * c_ * r_ * s_,
    )
    tasks["mm"] = dict(
        args=[(mat, mat), (mat, mat)],
        meta=dict(BLOCK_SIZE_M=64, BLOCK_SIZE_N=64, BLOCK_SIZE_K=64),
        flops=2 * mat**3,
    )
    tasks["rms_norm"] = dict(args=[(mat, mat)], meta={}, flops=3 * mat * mat)
    s_len, half = rope_shape[1], rope_shape[3] // 2
    tasks["rope"] = dict(
        args=[rope_shape, (s_len, half), (s_len, half)],
        meta={},
        flops=6 * int(np.prod(rope_shape)),
    )
    b_s, h_s, s_s, d_s = sdpa_shape
    tasks["sdpa"] = dict(
        args=[sdpa_shape, sdpa_shape, sdpa_shape],
        meta=dict(BLOCK_SIZE_M=64, BLOCK_SIZE_N=64),
        flops=4 * b_s * h_s * s_s * s_s * d_s,
    )
    tasks["silu"] = dict(args=[(n_vec,)], meta=dict(BLOCK_SIZE=1024), flops=4 * n_vec)
    tasks["softmax"] = dict(args=[(mat, mat)], meta={}, flops=5 * mat * mat)

    for t in tasks.values():
        t["dtype"] = "float32"  # documented float16 -> float32 substitution
    return tasks


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    # Prefer the direct HLO dialect (robust to StableHLO pretty-printer
    # version skew on ops like dynamic_slice); fall back to the stablehlo
    # text round-trip used by /opt/xla-example/gen_hlo.py.
    try:
        return lowered.compiler_ir(dialect="hlo").as_hlo_text()
    except Exception:
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        return comp.as_hlo_text()


def task_callable(name: str, variant: str, shapes, meta):
    """A jit-lowerable function running one Fig 6 task under one variant."""
    if variant == "ref":
        fn = ref_mod.ALL[name]

        def run(*args):
            return (fn(*args),)

        return run

    kernels = NT_KERNELS if variant == "nt" else BASELINE_KERNELS
    kern = kernels[name]

    def run(*args):
        if name == "add":
            out = jnp.empty(args[0].shape, args[0].dtype)
        elif name in ("mm", "addmm"):
            a, b = (args[1], args[2]) if name == "addmm" else (args[0], args[1])
            out = jnp.empty((a.shape[0], b.shape[1]), a.dtype)
        elif name == "bmm":
            out = jnp.empty(
                (args[0].shape[0], args[0].shape[1], args[1].shape[2]), args[0].dtype
            )
        elif name == "conv2d":
            x, f = args
            out = jnp.empty(
                (
                    x.shape[0],
                    f.shape[0],
                    x.shape[2] - f.shape[2] + 1,
                    x.shape[3] - f.shape[3] + 1,
                ),
                x.dtype,
            )
        else:
            out = jnp.empty(args[0].shape, args[0].dtype)
        return (kern(*args, out, **meta),)

    return run


def example_args(shapes, dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(tuple(s), dtype) for s in shapes]


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def export_kernels(out_dir: Path, full: bool, manifest: dict):
    tasks = task_table(full)
    manifest["kernels"] = []
    for name, spec in tasks.items():
        for variant in ("nt", "baseline", "ref"):
            fn = task_callable(name, variant, spec["args"], spec["meta"])
            args = example_args(spec["args"])
            t0 = time.time()
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            path = f"{name}.{variant}.hlo.txt"
            (out_dir / path).write_text(text)
            out_shapes = [
                dict(shape=list(o.shape), dtype=str(o.dtype))
                for o in jax.eval_shape(fn, *args)
            ]
            manifest["kernels"].append(
                dict(
                    name=name,
                    variant=variant,
                    path=path,
                    args=[dict(shape=list(s), dtype="float32") for s in spec["args"]],
                    outputs=out_shapes,
                    meta=spec["meta"],
                    flops=spec["flops"],
                )
            )
            print(f"  {path}: {len(text)} chars in {time.time() - t0:.1f}s")


def export_model(out_dir: Path, full: bool, manifest: dict):
    cfg = (
        model_mod.ModelConfig(max_seq=2112)
        if full
        else model_mod.ModelConfig(max_seq=128)
    )
    batch, prompt = 2, 32
    params = model_mod.init_params(cfg, seed=0)
    names = model_mod.weight_names(cfg)

    # -- weights blob -------------------------------------------------------
    weights_path = out_dir / "weights.bin"
    offset = 0
    table = []
    with open(weights_path, "wb") as f:
        for n in names:
            arr = np.asarray(params[n], np.float32)
            data = arr.tobytes()
            table.append(dict(name=n, shape=list(arr.shape), offset=offset, nbytes=len(data)))
            f.write(data)
            offset += len(data)

    manifest["model"] = dict(
        config=dict(
            vocab_size=cfg.vocab_size,
            d_model=cfg.d_model,
            n_layers=cfg.n_layers,
            n_heads=cfg.n_heads,
            d_ff=cfg.d_ff,
            max_seq=cfg.max_seq,
        ),
        batch=batch,
        prompt=prompt,
        weights_path="weights.bin",
        weights=table,
        steps=[],
    )

    weight_structs = [jax.ShapeDtypeStruct(tuple(params[n].shape), jnp.float32) for n in names]
    cache_struct = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.d_head), jnp.float32
    )

    for variant in ("nt", "baseline", "ref"):
        prefill = model_mod.make_prefill(cfg, variant)
        t0 = time.time()
        lowered = jax.jit(prefill).lower(
            *weight_structs, jax.ShapeDtypeStruct((batch, prompt), jnp.int32)
        )
        path = f"model.prefill.{variant}.hlo.txt"
        (out_dir / path).write_text(to_hlo_text(lowered))
        manifest["model"]["steps"].append(dict(kind="prefill", variant=variant, path=path))
        print(f"  {path} in {time.time() - t0:.1f}s")

        decode = model_mod.make_decode_step(cfg, variant)
        t0 = time.time()
        lowered = jax.jit(decode).lower(
            *weight_structs,
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            cache_struct,
            cache_struct,
        )
        path = f"model.decode.{variant}.hlo.txt"
        (out_dir / path).write_text(to_hlo_text(lowered))
        manifest["model"]["steps"].append(dict(kind="decode", variant=variant, path=path))
        print(f"  {path} in {time.time() - t0:.1f}s")


def export_golden(out_dir: Path, manifest: dict):
    """Golden input/output pairs for the Rust runtime integration tests."""
    golden_dir = out_dir / "golden"
    golden_dir.mkdir(exist_ok=True)
    rng = np.random.default_rng(42)
    manifest["golden"] = []

    # add
    x = rng.standard_normal(65536).astype(np.float32)
    y = rng.standard_normal(65536).astype(np.float32)
    out = np.asarray(ref_mod.add(jnp.asarray(x), jnp.asarray(y)))
    for fname, arr in [("add.x.bin", x), ("add.y.bin", y), ("add.out.bin", out)]:
        (golden_dir / fname).write_bytes(arr.tobytes())
    manifest["golden"].append(
        dict(kernel="add", inputs=["golden/add.x.bin", "golden/add.y.bin"],
             output="golden/add.out.bin", shape=[65536])
    )

    # mm
    a = rng.standard_normal((256, 256)).astype(np.float32)
    b = rng.standard_normal((256, 256)).astype(np.float32)
    out = np.asarray(ref_mod.mm(jnp.asarray(a), jnp.asarray(b)))
    for fname, arr in [("mm.a.bin", a), ("mm.b.bin", b), ("mm.out.bin", out)]:
        (golden_dir / fname).write_bytes(arr.tobytes())
    manifest["golden"].append(
        dict(kernel="mm", inputs=["golden/mm.a.bin", "golden/mm.b.bin"],
             output="golden/mm.out.bin", shape=[256, 256])
    )


def export_arrangements(manifest: dict):
    """Arrangement metadata + evaluation goldens for the Rust algebra mirror."""
    manifest["arrangements"] = []
    samples_rng = np.random.default_rng(11)
    for name, kern in NT_KERNELS.items():
        meta = kern.export_metadata()
        # golden evaluations: sample each parameter's index expressions at
        # random variable bindings so the Rust expression parser/evaluator
        # can be cross-checked bit-for-bit.
        goldens = []
        for param in meta["params"]:
            env = {}
            for level in param["levels"]:
                for dim in level:
                    env[dim["var"]] = int(samples_rng.integers(0, 7))
            for expr in param["indices"]:
                free = _free_names(expr)
                golden = _sample_golden(expr, free, env, samples_rng)
                if golden is not None:
                    golden["param"] = param["name"]
                    goldens.append(golden)
        meta["goldens"] = goldens
        manifest["arrangements"].append(meta)


def _sample_golden(expr: str, free: set[str], env: dict, rng):
    """Sample symbol bindings until the expression evaluates cleanly.

    Size symbols interact (e.g. a conv outer extent ``H - R + 1`` must stay
    positive to serve as a mixed-radix divisor), so rejection-sample.
    """
    import ast as _ast

    from ninetoothed.symbols import Expr

    node = Expr(_ast.parse(expr, mode="eval").body)
    for attempt in range(64):
        full_env = dict(env)
        lo = 8 + attempt  # widen sizes on retries so differences stay positive
        for f in sorted(free):
            if f not in full_env:
                full_env[f] = int(rng.integers(lo, lo + 8))
        try:
            value = int(node.evaluate(full_env))
        except (ZeroDivisionError, ValueError):
            continue
        return dict(expr=expr, env=full_env, value=value)
    return None


def _free_names(expr: str) -> set[str]:
    import ast as _ast

    return {
        n.id
        for n in _ast.walk(_ast.parse(expr, mode="eval"))
        if isinstance(n, _ast.Name) and n.id not in ("cdiv", "min", "max")
    }


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument("--full", action="store_true", help="paper-scale shapes")
    parser.add_argument("--skip-model", action="store_true")
    args = parser.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest: dict = dict(version=1, full=bool(args.full))

    print("exporting kernel tasks ...")
    export_kernels(out_dir, args.full, manifest)
    if not args.skip_model:
        print("exporting model steps ...")
        export_model(out_dir, args.full, manifest)
    print("exporting goldens ...")
    export_golden(out_dir, manifest)
    export_arrangements(manifest)
    print("exporting code metrics (Table 2) ...")
    import metrics as metrics_mod

    manifest["metrics"] = metrics_mod.export_metrics(Path(__file__).parent / "kernels")

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
