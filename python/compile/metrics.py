"""AST-exact code metrics (the Table 2 suite), radon-compatible definitions.

The paper evaluates code complexity with radon's metric families: raw
(LOC/LLOC/SLOC), cyclomatic complexity (G), Halstead (η, N, V, D) and the
maintainability index (MI).  radon is not vendored here, so this module
implements the same definitions over Python's ``ast`` + ``tokenize``:

* **raw** — LOC: physical lines; SLOC: non-blank non-comment lines;
  LLOC: logical lines (one per simple statement).
* **cyclomatic** — per function 1 + decisions (if/elif/for/while/except/
  boolean operators/ternary/comprehension clauses); the reported G is the
  sum over functions, which reproduces the paper's add=2 / mm=3 pattern.
* **Halstead** — AST-based like radon: operators are BinOp/UnaryOp/BoolOp/
  Compare/AugAssign operator occurrences, operands their direct children;
  η = η1+η2, N = N1+N2, V = N log2 η, D = η1/2 · N2/η2.
* **MI** — the SEI/radon formula
  ``max(0, (171 − 5.2 ln V − 0.23 G − 16.2 ln SLOC) · 100 / 171)``.

Run at AOT time (``aot.py`` calls :func:`export_metrics`); the Rust
``codemetrics`` module implements a lexer-level version of the same suite
independently, and the Table 2 harness cross-checks the two.
"""

from __future__ import annotations

import ast
import io
import json
import math
import tokenize
from pathlib import Path

# ---------------------------------------------------------------------------
# raw metrics
# ---------------------------------------------------------------------------


def raw_metrics(source: str) -> dict:
    lines = source.splitlines()
    loc = len(lines)
    sloc = 0
    comment_only = 0
    blank = 0
    for line in lines:
        stripped = line.strip()
        if not stripped:
            blank += 1
        elif stripped.startswith("#"):
            comment_only += 1
        else:
            sloc += 1
    tree = ast.parse(source)
    lloc = sum(1 for node in ast.walk(tree) if isinstance(node, ast.stmt))
    return {"loc": loc, "lloc": lloc, "sloc": sloc, "blank": blank}


# ---------------------------------------------------------------------------
# cyclomatic complexity
# ---------------------------------------------------------------------------


class _CCVisitor(ast.NodeVisitor):
    def __init__(self):
        self.complexity = 1

    def generic_visit(self, node):
        if isinstance(node, (ast.If, ast.For, ast.While, ast.AsyncFor, ast.ExceptHandler, ast.IfExp, ast.Assert)):
            self.complexity += 1
        elif isinstance(node, ast.BoolOp):
            self.complexity += len(node.values) - 1
        elif isinstance(node, ast.comprehension):
            self.complexity += 1 + len(node.ifs)
        super().generic_visit(node)


def cyclomatic(source: str) -> int:
    """Sum over functions of per-function complexity."""
    tree = ast.parse(source)
    total = 0
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            visitor = _CCVisitor()
            for child in ast.iter_child_nodes(node):
                visitor.visit(child)
            total += visitor.complexity
    return total if total else 1


# ---------------------------------------------------------------------------
# Halstead (radon-style AST walk)
# ---------------------------------------------------------------------------


def _operand_name(node) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Constant):
        return repr(node.value)
    if isinstance(node, ast.Attribute):
        return node.attr
    return ast.dump(node)


class _HalsteadVisitor(ast.NodeVisitor):
    def __init__(self):
        self.operators: list[str] = []
        self.operands: list[str] = []

    def _op(self, op) -> str:
        return type(op).__name__

    def visit_BinOp(self, node):
        self.operators.append(self._op(node.op))
        self.operands.append(_operand_name(node.left))
        self.operands.append(_operand_name(node.right))
        self.generic_visit(node)

    def visit_UnaryOp(self, node):
        self.operators.append(self._op(node.op))
        self.operands.append(_operand_name(node.operand))
        self.generic_visit(node)

    def visit_BoolOp(self, node):
        self.operators.append(self._op(node.op))
        self.operands.extend(_operand_name(v) for v in node.values)
        self.generic_visit(node)

    def visit_Compare(self, node):
        self.operators.extend(self._op(op) for op in node.ops)
        self.operands.append(_operand_name(node.left))
        self.operands.extend(_operand_name(c) for c in node.comparators)
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self.operators.append(self._op(node.op))
        self.operands.append(_operand_name(node.target))
        self.operands.append(_operand_name(node.value))
        self.generic_visit(node)


def halstead(source: str) -> dict:
    visitor = _HalsteadVisitor()
    visitor.visit(ast.parse(source))
    n1 = len(set(visitor.operators))
    n2 = len(set(visitor.operands))
    big_n1 = len(visitor.operators)
    big_n2 = len(visitor.operands)
    vocabulary = n1 + n2
    length = big_n1 + big_n2
    volume = length * math.log2(vocabulary) if vocabulary > 1 else float(length)
    difficulty = (n1 / 2) * (big_n2 / n2) if n2 else 0.0
    return {
        "eta1": n1,
        "eta2": n2,
        "N1": big_n1,
        "N2": big_n2,
        "vocabulary": vocabulary,
        "length": length,
        "volume": volume,
        "difficulty": difficulty,
    }


# ---------------------------------------------------------------------------
# maintainability index
# ---------------------------------------------------------------------------


def maintainability_index(volume: float, complexity: int, sloc: int) -> float:
    if sloc <= 0:
        return 100.0
    v = math.log(volume) if volume > 0 else 0.0
    mi = 171.0 - 5.2 * v - 0.23 * complexity - 16.2 * math.log(sloc)
    return max(0.0, mi * 100.0 / 171.0)


def analyze(source: str) -> dict:
    raw = raw_metrics(source)
    g = cyclomatic(source)
    h = halstead(source)
    mi = maintainability_index(h["volume"], g, raw["sloc"])
    return {**raw, "cyclomatic": g, **h, "mi": mi}


# ---------------------------------------------------------------------------
# measured regions
# ---------------------------------------------------------------------------

MARK_BEGIN = "# --- metrics:begin ---"
MARK_END = "# --- metrics:end ---"


def measured_region(path: Path) -> str:
    """The comparable region of a kernel file.

    Baseline files delimit the kernel + launch function with marker
    comments (Triton's role: kernel + grid glue).  NineToothed files are
    measured whole minus imports — the paper's Listing 3 convention
    (tensors + arrangement + application + make).
    """
    text = path.read_text()
    if MARK_BEGIN in text:
        region = text.split(MARK_BEGIN, 1)[1].split(MARK_END, 1)[0]
        return region.strip() + "\n"
    # strip module docstring and imports
    tree = ast.parse(text)
    lines = text.splitlines()
    keep_from = 0
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            keep_from = max(keep_from, node.end_lineno)
        elif (
            isinstance(node, ast.Expr)
            and isinstance(node.value, ast.Constant)
            and isinstance(node.value.value, str)
        ):
            keep_from = max(keep_from, node.end_lineno)
        else:
            break
    return "\n".join(lines[keep_from:]).strip() + "\n"


KERNELS = [
    "add",
    "addmm",
    "bmm",
    "conv2d",
    "mm",
    "rms_norm",
    "rope",
    "sdpa",
    "silu",
    "softmax",
]


def export_metrics(kernels_dir: Path) -> dict:
    """Table 2 rows for every kernel × {nt, baseline}."""
    rows = []
    for name in KERNELS:
        for variant, sub in (("nt", "nt"), ("baseline", "baseline")):
            path = kernels_dir / sub / f"{name}.py"
            region = measured_region(path)
            rows.append({"kernel": name, "variant": variant, **analyze(region)})
    return {"rows": rows}


if __name__ == "__main__":
    import sys

    out = export_metrics(Path(__file__).parent / "kernels")
    json.dump(out, sys.stdout, indent=1)
