"""L2: the end-to-end model of paper §5.3.2, in JAX, built on the kernels.

The paper replaces the Attention / Linear / RMSNorm / SiLU modules of
DeepSeek-R1-Distill-Llama-8B with kernels written in both DSLs and measures
inference throughput.  We substitute a tiny Llama-family model
(RMSNorm + rope + attention-with-bias + SiLU-gated MLP; see DESIGN.md §6)
whose forward pass is assembled from a swappable *kernel backend*:

* ``variant="nt"``        — NineToothed-generated kernels,
* ``variant="baseline"``  — the hand-written Pallas kernels,
* ``variant="ref"``       — pure jnp (the "PyTorch" series of Fig 7).

Only the four module kinds the paper swaps differ between variants; all
glue (embeddings, KV-cache updates, residuals) is shared.  The prefill and
single-token decode steps are lowered to HLO text by ``aot.py`` and driven
from the Rust inference engine.

Attention is causal via an additive score bias (the ``sdpa_bias`` kernel):
at prefill the bias is the lower-triangular 0 / -1e30 matrix; at decode it
masks KV-cache slots beyond the current position, which lets a fixed-shape
AOT artifact serve any position within its cache bucket.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

MASK_VALUE = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-Llama configuration (substitutes the paper's 8B model)."""

    vocab_size: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 512
    max_seq: int = 256
    rope_base: float = 10000.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# weight order is the AOT calling convention — the Rust engine passes the
# flat weight list in exactly this order (see manifest.json "weights").
def weight_names(cfg: ModelConfig) -> list[str]:
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [
            f"layer{i}.wq",
            f"layer{i}.wk",
            f"layer{i}.wv",
            f"layer{i}.wo",
            f"layer{i}.w_gate",
            f"layer{i}.w_up",
            f"layer{i}.w_down",
        ]
    names += ["lm_head"]
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)

    def w(*shape, scale=None):
        scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    params: dict[str, jnp.ndarray] = {"embed": w(cfg.vocab_size, cfg.d_model, scale=0.02)}
    for i in range(cfg.n_layers):
        params[f"layer{i}.wq"] = w(cfg.d_model, cfg.d_model)
        params[f"layer{i}.wk"] = w(cfg.d_model, cfg.d_model)
        params[f"layer{i}.wv"] = w(cfg.d_model, cfg.d_model)
        params[f"layer{i}.wo"] = w(cfg.d_model, cfg.d_model)
        params[f"layer{i}.w_gate"] = w(cfg.d_model, cfg.d_ff)
        params[f"layer{i}.w_up"] = w(cfg.d_model, cfg.d_ff)
        params[f"layer{i}.w_down"] = w(cfg.d_ff, cfg.d_model)
    params["lm_head"] = w(cfg.d_model, cfg.vocab_size, scale=0.02)
    return params


def rope_tables(cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    half = cfg.d_head // 2
    pos = np.arange(cfg.max_seq)[:, None]
    freq = 1.0 / (cfg.rope_base ** (np.arange(half) / half))
    angles = pos * freq
    return (
        jnp.asarray(np.cos(angles), jnp.float32),
        jnp.asarray(np.sin(angles), jnp.float32),
    )


# ---------------------------------------------------------------------------
# kernel backends
# ---------------------------------------------------------------------------


class Backend:
    """The four swappable module kinds of paper §5.3.2 plus rope."""

    def __init__(self, variant: str):
        self.variant = variant
        if variant == "ref":
            from kernels import ref

            self._mm = lambda a, b: ref.mm(a, b)
            self._rms = lambda x: ref.rms_norm(x)
            self._silu = lambda x: ref.silu(x)
            self._rope = lambda x, c, s: ref.rope(x, c, s)
            self._attn = self._ref_attn
        elif variant in ("nt", "baseline"):
            if variant == "nt":
                from kernels.nt import KERNELS
            else:
                from kernels.baseline import KERNELS
            k = KERNELS

            def _mm(a, b):
                out = jnp.empty((a.shape[0], b.shape[1]), a.dtype)
                return k["mm"](a, b, out, BLOCK_SIZE_M=64, BLOCK_SIZE_N=64, BLOCK_SIZE_K=64)

            def _rms(x):
                return k["rms_norm"](x, jnp.empty_like(x))

            def _silu(x):
                return k["silu"](x, jnp.empty_like(x), BLOCK_SIZE=1024)

            def _rope(x, c, s):
                return k["rope"](x, c, s, jnp.empty_like(x))

            def _attn(q, key, value, bias):
                return k["sdpa_bias"](
                    q, key, value, bias, jnp.empty_like(q),
                    BLOCK_SIZE_M=64, BLOCK_SIZE_N=64,
                )

            self._mm, self._rms, self._silu, self._rope, self._attn = (
                _mm, _rms, _silu, _rope, _attn,
            )
        else:
            raise ValueError(f"unknown variant {variant!r}")

    @staticmethod
    def _ref_attn(q, key, value, bias):
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
        scores = jnp.einsum("bhsd,bhtd->bhst", qf, key.astype(jnp.float32)) * scale
        scores = scores + bias[None, None].astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhst,bhtd->bhsd", probs, value.astype(jnp.float32)).astype(q.dtype)

    # module-level ops used by the model ------------------------------------

    def linear(self, x, w):
        """x: (..., d_in) @ w: (d_in, d_out) through the 2D mm kernel."""
        lead = x.shape[:-1]
        flat = x.reshape(-1, x.shape[-1])
        return self._mm(flat, w).reshape(*lead, w.shape[1])

    def rms_norm(self, x):
        lead = x.shape[:-1]
        flat = x.reshape(-1, x.shape[-1])
        return self._rms(flat).reshape(*lead, x.shape[-1])

    def silu(self, x):
        shape = x.shape
        return self._silu(x.reshape(-1)).reshape(shape)

    def rope(self, x, cos, sin):
        return self._rope(x, cos, sin)

    def attention(self, q, k, v, bias):
        return self._attn(q, k, v, bias)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


def _split_heads(x, n_heads):  # (B, S, D) -> (B, H, S, Dh)
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):  # (B, H, S, Dh) -> (B, S, D)
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _project_kv(backend, cfg, params, i, x, cos, sin):
    h = backend.rms_norm(x)
    k = backend.linear(h, params[f"layer{i}.wk"])
    v = backend.linear(h, params[f"layer{i}.wv"])
    k = backend.rope(k.reshape(*k.shape[:2], cfg.n_heads, cfg.d_head), cos, sin)
    k = k.transpose(0, 2, 1, 3)  # (B, H, S, Dh)
    v = _split_heads(v, cfg.n_heads)
    return k, v


def _block(backend, cfg, params, i, x, keys, values, bias, cos, sin):
    """One transformer block over (B, S, D) with explicit K/V tensors."""
    h = backend.rms_norm(x)
    q = backend.linear(h, params[f"layer{i}.wq"])
    q = backend.rope(q.reshape(*q.shape[:2], cfg.n_heads, cfg.d_head), cos, sin)
    q = q.transpose(0, 2, 1, 3)  # (B, H, S, Dh)
    attn = backend.attention(q, keys, values, bias)
    x = x + backend.linear(_merge_heads(attn), params[f"layer{i}.wo"])
    h = backend.rms_norm(x)
    gate = backend.silu(backend.linear(h, params[f"layer{i}.w_gate"]))
    up = backend.linear(h, params[f"layer{i}.w_up"])
    x = x + backend.linear(gate * up, params[f"layer{i}.w_down"])
    return x


def make_prefill(cfg: ModelConfig, variant: str) -> Callable:
    """(weights..., tokens (B,S) i32) -> (logits (B,vocab), cache_k, cache_v).

    Caches are returned padded to ``cfg.max_seq`` so the decode artifact's
    input shapes are fixed.
    """
    backend = Backend(variant)
    cos_t, sin_t = rope_tables(cfg)
    names = weight_names(cfg)

    def prefill(*args):
        weights, tokens = list(args[:-1]), args[-1]
        params = dict(zip(names, weights))
        b, s = tokens.shape
        cos, sin = cos_t[:s], sin_t[:s]
        x = params["embed"][tokens]  # (B, S, D)
        causal = jnp.where(
            jnp.arange(s)[:, None] >= jnp.arange(s)[None, :], 0.0, MASK_VALUE
        ).astype(jnp.float32)
        cache_k = jnp.zeros(
            (cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.d_head), jnp.float32
        )
        cache_v = jnp.zeros_like(cache_k)
        for i in range(cfg.n_layers):
            k, v = _project_kv(backend, cfg, params, i, x, cos, sin)
            cache_k = cache_k.at[i, :, :, :s].set(k)
            cache_v = cache_v.at[i, :, :, :s].set(v)
            x = _block(backend, cfg, params, i, x, k, v, causal, cos, sin)
        x = backend.rms_norm(x)
        logits = backend.linear(x[:, -1], params["lm_head"])  # (B, vocab)
        return logits, cache_k, cache_v

    return prefill


def make_decode_step(cfg: ModelConfig, variant: str) -> Callable:
    """(weights..., token (B,) i32, pos () i32, cache_k, cache_v)
    -> (logits, cache_k, cache_v).

    One autoregressive step against the fixed-size KV cache; slots beyond
    ``pos`` are masked by the additive bias.
    """
    backend = Backend(variant)
    cos_t, sin_t = rope_tables(cfg)
    names = weight_names(cfg)

    def decode(*args):
        weights = list(args[:-4])
        token, pos, cache_k, cache_v = args[-4:]
        params = dict(zip(names, weights))
        x = params["embed"][token][:, None, :]  # (B, 1, D)
        cos = jax.lax.dynamic_slice_in_dim(cos_t, pos, 1, axis=0)
        sin = jax.lax.dynamic_slice_in_dim(sin_t, pos, 1, axis=0)
        positions = jnp.arange(cfg.max_seq)
        bias = jnp.where(positions[None, :] <= pos, 0.0, MASK_VALUE).astype(jnp.float32)
        for i in range(cfg.n_layers):
            k_new, v_new = _project_kv(backend, cfg, params, i, x, cos, sin)
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k_new[None], (i, 0, 0, pos, 0)
            )
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v_new[None], (i, 0, 0, pos, 0)
            )
            x = _block(
                backend, cfg, params, i, x, cache_k[i], cache_v[i], bias, cos, sin
            )
        x = backend.rms_norm(x)
        logits = backend.linear(x[:, -1], params["lm_head"])
        return logits, cache_k, cache_v

    return decode


def greedy_decode(cfg, variant, params, tokens, steps):
    """Reference end-to-end loop used by tests and the Fig 7 oracle."""
    names = weight_names(cfg)
    weights = [params[n] for n in names]
    prefill = make_prefill(cfg, variant)
    decode = make_decode_step(cfg, variant)
    logits, ck, cv = prefill(*weights, tokens)
    out = []
    pos = tokens.shape[1]
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out.append(token)
    for _ in range(steps - 1):
        logits, ck, cv = decode(*weights, token, jnp.int32(pos), ck, cv)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(token)
        pos += 1
    return jnp.stack(out, axis=1)
