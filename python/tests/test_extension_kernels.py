"""Extension kernels (beyond the paper's 10) + ntl language coverage."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

import ninetoothed.language as ntl

RNG = np.random.default_rng(2)


def randn(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("n", [256, 1000])
def test_gelu(n):
    from kernels.nt import gelu

    x = randn(n)
    out = gelu.kernel(x, jnp.empty_like(x), GELU_BLOCK=256)
    expected = jax.nn.gelu(x, approximate=True)
    assert_allclose(out, expected, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,n", [(4, 64), (7, 100)])
def test_layer_norm(m, n):
    from kernels.nt import layer_norm

    x = randn(m, n)
    out = layer_norm.kernel(x, jnp.empty_like(x))
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    expected = (x - mean) / jnp.sqrt(var + 1e-6)
    assert_allclose(out, expected, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ntl language functions (materialization contract)
# ---------------------------------------------------------------------------


class FakeTile:
    """Anything exposing _nt_materialize behaves like a tile proxy."""

    def __init__(self, value):
        self.value = value

    def _nt_materialize(self):
        return self.value


def test_ntl_materializes_proxies():
    x = FakeTile(jnp.asarray([1.0, 4.0, 9.0]))
    assert_allclose(ntl.sqrt(x), [1.0, 2.0, 3.0])
    assert_allclose(ntl.sum(x), 14.0)
    assert_allclose(ntl.max(x), 9.0)
    assert_allclose(ntl.cast(x, jnp.int32), [1, 4, 9])


def test_ntl_dot_accumulates_f32():
    a = FakeTile(jnp.ones((4, 4), jnp.float16))
    b = FakeTile(jnp.ones((4, 4), jnp.float16))
    out = ntl.dot(a, b)
    assert out.dtype == jnp.float32
    assert_allclose(out, 4.0 * jnp.ones((4, 4)))


def test_ntl_trans_where_minimum():
    x = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    assert_allclose(ntl.trans(FakeTile(x)), x.T)
    assert_allclose(ntl.where(x > 2, x, 0.0), [[0, 0], [3, 4]])
    assert_allclose(ntl.minimum(FakeTile(x), 2.0), [[1, 2], [2, 2]])


def test_ntl_shapes_and_fills():
    z = ntl.zeros((2, 3))
    assert z.shape == (2, 3) and float(z.sum()) == 0.0
    f = ntl.full((4,), -1e30)
    assert f.shape == (4,)
    assert np.isclose(float(f[0]), -1e30, rtol=1e-6)
    r = ntl.reshape(FakeTile(jnp.arange(6.0)), (2, 3))
    assert r.shape == (2, 3)
    c = ntl.cat((jnp.ones(2), jnp.zeros(2)))
    assert c.shape == (4,)


def test_ntl_activation_helpers():
    x = jnp.asarray([-1.0, 0.0, 1.0])
    assert_allclose(ntl.sigmoid(FakeTile(x)), jax.nn.sigmoid(x))
    assert_allclose(ntl.silu(FakeTile(x)), x * jax.nn.sigmoid(x))
    assert_allclose(ntl.rsqrt(FakeTile(jnp.asarray([4.0]))), [0.5])
    assert_allclose(ntl.exp2(FakeTile(jnp.asarray([3.0]))), [8.0])
    assert_allclose(ntl.log(FakeTile(jnp.asarray([1.0]))), [0.0])
    assert_allclose(ntl.cos(FakeTile(jnp.asarray([0.0]))), [1.0])
    assert_allclose(ntl.sin(FakeTile(jnp.asarray([0.0]))), [0.0])
