"""End-to-end model equivalence across kernel backends (Fig 7 premise)."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from model import ModelConfig, greedy_decode, init_params, make_decode_step, make_prefill, weight_names

CFG = ModelConfig(vocab_size=128, d_model=64, n_layers=2, n_heads=4, d_ff=128, max_seq=64)
PARAMS = init_params(CFG, seed=7)
TOKENS = jnp.asarray(np.random.default_rng(3).integers(0, CFG.vocab_size, (2, 8)), jnp.int32)


def test_prefill_variants_agree():
    weights = [PARAMS[n] for n in weight_names(CFG)]
    ref_logits, ref_ck, ref_cv = make_prefill(CFG, "ref")(*weights, TOKENS)
    for variant in ("nt", "baseline"):
        logits, ck, cv = make_prefill(CFG, variant)(*weights, TOKENS)
        assert_allclose(logits, ref_logits, rtol=2e-3, atol=2e-3)
        assert_allclose(ck, ref_ck, rtol=2e-3, atol=2e-3)


def test_decode_step_variants_agree():
    weights = [PARAMS[n] for n in weight_names(CFG)]
    _, ck, cv = make_prefill(CFG, "ref")(*weights, TOKENS)
    token = jnp.asarray([5, 9], jnp.int32)
    pos = jnp.int32(TOKENS.shape[1])
    ref_logits, _, _ = make_decode_step(CFG, "ref")(*weights, token, pos, ck, cv)
    for variant in ("nt", "baseline"):
        logits, _, _ = make_decode_step(CFG, variant)(*weights, token, pos, ck, cv)
        assert_allclose(logits, ref_logits, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("variant", ["nt", "baseline"])
def test_greedy_decode_matches_ref(variant):
    """Greedy decode must produce token-identical output across backends."""
    ref_tokens = greedy_decode(CFG, "ref", PARAMS, TOKENS, steps=4)
    got = greedy_decode(CFG, variant, PARAMS, TOKENS, steps=4)
    assert (np.asarray(got) == np.asarray(ref_tokens)).all()


def test_prefill_decode_consistency():
    """Decoding the last prompt token must match including it in prefill."""
    weights = [PARAMS[n] for n in weight_names(CFG)]
    full_logits, _, _ = make_prefill(CFG, "ref")(*weights, TOKENS)
    _, ck, cv = make_prefill(CFG, "ref")(*weights, TOKENS[:, :-1])
    step_logits, _, _ = make_decode_step(CFG, "ref")(
        *weights, TOKENS[:, -1], jnp.int32(TOKENS.shape[1] - 1), ck, cv
    )
    assert_allclose(step_logits, full_logits, rtol=1e-4, atol=1e-4)
