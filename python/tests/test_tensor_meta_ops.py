"""Unit tests for hierarchical tensors and the meta-operations of paper
Table 1.  Each test checks both the *hierarchy* (level shapes) and the
*source-to-target mapping* (index expressions evaluated at sample points).
"""

import pytest

from ninetoothed import Tensor
from ninetoothed.symbols import Symbol


def evaluate_indices(t, env):
    return [int(e.evaluate(env)) for e in t.indices]


def bind(t, level_values):
    """Bind each level's variables to the given index tuples."""
    env = {}
    for level, values in zip(t.levels, level_values):
        for dim, v in zip(level, values):
            env[dim.var] = v
    return env


def test_symbolic_shape_and_strides():
    x = Tensor(2, name="x")
    assert tuple(str(s) for s in x.shape) == ("x_size_0", "x_size_1")
    assert tuple(str(s) for s in x.strides) == ("x_stride_0", "x_stride_1")


def test_tile_default_stride():
    """Paper Algorithm 1: ceil-division outer shape, tile-shape inner."""
    x = Tensor(2, name="x").tile((16, 32))
    assert len(x.levels) == 2
    outer, inner = x.levels
    assert str(outer[0].size) == "cdiv(x_size_0, 16)"
    assert str(inner[0].size) == "16"
    env = bind(x, [(2, 3), (5, 7)])
    env.update({"x_size_0": 100, "x_size_1": 100})
    assert evaluate_indices(x, env) == [2 * 16 + 5, 3 * 32 + 7]


def test_tile_with_stride_is_convolution_window():
    """tile(strides=...) generates overlapping windows (paper §3.1.3)."""
    x = Tensor(1, name="x").tile((3,), strides=(1,))
    outer, inner = x.levels
    # floor((S - 3) / 1) + 1 windows
    assert str(outer[0].size) == "x_size_0 - 3 + 1"
    env = bind(x, [(4,), (2,)])
    env["x_size_0"] = 10
    assert evaluate_indices(x, env) == [4 * 1 + 2]


def test_tile_full_dim():
    x = Tensor(2, name="x").tile((1, -1))
    outer, inner = x.levels
    assert str(outer[1].size) == "1"
    assert str(inner[1].size) == "x_size_1"


def test_tile_rank_mismatch():
    with pytest.raises(ValueError):
        Tensor(2).tile((4,))


def test_expand_broadcasts():
    x = Tensor(2, name="x").tile((4, -1)).expand((-1, 5))
    # wait: dim 1 of the outer level is cdiv(x_size_1, x_size_1) == 1
    outer = x.levels[0]
    assert str(outer[1].size) == "5"
    # the expanded variable must not feed the index expressions
    env = bind(x, [(1, 3), (2, 0)])
    env["x_size_1"] = 7
    idx = evaluate_indices(x, env)
    env2 = bind(x, [(1, 4), (2, 0)])
    env2["x_size_1"] = 7
    assert idx == evaluate_indices(x, env2)


def test_expand_non_singleton_raises():
    # inner-level sizes are concrete, so the violation is caught eagerly
    with pytest.raises(ValueError):
        Tensor(2).tile((4, 4)).dtype.expand((3, -1))


def test_squeeze():
    x = Tensor(2, name="x").tile((1, 16))
    x.dtype = x.dtype.squeeze(0)
    assert len(x.levels[1]) == 1
    assert str(x.levels[1][0].size) == "16"


def test_squeeze_non_singleton_raises():
    with pytest.raises(ValueError):
        Tensor(2).tile((4, 16)).dtype.squeeze(0)


def test_unsqueeze():
    x = Tensor(2, name="x").tile((4, 4)).unsqueeze(0)
    assert len(x.levels[0]) == 3
    assert str(x.levels[0][0].size) == "1"


def test_permute():
    x = Tensor(3, name="x").permute((2, 0, 1))
    assert tuple(str(s) for s in x.shape) == ("x_size_2", "x_size_0", "x_size_1")
    env = bind(x, [(5, 1, 2)])
    # dims reordered but index expressions still map to source dims
    assert evaluate_indices(x, env)[2] == 5  # source dim 2 gets the first index


def test_permute_invalid():
    with pytest.raises(ValueError):
        Tensor(2).permute((0, 0))


def test_flatten_mixed_radix():
    x = Tensor(3, name="x").flatten()
    assert len(x.levels[0]) == 1
    env = bind(x, [(37,)])
    env.update({"x_size_0": 2, "x_size_1": 4, "x_size_2": 5})
    # 37 = 1*20 + 3*5 + 2
    assert evaluate_indices(x, env) == [1, 3, 2]


def test_flatten_range():
    x = Tensor(4, name="x").flatten(start_dim=1, end_dim=3)
    assert len(x.levels[0]) == 3


def test_flatten_end_dim_exclusive():
    """Paper Listing 8: flatten(end_dim=3) merges exactly dims 0..2."""
    x = Tensor(6, name="x").flatten(end_dim=3)
    assert len(x.levels[0]) == 4


def test_ravel_collapses_levels():
    x = Tensor(2, name="x").tile((4, 4))
    r = x.ravel()
    assert len(r.levels) == 1
    assert len(r.levels[0]) == 4


def test_dtype_view_and_assignment():
    x = Tensor(2, name="x").tile((4, 8))
    inner = x.dtype
    assert tuple(str(s) for s in inner.shape) == ("4", "8")
    x.dtype = inner.permute((1, 0))
    assert str(x.levels[1][0].size) == "8"


def test_dtype_of_innermost_is_element_type():
    x = Tensor(2, dtype="float16")
    assert x.dtype == "float16"


def test_conv_arrangement_structure():
    """Walk paper Listing 8's input arrangement and check every step's shape."""
    x = Tensor(4, name="x")
    f = Tensor(4, name="f")
    arranged = x.tile((1, *f.shape[1:]), strides=(-1, -1, 1, 1))
    outer = arranged.levels[0]
    assert str(outer[0].size) == "cdiv(x_size_0, 1)" or str(outer[0].size) == "x_size_0"
    arranged = arranged.squeeze(1)
    assert len(arranged.levels[0]) == 3
    arranged.dtype = arranged.dtype.squeeze(0)
    assert len(arranged.levels[1]) == 3
    arranged = arranged.ravel()
    assert len(arranged.levels) == 1
    assert len(arranged.levels[0]) == 6
    arranged = arranged.flatten(end_dim=3).flatten(start_dim=1)
    assert len(arranged.levels[0]) == 2


def test_scalar_tensor():
    t = Tensor(0, name="beta")
    assert t.source_ndim == 0
    assert t.shape == ()
