"""Auto-tuner behaviour and failure-injection tests for the DSL."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

import ninetoothed
import ninetoothed.language as ntl  # noqa: F401
from ninetoothed import Symbol, Tensor

BLOCK = Symbol("ATB", constexpr=True, default=64)


def _scale_kernel():
    def arrangement(src, dst, ATB=BLOCK):
        return src.tile((ATB,)), dst.tile((ATB,))

    def application(src, dst):
        dst = src * 3.0  # noqa: F841

    return ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))


def test_autotune_picks_a_candidate():
    kern = _scale_kernel()
    x = jnp.asarray(np.arange(2048), jnp.float32)
    best, secs = kern.autotune(
        x, jnp.empty_like(x), candidates={"ATB": [128, 256, 512]}, repeats=1
    )
    assert best["ATB"] in (128, 256, 512)
    assert secs > 0
    # the tuned kernel still computes the right thing
    assert_allclose(kern(x, jnp.empty_like(x), **best), x * 3.0)


def test_autotune_no_viable_candidates():
    kern = _scale_kernel()
    x = jnp.asarray(np.arange(16), jnp.float32)

    # candidate values that break specialization (block size 0 divides)
    with pytest.raises((ValueError, ZeroDivisionError)):
        kern.autotune(x, jnp.empty_like(x), candidates={"ATB": [0]}, repeats=1)


# ---------------------------------------------------------------------------
# failure injection
# ---------------------------------------------------------------------------


def test_arrangement_returning_wrong_arity():
    def arrangement(a, b):
        return (a.tile((8,)),)  # drops b

    def application(a, b):
        b = a  # noqa: F841

    with pytest.raises(ValueError, match="one arranged tensor per parameter"):
        ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))


def test_application_param_count_mismatch():
    def arrangement(a):
        return (a.tile((8,)),)

    def application(a, b):
        b = a  # noqa: F841

    with pytest.raises(ValueError, match="takes 2 tensors"):
        ninetoothed.make(arrangement, application, (Tensor(1),))


def test_outermost_rank_mismatch_rejected_at_make():
    """Rank mismatch is detectable symbolically (paper §3.2.1)."""

    def arrangement(a, b):
        return a.tile((8, 8)), b.tile((8,))

    def application(a, b):
        b = a  # noqa: F841

    with pytest.raises(ValueError, match="mismatched ranks"):
        ninetoothed.make(arrangement, application, (Tensor(2), Tensor(1)))


def test_store_to_scalar_rejected():
    def arrangement(a, out):
        return a, out

    def application(a, out):
        out = a  # noqa: F841

    kern = ninetoothed.make(arrangement, application, (Tensor(0), Tensor(0)))
    with pytest.raises(Exception, match="scalar"):
        kern(jnp.float32(1.0), jnp.float32(0.0))


def test_deferred_singleton_check_fires():
    """conv-style squeeze of cdiv(A, B) must fail when A % B != 0 makes it
    exceed 1 at launch time."""

    def arrangement(x, f, out):
        tiled = x.tile((f.shape[0],))  # cdiv(x, f) tiles
        # deferred: requires cdiv(x_len, f_len) == 1; unsqueeze restores the
        # outer rank so the §3.2.1 rank check passes and the numeric check
        # is what fires
        tiled = tiled.squeeze(0).unsqueeze(0)
        return tiled, f.tile((-1,)), out.tile((-1,))

    def application(x, f, out):
        out = x + f  # noqa: F841

    kern = ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1), Tensor(1)))
    x = jnp.zeros(32, jnp.float32)
    f = jnp.zeros(8, jnp.float32)  # cdiv(32, 8) = 4 != 1
    with pytest.raises(ValueError, match="requires cdiv"):
        kern(x, f, jnp.zeros(8, jnp.float32))


def test_float16_end_to_end():
    kern = _scale_kernel()
    x = jnp.asarray(np.random.default_rng(0).standard_normal(100), jnp.float16)
    out = kern(x, jnp.empty_like(x), ATB=32)
    assert out.dtype == jnp.float16
    assert_allclose(np.asarray(out), np.asarray(x) * 3.0, rtol=1e-2, atol=1e-2)


def test_empty_is_never_materialized_from_output():
    """Outputs are write-only: the kernel must not read the (empty) output
    buffer's contents."""
    kern = _scale_kernel()
    x = jnp.asarray(np.arange(64), jnp.float32)
    poisoned = jnp.full_like(x, jnp.nan)
    out = kern(x, poisoned, ATB=32)
    assert not np.isnan(np.asarray(out)).any()
