"""Hand-written Pallas baselines vs the pure-jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from kernels import ref
from kernels.baseline import KERNELS

RNG = np.random.default_rng(1)


def randn(*shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype=dtype)


TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [64, 1000, 4097])
def test_add(n):
    x, y = randn(n), randn(n)
    out = KERNELS["add"](x, y, jnp.empty_like(x), BLOCK_SIZE=256)
    assert_allclose(out, ref.add(x, y), **TOL)


@pytest.mark.parametrize("n", [64, 1000])
def test_silu(n):
    x = randn(n)
    out = KERNELS["silu"](x, jnp.empty_like(x), BLOCK_SIZE=256)
    assert_allclose(out, ref.silu(x), **TOL)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (70, 50, 90)])
def test_mm(m, k, n):
    a, b = randn(m, k), randn(k, n)
    out = KERNELS["mm"](
        a, b, jnp.empty((m, n), jnp.float32),
        BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32,
    )
    assert_allclose(out, ref.mm(a, b), **TOL)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (70, 50, 90)])
def test_addmm(m, k, n):
    inp, a, b = randn(m, n), randn(m, k), randn(k, n)
    out = KERNELS["addmm"](
        inp, a, b, 0.7, 1.3, jnp.empty((m, n), jnp.float32),
        BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32,
    )
    assert_allclose(out, ref.addmm(inp, a, b, 0.7, 1.3), **TOL)


@pytest.mark.parametrize("b,m,k,n", [(2, 32, 32, 32), (3, 40, 50, 36)])
def test_bmm(b, m, k, n):
    x, y = randn(b, m, k), randn(b, k, n)
    out = KERNELS["bmm"](
        x, y, jnp.empty((b, m, n), jnp.float32),
        BLOCK_SIZE_M=16, BLOCK_SIZE_N=16, BLOCK_SIZE_K=16,
    )
    assert_allclose(out, ref.bmm(x, y), **TOL)


@pytest.mark.parametrize(
    "n,c,h,w,k,r,s", [(2, 3, 10, 10, 4, 3, 3), (1, 2, 8, 9, 3, 3, 2)]
)
def test_conv2d(n, c, h, w, k, r, s):
    x, f = randn(n, c, h, w), randn(k, c, r, s)
    p, q = h - r + 1, w - s + 1
    out = KERNELS["conv2d"](
        x, f, jnp.empty((n, k, p, q), jnp.float32),
        BLOCK_SIZE_M=16, BLOCK_SIZE_N=16, BLOCK_SIZE_K=16,
    )
    assert_allclose(out, ref.conv2d(x, f), **TOL)


@pytest.mark.parametrize("m,n", [(8, 64), (5, 100)])
def test_softmax(m, n):
    x = randn(m, n)
    out = KERNELS["softmax"](x, jnp.empty_like(x))
    assert_allclose(out, ref.softmax(x), **TOL)


@pytest.mark.parametrize("m,n", [(8, 64), (5, 100)])
def test_rms_norm(m, n):
    x = randn(m, n)
    out = KERNELS["rms_norm"](x, jnp.empty_like(x))
    assert_allclose(out, ref.rms_norm(x), **TOL)


@pytest.mark.parametrize("b,s,h,d", [(2, 8, 3, 16), (1, 5, 2, 8)])
def test_rope(b, s, h, d):
    x = randn(b, s, h, d)
    pos = np.arange(s)[:, None]
    freq = 1.0 / (10000 ** (np.arange(d // 2) / (d // 2)))
    cos = jnp.asarray(np.cos(pos * freq), jnp.float32)
    sin = jnp.asarray(np.sin(pos * freq), jnp.float32)
    out = KERNELS["rope"](x, cos, sin, jnp.empty_like(x))
    assert_allclose(out, ref.rope(x, cos, sin), **TOL)


@pytest.mark.parametrize("b,h,s,d", [(1, 2, 64, 16), (2, 3, 128, 32)])
def test_sdpa(b, h, s, d):
    q, k, v = randn(b, h, s, d), randn(b, h, s, d), randn(b, h, s, d)
    out = KERNELS["sdpa"](
        q, k, v, jnp.empty_like(q), BLOCK_SIZE_M=32, BLOCK_SIZE_N=32
    )
    assert_allclose(out, ref.sdpa(q, k, v), **TOL)


def test_nt_and_baseline_agree_mm():
    """The paper's Fig 6 premise: both DSL levels compute the same thing."""
    from kernels.nt import KERNELS as NT

    a, b = randn(96, 96), randn(96, 96)
    out_b = KERNELS["mm"](a, b, jnp.empty((96, 96), jnp.float32),
                          BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32)
    out_n = NT["mm"](a, b, jnp.empty((96, 96), jnp.float32),
                     BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32)
    assert_allclose(out_b, out_n, rtol=1e-5, atol=1e-5)
