"""Unit tests for the AST-backed symbolic expression system (paper §3.1.2)."""

import pytest

from ninetoothed.symbols import Expr, Symbol, fresh_var


def test_symbol_construction():
    s = Symbol("BLOCK_SIZE", constexpr=True)
    assert s.name == "BLOCK_SIZE"
    assert s.constexpr
    assert str(s) == "BLOCK_SIZE"


def test_invalid_symbol_name():
    with pytest.raises(ValueError):
        Symbol("not a name")


def test_arithmetic_builds_trees():
    a, b = Symbol("a"), Symbol("b")
    assert str(a + b) == "a + b"
    assert str(a * b + 1) == "a * b + 1"
    assert str((a - b) // 2) == "(a - b) // 2"
    assert str(a % b) == "a % b"


def test_constant_folding():
    a = Symbol("a")
    assert str(a + 0) == "a"
    assert str(a * 1) == "a"
    assert str(a * 0) == "0"
    assert str(a // 1) == "a"
    assert str(a % 1) == "0"
    assert (Expr(6) * 7).constant() == 42
    assert (Expr(7) // 2).constant() == 3
    assert (Expr(7) % 4).constant() == 3


def test_reverse_operators():
    a = Symbol("a")
    assert str(2 + a) == "2 + a"
    assert str(2 * a) == "2 * a"
    assert str(10 - a) == "10 - a"
    assert str(10 // a) == "10 // a"


def test_cdiv():
    a, b = Symbol("a"), Symbol("b")
    assert str(a.cdiv(b)) == "cdiv(a, b)"
    assert Expr(10).cdiv(3).constant() == 4
    # structural identity
    assert a.cdiv(a).constant() == 1


def test_evaluate():
    a, b = Symbol("a"), Symbol("b")
    e = (a + b) * 2 - a // b
    assert e.evaluate({"a": 7, "b": 3}) == (7 + 3) * 2 - 7 // 3
    assert a.cdiv(b).evaluate({"a": 10, "b": 4}) == 3


def test_substitute():
    a, b, c = Symbol("a"), Symbol("b"), Symbol("c")
    e = a * 4 + b
    sub = e.substitute({"a": c + 1, "b": 0})
    assert sub.evaluate({"c": 2}) == 12
    # substitution refolds: b -> 0 disappears
    assert "b" not in sub.free_symbols()


def test_substitute_is_capture_free():
    a, b = Symbol("a"), Symbol("b")
    e = a + b
    sub = e.substitute({"a": b, "b": 7})  # simultaneous, not sequential
    assert sub.evaluate({"b": 3}) == 10


def test_free_symbols():
    a, b = Symbol("a"), Symbol("b")
    assert (a * b + a).free_symbols() == {"a", "b"}
    assert (a.cdiv(b)).free_symbols() == {"a", "b"}
    assert Expr(5).free_symbols() == set()


def test_bounds_linear():
    a = Symbol("a")
    lo, hi = (a * 3 + 2).bounds({"a": (0, 9)})
    assert (lo, hi) == (2, 29)


def test_bounds_div_mod():
    a = Symbol("a")
    lo, hi = (a // 4).bounds({"a": (0, 10)})
    assert (lo, hi) == (0, 2)
    lo, hi = (a % 4).bounds({"a": (0, 10)})
    assert (lo, hi) == (0, 3)


def test_bounds_tile_pattern():
    """The exact pattern produced by tile(): o * s + i."""
    o, i = Symbol("o"), Symbol("i")
    e = o * 16 + i
    lo, hi = e.bounds({"o": (0, 3), "i": (0, 15)})
    assert (lo, hi) == (0, 63)


def test_bounds_flatten_pattern():
    """The mixed-radix pattern produced by flatten(): (w // q) % s."""
    w = Symbol("w")
    e = (w // 5) % 3
    lo, hi = e.bounds({"w": (0, 74)})
    assert (lo, hi) == (0, 2)


def test_bounds_unknown_symbol_raises():
    a = Symbol("a")
    with pytest.raises(KeyError):
        a.bounds({})


def test_fresh_var_unique():
    names = {fresh_var() for _ in range(100)}
    assert len(names) == 100


def test_expr_equality_and_hash():
    a = Symbol("a")
    assert a + 1 == a + 1
    assert hash(a + 1) == hash(a + 1)
    assert a + 1 != a + 2


def test_int_conversion():
    assert int(Expr(5) + 3) == 8
    with pytest.raises(ValueError):
        int(Symbol("a") + 1)


def test_negative_constants():
    e = Expr(-3)
    assert e.constant() == -3
    assert (e * -2).constant() == 6
