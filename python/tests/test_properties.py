"""Property-based tests (hypothesis): shape/dtype sweeps of the generated
kernels against the jnp oracles, and algebraic invariants of the
meta-operation layer.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from kernels import ref
from kernels.nt import KERNELS
from ninetoothed import Tensor
from ninetoothed.symbols import Expr, Symbol

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# generated kernels vs oracle, arbitrary shapes (pad-and-crop must hold)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 3000),
    block=st.sampled_from([32, 128, 256]),
    dtype=st.sampled_from([jnp.float32, jnp.float16]),
)
def test_add_any_shape(n, block, dtype):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n), dtype)
    y = jnp.asarray(rng.standard_normal(n), dtype)
    out = KERNELS["add"](x, y, jnp.empty_like(x), BLOCK_SIZE=block)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    assert_allclose(np.asarray(out), np.asarray(ref.add(x, y)), rtol=tol, atol=tol)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 150),
    n=st.integers(1, 150),
    block=st.sampled_from([16, 32, 64]),
)
def test_mm_any_shape(m, k, n, block):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    out = KERNELS["mm"](
        a, b, jnp.empty((m, n), jnp.float32),
        BLOCK_SIZE_M=block, BLOCK_SIZE_N=block, BLOCK_SIZE_K=block,
    )
    assert_allclose(out, ref.mm(a, b), rtol=5e-4, atol=5e-4)


@settings(**SETTINGS)
@given(m=st.integers(1, 40), n=st.integers(1, 300))
def test_softmax_any_shape(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    out = KERNELS["softmax"](x, jnp.empty_like(x))
    assert_allclose(out, ref.softmax(x), rtol=2e-5, atol=2e-5)
    # softmax rows sum to 1 — reduction over the padded -inf region must
    # contribute nothing
    assert_allclose(np.asarray(out).sum(axis=-1), np.ones(m), rtol=1e-5)


@settings(**SETTINGS)
@given(m=st.integers(1, 40), n=st.integers(1, 300))
def test_rms_norm_any_shape(m, n):
    rng = np.random.default_rng(m * 1000 + n)
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    out = KERNELS["rms_norm"](x, jnp.empty_like(x))
    assert_allclose(out, ref.rms_norm(x), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# meta-operation invariants
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    size=st.integers(1, 300),
    tile=st.integers(1, 64),
)
def test_tile_index_coverage(size, tile):
    """Every source element of a 1-D tensor is covered by exactly one
    (outer, inner) pair under default-stride tiling — the paper's
    non-overlapping observation."""
    x = Tensor(1, name="x").tile((tile,))
    outer_size = -(-size // tile)
    env_base = {"x_size_0": size}
    seen = {}
    (outer,), (inner,) = x.levels
    expr = x.indices[0]
    for o in range(outer_size):
        for i in range(tile):
            v = int(expr.evaluate({**env_base, outer.var: o, inner.var: i}))
            assert v not in seen, f"element {v} covered twice"
            seen[v] = (o, i)
    covered = set(seen)
    assert set(range(size)).issubset(covered)
    # padding is bounded by one tile
    assert max(covered) < outer_size * tile


@settings(**SETTINGS)
@given(
    s0=st.integers(1, 8),
    s1=st.integers(1, 8),
    s2=st.integers(1, 8),
)
def test_flatten_is_bijection(s0, s1, s2):
    """flatten's mixed-radix decomposition is a bijection onto the box."""
    x = Tensor(3, name="x").flatten()
    env_base = {"x_size_0": s0, "x_size_1": s1, "x_size_2": s2}
    var = x.levels[0][0].var
    seen = set()
    for w in range(s0 * s1 * s2):
        coords = tuple(int(e.evaluate({**env_base, var: w})) for e in x.indices)
        assert coords not in seen
        seen.add(coords)
        assert all(0 <= c < s for c, s in zip(coords, (s0, s1, s2)))
    assert len(seen) == s0 * s1 * s2


@settings(**SETTINGS)
@given(
    perm=st.permutations(range(4)),
)
def test_permute_preserves_index_map(perm):
    """permute reorders dims but never changes where data comes from."""
    x = Tensor(4, name="x")
    p = x.permute(tuple(perm))
    # index expressions are positionally identical per source dim
    before = [str(e) for e in x.indices]
    after = [str(e) for e in p.indices]
    assert before == after


@settings(**SETTINGS)
@given(
    a=st.integers(0, 1000),
    b=st.integers(1, 100),
    c=st.integers(0, 50),
)
def test_expr_eval_matches_python(a, b, c):
    """Symbolic evaluation agrees with direct Python arithmetic."""
    x, y, z = Symbol("x"), Symbol("y"), Symbol("z")
    e = (x + y * 3) // y + (x - z) % y + x.cdiv(y)
    expected = (a + b * 3) // b + (a - c) % b + -(-a // b)
    assert e.evaluate({"x": a, "y": b, "z": c}) == expected


@settings(**SETTINGS)
@given(
    lo=st.integers(0, 50),
    width=st.integers(0, 50),
    mul=st.integers(1, 20),
    add=st.integers(0, 100),
)
def test_bounds_are_sound(lo, width, mul, add):
    """Interval arithmetic never under-approximates (padding soundness)."""
    x = Symbol("x")
    e = (x * mul + add) // 3 % 17
    blo, bhi = e.bounds({"x": (lo, lo + width)})
    for v in range(lo, lo + width + 1):
        val = e.evaluate({"x": v})
        assert blo <= val <= bhi
