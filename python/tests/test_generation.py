"""Unit tests for the code generator (paper §3.2): AST store-rewrite,
tile proxies, specialization caching, fast-path/gather-path agreement,
error handling."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

import ninetoothed
import ninetoothed.language as ntl
from ninetoothed import Symbol, Tensor
from ninetoothed.generation import _transform_application


# ---------------------------------------------------------------------------
# AST rewrite
# ---------------------------------------------------------------------------


def test_transform_detects_outputs():
    def application(a, b, out):
        out = a + b  # noqa: F841

    _, stored, _ = _transform_application(application, ["a", "b", "out"])
    assert stored == {"out"}


def test_transform_augassign_and_subscript():
    def application(a, out):
        out += a  # load-modify-store
        out[0] = a  # subscript store

    _, stored, _ = _transform_application(application, ["a", "out"])
    assert stored == {"out"}


def test_transform_requires_a_store():
    def application(a, b):
        c = a + b  # noqa: F841 — no parameter assignment

    with pytest.raises(ValueError, match="never assigns"):
        _transform_application(application, ["a", "b"])


def test_transform_keeps_local_assignments():
    def application(a, out):
        tmp = a * 2
        out = tmp  # noqa: F841

    code, stored, _ = _transform_application(application, ["a", "out"])
    assert stored == {"out"}
    assert code is not None


# ---------------------------------------------------------------------------
# generated kernels: structural behaviours
# ---------------------------------------------------------------------------


BLOCK = Symbol("TB", constexpr=True, default=64)


def _copy_kernel():
    def arrangement(src, dst, TB=BLOCK):
        return src.tile((TB,)), dst.tile((TB,))

    def application(src, dst):
        dst = src  # noqa: F841

    return ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))


def test_specialization_cache_reused():
    kern = _copy_kernel()
    x = jnp.arange(100, dtype=jnp.float32)
    launch1 = kern.specialize(x, x, TB=32)
    launch2 = kern.specialize(x, x, TB=32)
    assert launch1 is launch2
    launch3 = kern.specialize(x, x, TB=16)
    assert launch3 is not launch1


def test_symbol_default_used_when_not_passed():
    kern = _copy_kernel()
    x = jnp.arange(130, dtype=jnp.float32)
    out = kern(x, jnp.empty_like(x))  # TB defaults to 64
    assert_allclose(out, x)
    assert kern.specialize(x, x).grid == (3,)


def test_missing_symbol_raises():
    nodefault = Symbol("TB_NODEFAULT", constexpr=True)

    def arrangement(src, dst, TB_NODEFAULT=nodefault):
        return src.tile((TB_NODEFAULT,)), dst.tile((TB_NODEFAULT,))

    def application(src, dst):
        dst = src  # noqa: F841

    kern = ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))
    x = jnp.arange(16, dtype=jnp.float32)
    with pytest.raises(ValueError, match="no value for symbol"):
        kern(x, jnp.empty_like(x))


def test_wrong_rank_raises():
    kern = _copy_kernel()
    x = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="expects 1 dims"):
        kern(x, x, TB=4)


def test_fast_path_and_gather_path_agree():
    """The affine fast path (dynamic_slice) must be numerically identical
    to the general gather path on an arrangement both can execute."""
    import ninetoothed.generation as generation

    def arrangement(src, dst, TB=BLOCK):
        return src.tile((TB,)), dst.tile((TB,))

    def application(src, dst):
        dst = src * 2.0  # noqa: F841

    x = jnp.asarray(np.random.default_rng(0).standard_normal(300), jnp.float32)

    kern_fast = ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))
    out_fast = kern_fast(x, jnp.empty_like(x), TB=64)
    launch = kern_fast.specialize(x, x, TB=64)
    assert all(s.fast_plan is not None for s in launch.specs)

    # disable the fast path and re-make
    orig = generation._ParamSpec._plan_fast_path
    generation._ParamSpec._plan_fast_path = lambda self: None
    try:
        kern_slow = ninetoothed.make(arrangement, application, (Tensor(1), Tensor(1)))
        out_slow = kern_slow(x, jnp.empty_like(x), TB=64)
    finally:
        generation._ParamSpec._plan_fast_path = orig
    assert_allclose(out_fast, out_slow)


def test_conv2d_uses_gather_path():
    """Mixed-radix (flattened) index maps cannot use dynamic_slice."""
    from kernels.nt import conv2d as conv_mod

    x = jnp.zeros((1, 2, 8, 8), jnp.float32)
    f = jnp.zeros((3, 2, 3, 3), jnp.float32)
    launch = conv_mod.kernel.specialize(
        x, f, jnp.zeros((1, 3, 6, 6), jnp.float32),
        BLOCK_SIZE_M=16, BLOCK_SIZE_N=16, BLOCK_SIZE_K=16,
    )
    by_name = {s.name: s for s in launch.specs}
    # application params are (input, other, output) — mm.application reused
    assert by_name["input"].fast_plan is None  # ravel+flatten -> gather
    assert by_name["other"].fast_plan is None  # flatten+permute -> gather


def test_mm_uses_fast_path():
    from kernels.nt import mm as mm_mod

    a = jnp.zeros((64, 64), jnp.float32)
    launch = mm_mod.kernel.specialize(
        a, a, a, BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32
    )
    assert all(s.fast_plan is not None for s in launch.specs)


def test_grid_exposed_on_launch():
    from kernels.nt import mm as mm_mod

    a = jnp.zeros((64, 96), jnp.float32)
    b = jnp.zeros((96, 128), jnp.float32)
    launch = mm_mod.kernel.specialize(
        a, b, jnp.zeros((64, 128), jnp.float32),
        BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32,
    )
    assert launch.grid == (2, 4)


def test_metadata_export_shape():
    from kernels.nt import mm as mm_mod

    meta = mm_mod.kernel.export_metadata()
    assert meta["kernel"] == "mm"
    assert [p["name"] for p in meta["params"]] == ["input", "other", "output"]
    for p in meta["params"]:
        assert len(p["indices"]) == p["source_ndim"]
        assert p["levels"], "levels must be exported"


def test_scalar_params_excluded_from_grid():
    from kernels.nt import addmm as addmm_mod

    m = jnp.zeros((64, 64), jnp.float32)
    launch = addmm_mod.kernel.specialize(
        m, m, m, jnp.float32(1.0), jnp.float32(1.0), m,
        BLOCK_SIZE_M=32, BLOCK_SIZE_N=32, BLOCK_SIZE_K=32,
    )
    assert launch.grid == (2, 2)


def test_kernel_composes_under_jit():
    """The generated launch function must be traceable (L2 embeds it)."""
    import jax

    kern = _copy_kernel()

    @jax.jit
    def fn(x):
        return kern(x, jnp.empty_like(x), TB=32) + 1.0

    x = jnp.arange(70, dtype=jnp.float32)
    assert_allclose(fn(x), x + 1.0)
