//! Relative-link checker for the documentation tree — the docs CI job
//! runs `cargo run --release --bin linkcheck` and fails on any broken
//! relative link or unknown `#anchor` in `README.md`, `rust/README.md`
//! or `docs/*.md`.  Std-only, like everything else in the crate.
//!
//! What counts as a link: inline markdown `[text](target)` outside
//! fenced code blocks.  `http(s)://` and `mailto:` targets are skipped
//! (offline CI cannot vouch for the network); everything else must
//! resolve to an existing file or directory relative to the containing
//! document, and a `#fragment` on a markdown target must match a heading
//! in that file under GitHub's slugification rules.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

fn main() {
    // the binary lives in rust/; the documentation tree is one level up
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate has a parent").to_path_buf()
    });

    let mut files = vec![root.join("README.md"), root.join("rust/README.md")];
    let docs = root.join("docs");
    if let Ok(entries) = std::fs::read_dir(&docs) {
        let mut md: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|ext| ext == "md"))
            .collect();
        md.sort();
        files.extend(md);
    }

    let mut slug_cache: HashMap<PathBuf, Vec<String>> = HashMap::new();
    let mut checked = 0usize;
    let mut broken = Vec::new();
    for file in &files {
        let text = match std::fs::read_to_string(file) {
            Ok(text) => text,
            Err(e) => {
                broken.push(format!("{}: unreadable: {e}", file.display()));
                continue;
            }
        };
        let dir = file.parent().unwrap_or(Path::new("."));
        for (line_no, target) in links(&text) {
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
            {
                continue;
            }
            checked += 1;
            let (path_part, anchor) = match target.split_once('#') {
                Some((p, a)) => (p, Some(a)),
                None => (target.as_str(), None),
            };
            // `#anchor` alone refers to the containing document
            let resolved =
                if path_part.is_empty() { file.clone() } else { dir.join(path_part) };
            if !resolved.exists() {
                broken.push(format!(
                    "{}:{line_no}: broken link `{target}` ({} does not exist)",
                    file.display(),
                    resolved.display()
                ));
                continue;
            }
            let Some(anchor) = anchor else { continue };
            if resolved.extension().map(|ext| ext != "md").unwrap_or(true) {
                continue; // anchors into non-markdown files are not ours to judge
            }
            let slugs = slug_cache.entry(resolved.clone()).or_insert_with(|| {
                std::fs::read_to_string(&resolved)
                    .map(|t| heading_slugs(&t))
                    .unwrap_or_default()
            });
            if !slugs.iter().any(|s| s == anchor) {
                broken.push(format!(
                    "{}:{line_no}: broken anchor `{target}` (no heading slugifies to \
                     {anchor:?} in {})",
                    file.display(),
                    resolved.display()
                ));
            }
        }
    }

    println!("linkcheck: {} files, {checked} relative links", files.len());
    if !broken.is_empty() {
        for b in &broken {
            eprintln!("  {b}");
        }
        eprintln!("linkcheck: {} broken link(s)", broken.len());
        std::process::exit(1);
    }
    println!("linkcheck: OK");
}

/// Extract `(line number, target)` for every inline link outside fenced
/// code blocks.
fn links(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for (i, line) in text.lines().enumerate() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let bytes = line.as_bytes();
        let mut j = 0;
        while j + 1 < bytes.len() {
            if bytes[j] == b']' && bytes[j + 1] == b'(' {
                if let Some(end) = line[j + 2..].find(')') {
                    let target = line[j + 2..j + 2 + end].trim();
                    if !target.is_empty() {
                        out.push((i + 1, target.to_string()));
                    }
                    j += 2 + end;
                    continue;
                }
            }
            j += 1;
        }
    }
    out
}

/// GitHub-style anchor slugs for every ATX heading: backticks stripped,
/// lowercased, alphanumerics kept, spaces become hyphens, everything
/// else dropped; duplicate slugs get `-1`, `-2`, ... suffixes.
fn heading_slugs(text: &str) -> Vec<String> {
    let mut counts: HashMap<String, usize> = HashMap::new();
    let mut slugs = Vec::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let hashes = line.len() - line.trim_start_matches('#').len();
        if !(1..=6).contains(&hashes) || !line[hashes..].starts_with(' ') {
            continue;
        }
        let mut slug = String::new();
        for c in line[hashes..].trim().chars() {
            match c {
                '`' => {}
                ' ' => slug.push('-'),
                c if c.is_alphanumeric() => slug.extend(c.to_lowercase()),
                '-' | '_' => slug.push(c),
                _ => {}
            }
        }
        let n = counts.entry(slug.clone()).or_insert(0);
        if *n > 0 {
            slug = format!("{slug}-{n}");
        }
        *n += 1;
        slugs.push(slug);
    }
    slugs
}
