//! CI bench gate: compare `BENCH_native.json` (a fresh
//! `cargo bench --bench native_backend` run) against the committed
//! `BENCH_baseline.json` and fail on throughput regressions.
//!
//! Rows are matched by their `key` field.  For every metric named in
//! [`METRICS`] that appears in both the baseline and the current row, the
//! current value must be at least `baseline * (1 - tolerance)` —
//! tolerance defaults to 25% and can be overridden with
//! `NT_BENCH_TOLERANCE` (e.g. `0.4`).  A baseline row may carry its own
//! `"tolerance"` field, which overrides the global value for every
//! metric in that row (the `obs_overhead_*` row uses `0.05`: the
//! metrics+tracing-enabled path must stay within 5% of bare execution).
//!
//! The committed baseline intentionally holds *conservative floors*
//! (slow-CI-runner safe), not best-machine numbers: its job is to catch
//! collapses — a blocked kernel silently reverting to the naive loop, a
//! scheduler losing its parallel speedup — not single-digit noise.
//! Regenerate it from a trusted machine with `--update`.
//!
//! Usage:
//!   bench_check [--current BENCH_native.json] [--baseline BENCH_baseline.json]
//!               [--update] [--strict]
//!
//! `--update` copies the current report over the baseline and exits.
//! `--strict` also fails when a baseline key is missing from the current
//! run (by default missing keys only warn, so the reduced CI smoke sweep
//! can share a baseline with full local runs).

use std::process::ExitCode;

use ninetoothed_repro::json::Json;

/// Metrics gated as "higher is better" when present in a baseline row.
/// `warm_per_s` is the plan-cache warm-path gate (a >25% regression in
/// warm `prepare` throughput fails CI); `coalesced_per_s` gates the
/// stacked-launch serving path the same way; `resolves_per_s` gates the
/// `kernel::make` registry indirection (hash lookup + Arc clone — the
/// API redesign must stay free on the per-request path);
/// `verifications_per_s` gates the declaration verifier's full four-pass
/// run over the mm declaration (dataflow + shape interpretation + race
/// audit + padding taint) — registration-time work, but it must stay
/// cheap enough that re-verifying on every `register` is never worth
/// skipping.  The
/// `sdpa_*`/`plan_sdpa_*` baseline rows gate the loop-carried
/// flash-attention kernel through the same `gflops_*`/`warm_per_s`
/// metrics — a collapse there means the carried-register loop
/// interpreter or its plan path regressed.
/// `obs_rel_throughput` gates the observability layer itself: it is the
/// bare-execution / observed-execution time ratio on the coalesced
/// serving shape, with a 1.0 baseline and a per-row 5% tolerance — the
/// recording points must stay effectively free.
/// `tuned_rel_throughput` gates the autotuner's election on the
/// `tuned_*` rows: heuristic-plan time over tuned-plan time, pinned to
/// exactly 1.0 when the heuristic itself wins — with a 1.0 baseline and
/// a per-row 5% tolerance, the tuned plan may tie but never lose to the
/// heuristic.  `restart_zero_measurements` gates the warm start on
/// `tune_table_restart`: 1.0 iff a fresh tuner restored every winner
/// from the just-written table without a single timed execution.
/// `eventlog_rel_throughput` gates the flight recorder on the
/// `obs_eventlog_*` row: bare-execution / logged-execution time with an
/// admit event written per request — baseline 1.0, per-row 5% tolerance,
/// so an enabled NDJSON event log may cost at most 5% of serving
/// throughput.
const METRICS: &[&str] = &[
    "gflops",
    "naive_gflops",
    "gflops_serial",
    "gflops_pooled",
    "speedup",
    "warm_per_s",
    "coalesced_per_s",
    "resolves_per_s",
    "verifications_per_s",
    "obs_rel_throughput",
    "eventlog_rel_throughput",
    "tuned_rel_throughput",
    "restart_zero_measurements",
];

fn load(path: &str) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))
}

fn rows(report: &Json) -> Vec<&Json> {
    report
        .get("rows")
        .and_then(|r| r.as_arr())
        .map(|r| r.iter().collect())
        .unwrap_or_default()
}

fn key_of(row: &Json) -> Option<&str> {
    row.get("key").and_then(|k| k.as_str())
}

fn main() -> ExitCode {
    let mut current_path = "BENCH_native.json".to_string();
    let mut baseline_path = "BENCH_baseline.json".to_string();
    let (mut update, mut strict) = (false, false);
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--current" => current_path = args.next().unwrap_or(current_path),
            "--baseline" => baseline_path = args.next().unwrap_or(baseline_path),
            "--update" => update = true,
            "--strict" => strict = true,
            other => {
                eprintln!("unknown argument {other:?}");
                return ExitCode::FAILURE;
            }
        }
    }
    let tolerance: f64 = std::env::var("NT_BENCH_TOLERANCE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);

    if update {
        return match std::fs::copy(&current_path, &baseline_path) {
            Ok(_) => {
                println!("rebaselined: {current_path} -> {baseline_path}");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("rebaseline failed: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let (current, baseline) = match (load(&current_path), load(&baseline_path)) {
        (Ok(c), Ok(b)) => (c, b),
        (c, b) => {
            for err in [c.err(), b.err()].into_iter().flatten() {
                eprintln!("bench_check: {err}");
            }
            return ExitCode::FAILURE;
        }
    };

    let current_rows = rows(&current);
    let mut failures = Vec::new();
    let mut missing = Vec::new();
    let mut checked = 0usize;
    for base_row in rows(&baseline) {
        let Some(key) = key_of(base_row) else { continue };
        let Some(cur_row) = current_rows.iter().find(|r| key_of(r) == Some(key)) else {
            missing.push(key.to_string());
            continue;
        };
        // a baseline row can pin its own tolerance (tighter gates for
        // rows whose metric is a ratio rather than raw throughput)
        let tolerance = base_row
            .get("tolerance")
            .and_then(|v| v.as_f64())
            .unwrap_or(tolerance);
        for metric in METRICS {
            let (Some(base), Some(cur)) = (
                base_row.get(metric).and_then(|v| v.as_f64()),
                cur_row.get(metric).and_then(|v| v.as_f64()),
            ) else {
                continue;
            };
            checked += 1;
            let floor = base * (1.0 - tolerance);
            let verdict = if cur < floor { "FAIL" } else { "ok" };
            println!(
                "{verdict:>4}  {key:<24} {metric:<14} current {cur:>8.2} vs floor {floor:>8.2} \
                 (baseline {base:.2}, tolerance {:.0}%)",
                tolerance * 100.0
            );
            if cur < floor {
                failures.push(format!(
                    "{key}/{metric}: {cur:.2} < {floor:.2} (baseline {base:.2})"
                ));
            }
        }
    }
    for key in &missing {
        println!("warn  {key:<24} missing from {current_path} (reduced sweep?)");
    }

    if checked == 0 {
        eprintln!("bench_check: no overlapping gated metrics between the two reports");
        return ExitCode::FAILURE;
    }
    if !failures.is_empty() || (strict && !missing.is_empty()) {
        eprintln!(
            "bench_check: {} regression(s) beyond the {:.0}% tolerance:",
            failures.len(),
            tolerance * 100.0
        );
        for f in &failures {
            eprintln!("  {f}");
        }
        if strict && !missing.is_empty() {
            eprintln!("  (strict) missing keys: {}", missing.join(", "));
        }
        return ExitCode::FAILURE;
    }
    println!("bench_check: {checked} metric(s) within {:.0}% of baseline", tolerance * 100.0);
    ExitCode::SUCCESS
}
