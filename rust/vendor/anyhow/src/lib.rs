//! Offline, std-only reimplementation of the `anyhow` API surface the
//! ninetoothed-repro crate uses.
//!
//! The offline crate set has no registry access, so this path crate stands
//! in for the real `anyhow`.  It models an error as a flattened context
//! chain (outermost message first): `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined with `": "` — the two formats the
//! codebase relies on.  Downcasting and backtraces are intentionally not
//! supported; nothing in the workspace uses them.

use std::error::Error as StdError;
use std::fmt;

/// A flattened error: context messages from outermost to root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Ad-hoc message error (the `anyhow!` constructor).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `.context(...)` does).
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NB: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "leaf failure")
        }
    }

    impl StdError for Leaf {}

    #[test]
    fn context_chain_formats() {
        let base: Result<(), Leaf> = Err(Leaf);
        let err = base.context("outer").unwrap_err();
        assert_eq!(format!("{err}"), "outer");
        assert_eq!(format!("{err:#}"), "outer: leaf failure");
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3, "math broke");
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "math broke");
    }
}
