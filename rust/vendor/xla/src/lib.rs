//! Offline stub of the `xla` (PJRT C API) binding.
//!
//! The offline container has no PJRT plugin, so this crate replaces the
//! real binding with two kinds of types:
//!
//! * **functional host types** — [`Literal`], [`Shape`], [`ArrayShape`],
//!   [`ElementType`] hold real data and behave exactly like the binding's
//!   host-side containers, so tensor conversion code keeps working;
//! * **uninhabited execution types** — [`PjRtClient`],
//!   [`PjRtLoadedExecutable`], [`PjRtBuffer`], [`HloModuleProto`] cannot be
//!   constructed ([`PjRtClient::cpu`] returns an error), which statically
//!   guarantees no code path pretends to execute an artifact.  The
//!   coordinator detects this and falls back to the native tile-execution
//!   backend (`ninetoothed_repro::exec`).
//!
//! Swapping this path crate for the real `xla` binding (on a machine with
//! a PJRT plugin) re-enables AOT-artifact execution with no source changes.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: every fallible operation reports PJRT unavailability.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline xla stub build); \
         artifact execution requires the real xla binding"
    ))
}

/// The uninhabited core of every execution-side type.
#[derive(Debug, Clone, Copy)]
enum Void {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U32,
    Pred,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn new(dims: Vec<i64>, ty: ElementType) -> ArrayShape {
        ArrayShape { dims, ty }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>().max(0) as usize
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Internal typed storage (public only because [`NativeType`] mentions it).
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
}

/// Host-side literal: a real, functional container (dims + typed data).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Element types [`Literal`] can hold (sealed).
pub trait NativeType: Sized + Copy {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(data: &Data) -> Option<&[Self]>;
    #[doc(hidden)]
    fn element_type() -> ElementType;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> Data {
        Data::F32(data)
    }
    fn unwrap(data: &Data) -> Option<&[f32]> {
        match data {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
    fn element_type() -> ElementType {
        ElementType::F32
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> Data {
        Data::S32(data)
    }
    fn unwrap(data: &Data) -> Option<&[i32]> {
        match data {
            Data::S32(v) => Some(v),
            _ => None,
        }
    }
    fn element_type() -> ElementType {
        ElementType::S32
    }
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { dims: vec![values.len() as i64], data: T::wrap(values.to_vec()) }
    }

    /// Rank-0 (scalar) i32 literal.
    pub fn scalar(value: i32) -> Literal {
        Literal { dims: vec![], data: Data::S32(vec![value]) }
    }

    fn element_type(&self) -> ElementType {
        match &self.data {
            Data::F32(_) => ElementType::F32,
            Data::S32(_) => ElementType::S32,
        }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::S32(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n.max(0) as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?} needs {n} elements, literal has {}",
                self.element_count()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(self.array_shape()?))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape::new(self.dims.clone(), self.element_type()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).map(<[T]>::to_vec).ok_or_else(|| {
            Error(format!(
                "literal holds {:?}, requested {:?}",
                self.element_type(),
                T::element_type()
            ))
        })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("stub literals are never tuples".to_string()))
    }
}

/// PJRT device buffer — uninhabited in the stub.
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.0 {}
    }
}

/// Compiled executable — uninhabited in the stub.
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.0 {}
    }
}

/// PJRT client — uninhabited in the stub; [`PjRtClient::cpu`] always errs.
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.0 {}
    }
}

/// Parsed HLO module — uninhabited in the stub.
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// XLA computation handle — uninhabited in the stub.
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        match proto.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must not construct a client");
        assert!(err.to_string().contains("PJRT runtime unavailable"), "{err}");
    }
}
