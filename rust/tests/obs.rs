//! Observability-layer integration tests: the per-kernel/per-shape
//! metrics registry under concurrency, the coordinator's recording
//! points, Prometheus exposition validity, trace sampling, the SLO
//! admission feedback loop, the flight recorder under concurrency, and
//! the opt-in execution profiler.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig, SubmitError};
use ninetoothed_repro::exec::{lookup, GridScheduler, PlanCache};
use ninetoothed_repro::harness::golden;
use ninetoothed_repro::json::Json;
use ninetoothed_repro::obs::{
    render_waterfall, EventLog, MetricsRegistry, ProfileReport, Span, SpanKind, Trace,
    TraceRecorder,
};
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

/// 8 threads hammer 8 distinct kernels through one shared registry; the
/// per-kernel rows must come out exact, and the merged (bare global)
/// snapshot must equal the sum of the per-kernel snapshots.
#[test]
fn registry_under_concurrent_distinct_kernel_hammering() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 100;
    let reg = Arc::new(MetricsRegistry::new());
    let handles: Vec<_> = (0..THREADS)
        .map(|i| {
            let reg = reg.clone();
            std::thread::spawn(move || {
                let kernel = format!("k{i}");
                for _ in 0..PER_THREAD {
                    // re-resolve every iteration: exercises the read-lock
                    // fast path against concurrent first-insert writers
                    let m = reg.handle(&kernel, "8x8");
                    m.submitted.fetch_add(1, Ordering::Relaxed);
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    m.observe_latency_us(100);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let rows = reg.snapshot();
    assert_eq!(rows.len(), THREADS);
    for row in &rows {
        assert_eq!(row.shapes, "8x8");
        assert_eq!(row.metrics.submitted, PER_THREAD);
        assert_eq!(row.metrics.completed, PER_THREAD);
        assert_eq!(row.metrics.latency_us_sum, PER_THREAD * 100);
        // 100µs lands in bucket [64, 127]; quantiles interpolate
        // log-linearly within it: p50 sits mid-bucket, p99 at the top
        assert_eq!(row.metrics.latency_quantile_us(0.5), 96);
        assert_eq!(row.metrics.latency_quantile_us(0.99), 127);
    }
    // bare global == sum of per-kernel rows
    let merged = reg.merged();
    let total = THREADS as u64 * PER_THREAD;
    assert_eq!(merged.submitted, total);
    assert_eq!(merged.completed, total);
    assert_eq!(merged.latency_us_sum, total * 100);
    assert_eq!(merged.latency_hist.iter().sum::<u64>(), total);
    assert!((merged.mean_latency_us() - 100.0).abs() < 1e-9);
}

/// Drive a mixed burst through the coordinator and check the snapshot:
/// per-kernel rows exist for every burst kernel, the global counters
/// equal the sum over rows, and plan-cache attribution is per kernel.
#[test]
fn coordinator_burst_populates_per_kernel_rows_and_traces() {
    let burst = ["mm", "softmax", "sdpa", "add"];
    let requests = 24;
    let config = CoordinatorConfig { workers: 2, ..Default::default() };
    let coordinator = Coordinator::start(Arc::new(Manifest::builtin()), config).unwrap();
    let mut rng = SplitMix64::new(7);
    // warm one request per kernel first (and wait for it), so the burst
    // below always hits the cached plan even if a whole kernel's worth of
    // requests coalesces into a single batch
    for kernel in burst {
        let inputs = golden::native_task_inputs(kernel, &mut rng).unwrap();
        coordinator.submit(kernel, "nt", inputs).unwrap().recv().unwrap().unwrap();
    }
    let mut receivers = Vec::new();
    for i in 0..requests {
        let kernel = burst[i % burst.len()];
        let inputs = golden::native_task_inputs(kernel, &mut rng).unwrap();
        receivers.push(coordinator.submit(kernel, "nt", inputs).unwrap());
    }
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let total = (requests + burst.len()) as u64;

    let snapshot = coordinator.obs_snapshot();
    for kernel in burst {
        assert!(
            snapshot.kernels.iter().any(|r| r.kernel == kernel),
            "missing per-kernel row for {kernel}"
        );
        // per-kernel plan-cache attribution: each kernel compiled exactly
        // once (fixed golden shapes) and hit the cache afterwards
        let (hits, misses) = snapshot
            .plan_kernels
            .iter()
            .find(|(k, _, _)| k == kernel)
            .map(|&(_, h, m)| (h, m))
            .unwrap_or((0, 0));
        assert_eq!(misses, 1, "{kernel} should compile exactly once");
        assert!(hits >= 1, "{kernel} should hit its cached plan");
    }
    // global == sum of per-kernel rows for every counter recorded on both
    let sum =
        |f: fn(&ninetoothed_repro::coordinator::MetricsSnapshot) -> u64| -> u64 {
            snapshot.kernels.iter().map(|r| f(&r.metrics)).sum()
        };
    assert_eq!(snapshot.global.submitted, total);
    assert_eq!(snapshot.global.submitted, sum(|m| m.submitted));
    assert_eq!(snapshot.global.completed, sum(|m| m.completed));
    assert_eq!(snapshot.global.executions, sum(|m| m.executions));
    assert_eq!(snapshot.global.latency_us_sum, sum(|m| m.latency_us_sum));
    assert_eq!(
        snapshot.global.latency_hist.iter().sum::<u64>(),
        total,
        "every completed request observed exactly once"
    );
    // default NT_TRACE_SAMPLE samples everything: the ring holds traces
    // and the slowest list is sorted descending
    assert!(!snapshot.traces.is_empty(), "traces should be recorded by default");
    for pair in snapshot.traces.windows(2) {
        assert!(pair[0].total_us >= pair[1].total_us);
    }
    let table = snapshot.render_table();
    for kernel in burst {
        assert!(table.contains(kernel), "stats table missing {kernel}:\n{table}");
    }
    coordinator.shutdown();
}

/// `render_prometheus()` must be valid text exposition format: every line
/// is a comment (`# HELP` / `# TYPE`) or a sample `name{labels} value`
/// with a legal metric name and a parseable value, and every sample's
/// family is TYPE-declared before use.
#[test]
fn prometheus_exposition_parses() {
    let config = CoordinatorConfig { workers: 1, ..Default::default() };
    let coordinator = Coordinator::start(Arc::new(Manifest::builtin()), config).unwrap();
    let mut rng = SplitMix64::new(11);
    let mut receivers = Vec::new();
    for kernel in ["softmax", "mm", "softmax"] {
        let inputs = golden::native_task_inputs(kernel, &mut rng).unwrap();
        receivers.push(coordinator.submit(kernel, "nt", inputs).unwrap());
    }
    for rx in receivers {
        rx.recv().unwrap().unwrap();
    }
    let text = coordinator.obs_snapshot().render_prometheus();
    coordinator.shutdown();

    let name_ok = |name: &str| {
        !name.is_empty()
            && name
                .chars()
                .next()
                .map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
                .unwrap_or(false)
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    };
    let mut typed: Vec<String> = Vec::new();
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let keyword = parts.next().unwrap_or("");
            let family = parts.next().unwrap_or("");
            assert!(
                keyword == "HELP" || keyword == "TYPE",
                "unknown comment keyword in: {line}"
            );
            assert!(name_ok(family), "bad family name in: {line}");
            if keyword == "TYPE" {
                let kind = parts.next().unwrap_or("");
                assert!(
                    ["counter", "gauge", "histogram", "summary", "untyped"].contains(&kind),
                    "bad TYPE in: {line}"
                );
                typed.push(family.to_string());
            }
            continue;
        }
        // sample line: name[{labels}] value
        let (series, value) = line.rsplit_once(' ').expect("sample line needs a value");
        let name = match series.split_once('{') {
            Some((name, labels)) => {
                assert!(labels.ends_with('}'), "unbalanced labels in: {line}");
                for pair in labels[..labels.len() - 1].split("\",") {
                    let (k, v) = pair.split_once("=\"").expect("label pair k=\"v\"");
                    assert!(name_ok(k), "bad label name {k:?} in: {line}");
                    assert!(!v.contains('\n'), "raw newline in label value: {line}");
                }
                name
            }
            None => series,
        };
        assert!(name_ok(name), "bad metric name in: {line}");
        assert!(
            value == "+Inf" || value.parse::<f64>().is_ok(),
            "unparseable value {value:?} in: {line}"
        );
        // histogram series suffix back to the declared family name
        let family = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|f| typed.iter().any(|t| t == f))
            .unwrap_or(name);
        assert!(
            typed.iter().any(|t| t == family),
            "sample {name} has no preceding TYPE declaration"
        );
        samples += 1;
    }
    assert!(samples > 10, "expected a real exposition, got {samples} samples");
    assert!(text.contains("nt_requests_total"));
    assert!(text.contains("nt_kernel_requests_total"));
    assert!(text.contains("nt_request_latency_us_bucket"));
}

/// Waterfall rendering edge cases: zero-duration spans still draw a
/// visible bar, an empty trace list renders to nothing, and slowest-N
/// with tied totals returns exactly N rows.
#[test]
fn waterfall_edge_cases() {
    let t = |kernel: &str, total_us: u64, spans: Vec<Span>| Trace {
        kernel: kernel.to_string(),
        shapes: "2x2".to_string(),
        batch_size: 1,
        coalesced: false,
        plan_hit: None,
        total_us,
        trace_id: Some("edge-1".to_string()),
        client_id: Some("acme".to_string()),
        spans,
    };
    assert_eq!(render_waterfall(&[]), "", "no traces, no output");

    // a zero-duration span must still render a visible bar
    let zero = t(
        "add",
        50,
        vec![
            Span { kind: SpanKind::Queued, start_us: 0, end_us: 0 },
            Span { kind: SpanKind::Execute, start_us: 0, end_us: 50 },
        ],
    );
    let out = render_waterfall(&[zero]);
    for line in out.lines().skip(1) {
        assert!(line.contains('#'), "span row without a bar: {line:?}");
    }
    // the header carries the wire identity fields
    assert!(out.contains("client=acme"), "{out}");
    assert!(out.contains("trace=edge-1"), "{out}");

    // net spans render under their wire names
    let wire = t(
        "mm",
        100,
        vec![
            Span { kind: SpanKind::NetRead, start_us: 0, end_us: 10 },
            Span { kind: SpanKind::Execute, start_us: 10, end_us: 90 },
            Span { kind: SpanKind::NetWrite, start_us: 90, end_us: 100 },
        ],
    );
    let out = render_waterfall(&[wire]);
    assert!(out.contains("net_read"), "{out}");
    assert!(out.contains("net_write"), "{out}");

    // slowest-N with ties: still exactly N, all with the tied total
    let rec = TraceRecorder::new(1, 8);
    for _ in 0..5 {
        rec.record(t("softmax", 200, vec![]));
    }
    let slow = rec.slowest(3);
    assert_eq!(slow.len(), 3);
    assert!(slow.iter().all(|s| s.total_us == 200));
}

/// 8 threads hammer one flight recorder through rotations: every line in
/// both generations must parse as a complete JSON object — one torn or
/// interleaved write fails the test.
#[test]
fn event_log_rotation_survives_concurrent_hammering() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 64;
    let path = std::env::temp_dir().join(format!("nt_obs_hammer_{}.ndjson", std::process::id()));
    let rotated = ninetoothed_repro::obs::events::rotated_path(&path);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&rotated);

    // a tight cap so the hammer crosses several rotations
    let log = Arc::new(EventLog::to_file(path.clone(), 2048, None).unwrap());
    let handles: Vec<_> = (0..THREADS)
        .map(|i| {
            let log = log.clone();
            std::thread::spawn(move || {
                let client = format!("tenant_{i}");
                for j in 0..PER_THREAD {
                    log.admit("softmax", "8x256", Some(&client));
                    if j % 16 == 0 {
                        log.plan_compile("softmax", "8x256");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let mut lines = 0usize;
    for file in [&rotated, &path] {
        let Ok(text) = std::fs::read_to_string(file) else { continue };
        assert!(text.ends_with('\n') || text.is_empty(), "{}: torn tail", file.display());
        for line in text.lines() {
            let parsed = Json::parse(line)
                .unwrap_or_else(|e| panic!("unparseable event line {line:?}: {e}"));
            assert!(matches!(parsed, Json::Obj(_)), "non-object event: {line}");
            let kind = parsed.get("event").and_then(Json::as_str).unwrap();
            assert!(["admit", "plan_compile"].contains(&kind), "unexpected event {kind}");
            lines += 1;
        }
    }
    assert!(lines > 0, "the hammer must leave events behind");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&rotated);
}

/// The SLO feedback loop end to end: completions that blow an
/// unsatisfiable objective flip the engine to burning, which (a) halves
/// the effective shed watermark, (b) tags sheds with the objective, and
/// (c) exports burn-rate gauges in the Prometheus exposition.
#[test]
fn slo_burn_lowers_watermark_and_exports_burn_rate() {
    let config = CoordinatorConfig {
        workers: 1,
        queue_capacity: 4,
        // every real request violates p99 < 1µs, so the budget burns as
        // soon as one evaluation window sees a completion
        slo: Some("p99<1us".to_string()),
        slo_window_ms: 1,
        ..Default::default()
    };
    let coordinator = Coordinator::start(Arc::new(Manifest::builtin()), config).unwrap();
    assert_eq!(
        coordinator.effective_watermark_now(),
        (4, None),
        "no completions yet: the configured watermark holds"
    );

    let mut rng = SplitMix64::new(19);
    for _ in 0..8 {
        let inputs = golden::native_task_inputs("mm", &mut rng).unwrap();
        coordinator.submit("mm", "nt", inputs).unwrap().recv().unwrap().unwrap();
    }
    // let the 1ms window elapse, then evaluate via the snapshot path
    std::thread::sleep(std::time::Duration::from_millis(10));
    let snapshot = coordinator.obs_snapshot();
    let (watermark, objective) = coordinator.effective_watermark_now();
    assert_eq!(watermark, 2, "burning SLO must halve the watermark");
    assert_eq!(objective.as_deref(), Some("p99<1us"));
    let status = snapshot.slo.iter().find(|s| s.objective == "p99<1us").unwrap();
    assert!(status.burning, "{status:?}");
    assert!(status.burn_rate > 1.0, "{status:?}");
    assert!(status.window_violations > 0, "{status:?}");

    let prom = snapshot.render_prometheus();
    for series in [
        "nt_slo_burn_rate{objective=\"p99<1us\"}",
        "nt_slo_burning{objective=\"p99<1us\"} 1",
    ] {
        assert!(prom.contains(series), "missing {series} in:\n{prom}");
    }

    // overload against the lowered watermark: park the single worker on
    // a large matmul, then flood — the shed must carry the objective
    let big = vec![
        HostTensor::randn(vec![128, 128], &mut rng),
        HostTensor::randn(vec![128, 128], &mut rng),
    ];
    let mut receivers = vec![coordinator.submit("mm", "nt", big).unwrap()];
    let mut shed = None;
    for _ in 0..20 {
        let inputs = golden::native_task_inputs("softmax", &mut rng).unwrap();
        match coordinator.submit_admit("softmax", "nt", inputs) {
            Ok(rx) => receivers.push(rx),
            Err(SubmitError::Overloaded { watermark, slo_objective, .. }) => {
                shed = Some((watermark, slo_objective));
                break;
            }
            Err(SubmitError::Invalid(e)) => panic!("unexpected invalid: {e:#}"),
        }
    }
    let (shed_watermark, shed_objective) = shed.expect("flooding a 1-worker queue must shed");
    assert_eq!(shed_watermark, 2, "shed at the lowered watermark");
    assert_eq!(shed_objective.as_deref(), Some("p99<1us"));
    for rx in receivers {
        let _ = rx.recv();
    }
    coordinator.shutdown();
}

/// The sampling knob keeps every k-th request; the ring drops the oldest.
#[test]
fn trace_recorder_samples_and_caps() {
    let rec = TraceRecorder::new(4, 16);
    let sampled = (0..16).filter(|_| rec.should_sample()).count();
    assert_eq!(sampled, 4, "every 4th of 16 requests");
    assert_eq!(rec.sample_interval(), 4);
}

/// Opt-in profiler: executing a cached program with an enabled report
/// accumulates per-instruction and per-cell wall time.
#[test]
fn profiler_accumulates_instruction_and_cell_time() {
    let cache = PlanCache::new(4);
    let softmax = lookup("softmax").unwrap();
    let mut rng = SplitMix64::new(3);
    let inputs = golden::native_task_inputs("softmax", &mut rng).unwrap();
    let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
    let compiled = cache.prepare(&softmax, "nt", &shapes).unwrap();
    let report = ProfileReport::enabled();
    let sched = GridScheduler::serial();
    let out = compiled.execute_profiled(&inputs, &sched, &report).unwrap();
    assert_eq!(out.len(), 1);
    let snap = report.snapshot("softmax 7x301");
    assert!(snap.cells > 0, "cells must be counted");
    assert!(snap.cell_ns_total > 0);
    assert!(snap.cell_ns_max > 0);
    assert!(!snap.instrs.is_empty(), "instruction kinds must be profiled");
    assert!(
        snap.instrs.iter().any(|s| s.kind == "load"),
        "softmax loads its input tile: {:?}",
        snap.instrs
    );
    assert!(snap.instrs.iter().all(|s| s.count > 0));
    let rendered = snap.render();
    assert!(rendered.contains("softmax 7x301"), "{rendered}");

    // a disabled report attached by default must record nothing
    let off = ProfileReport::from_env();
    if !off.is_enabled() {
        compiled.execute_profiled(&inputs, &sched, &off).unwrap();
        assert_eq!(off.snapshot("off").cells, 0);
    }
}
