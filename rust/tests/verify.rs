//! Acceptance for the declaration verifier (`kernel::verify`).
//!
//! Three contracts:
//!
//! * **negative corpus** — every deliberately broken declaration in
//!   `kernel::verify::corpus` fires *exactly* its intended `NT-V*` code:
//!   one diagnostic family, no cascades, no cross-talk between analyses;
//! * **clean builtins** — every registered kernel verifies with zero
//!   findings (warnings included), so `repro lint --all` ships clean;
//! * **race-audit agreement** — the independent coalescibility audit
//!   reproduces the derived `coalesce` flag for every executable
//!   builtin, and registration rejects a seeded unsound declaration
//!   (the `coalesce` flag tampered to `true` on a row-mixing program).

use ninetoothed_repro::exec::{Instr, TileProgram, UnaryOp};
use ninetoothed_repro::kernel::verify::{corpus, race_audit, verify, Code, Severity};
use ninetoothed_repro::kernel::{
    self, dim, make, AppBuilder, Arrangement, KernelRegistry, Meta, TensorSpec,
};
use ninetoothed_repro::{arrange::catalog, exec::ReduceOp};

fn elementwise_arrangement() -> Arrangement {
    Arrangement::new("1-D element-wise", |_| catalog::elementwise_1d(&["input", "output"]))
        .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" })
}

fn elementwise_tensors(probe: i64) -> Vec<TensorSpec> {
    vec![
        TensorSpec::input("input", vec![dim("n", probe)]),
        TensorSpec::output("output", vec![dim("n", probe)]),
    ]
}

/// Every corpus declaration fires exactly its intended code — the single
/// distinct code equals the expectation, and *every* diagnostic carries
/// it (an analysis cascading into a second code family is a bug here).
#[test]
fn corpus_cases_fire_exactly_their_code() {
    let cases = corpus::cases().unwrap();
    assert_eq!(cases.len(), 13, "one corpus case per NT-V* code");
    for case in &cases {
        assert!(
            !case.report.diagnostics.is_empty(),
            "{}: expected {} to fire, report is clean",
            case.name,
            case.expected.as_str()
        );
        assert_eq!(
            case.report.codes(),
            vec![case.expected],
            "{}: expected exactly {}, got:\n{}",
            case.name,
            case.expected.as_str(),
            case.report.render()
        );
    }
    // the corpus covers every code once, in order
    let expected: Vec<Code> = cases.iter().map(|c| c.expected).collect();
    let mut sorted = expected.clone();
    sorted.sort();
    sorted.dedup();
    assert_eq!(sorted, expected, "corpus is one case per code, in code order");
}

/// NT-V004 regression (the latent asymmetry this verifier closes): a
/// carry the body never assigns, read after the loop, is reported — the
/// old `TileProgram::validate` accepted it silently.
#[test]
fn never_assigned_carry_read_after_loop_is_reported() {
    let cases = corpus::cases().unwrap();
    let case = cases.iter().find(|c| c.expected == Code::CarryNeverAssigned).unwrap();
    let diag = &case.report.diagnostics[0];
    assert_eq!(diag.severity, Severity::Warning);
    assert!(
        diag.message.contains("no body instruction assigns it"),
        "message should explain the loop cannot change the carry: {}",
        diag.message
    );
    // ...and the same declaration still passes the old structural
    // validation, proving the verifier sees strictly more
    let program = &case.report;
    assert_eq!(program.kernel, "corpus_v004");
}

/// Every registered kernel declaration verifies completely clean —
/// errors *and* warnings — so `repro lint --all` has nothing to report.
#[test]
fn builtins_verify_clean() {
    let defs = kernel::kernels();
    assert!(defs.len() >= 10, "registry should hold the builtin catalog");
    for def in &defs {
        let report = verify(def);
        assert!(
            report.is_clean(),
            "builtin {} has verifier findings:\n{}",
            def.name,
            report.render()
        );
    }
}

/// The race audit independently reproduces the derived coalesce verdict
/// for every executable builtin (and abstains exactly on the
/// non-executable conv2d declaration).
#[test]
fn race_audit_agrees_with_derived_coalesce() {
    for def in kernel::kernels() {
        if def.executable() {
            assert_eq!(
                race_audit(&def),
                Some(def.coalesce),
                "race audit disagrees with derived coalesce for {}",
                def.name
            );
        } else {
            assert_eq!(race_audit(&def), None, "{} has no probe views to audit", def.name);
        }
    }
}

/// Seeded unsound declaration: tamper the pub `coalesce` field to `true`
/// on a row-mixing (block-wide reduction) kernel.  `make` derived it
/// `false`; registration must re-verify and reject with NT-V012.
#[test]
fn registration_rejects_tampered_coalesce() {
    let mut app = AppBuilder::new("tampered");
    let x = app.load(0);
    let m = app.reduce(x, None, ReduceOp::Max);
    let y = app.binary(x, m, ninetoothed_repro::exec::BinOp::Sub);
    app.store(1, y);
    let mut def = make(elementwise_arrangement(), app.build(), elementwise_tensors(8)).unwrap();
    assert!(!def.coalesce, "a block-wide reduction must not derive as coalescible");
    def.coalesce = true;
    let err = KernelRegistry::new().register(def).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("NT-V012"), "rejection should cite the race audit: {msg}");
}

/// `make` hard-errors on definite violations, citing the stable code.
#[test]
fn make_rejects_use_before_def_with_code() {
    let program = TileProgram {
        name: "broken",
        regs: 2,
        instrs: vec![
            Instr::Unary { dst: 1, a: 0, op: UnaryOp::Exp },
            Instr::Store { param: 1, src: 1 },
        ],
    };
    let err = make(elementwise_arrangement(), program, elementwise_tensors(8)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fails declaration verification"), "{msg}");
    assert!(msg.contains("NT-V001"), "{msg}");
}

/// Warnings do not block `make` (the declaration runs — it is just
/// suspicious), but they do appear in the report, so lint still fails.
#[test]
fn warnings_pass_make_but_dirty_the_report() {
    // unmasked padding: max-reduce over a padded (n=1000 -> block 1024)
    // load with pad 0 — NT-V013, a warning
    let mut app = AppBuilder::new("pad_warn");
    let x = app.load(0);
    let m = app.reduce(x, None, ReduceOp::Max);
    let y = app.binary(x, m, ninetoothed_repro::exec::BinOp::Sub);
    app.store(1, y);
    let def = make(elementwise_arrangement(), app.build(), elementwise_tensors(1000))
        .expect("warning-severity findings must not block make");
    let report = verify(&def);
    assert!(!report.is_clean() && !report.has_errors());
    assert_eq!(report.codes(), vec![Code::UnmaskedPadding]);
    assert_eq!(report.diagnostics[0].severity, Severity::Warning);
}

/// The stable string forms are a public contract (tests, docs and CI
/// grep for them) — pin every one.
#[test]
fn diagnostic_codes_are_stable() {
    let all = [
        (Code::UseBeforeDef, "NT-V001"),
        (Code::CarryUninitialized, "NT-V002"),
        (Code::UndeclaredCarry, "NT-V003"),
        (Code::CarryNeverAssigned, "NT-V004"),
        (Code::DeadRegister, "NT-V005"),
        (Code::DeadStore, "NT-V006"),
        (Code::RankMismatch, "NT-V007"),
        (Code::DotDimMismatch, "NT-V008"),
        (Code::ShapeMismatch, "NT-V009"),
        (Code::AxisOutOfBounds, "NT-V010"),
        (Code::OddSplit, "NT-V011"),
        (Code::CoalesceUnsound, "NT-V012"),
        (Code::UnmaskedPadding, "NT-V013"),
    ];
    for (code, s) in all {
        assert_eq!(code.as_str(), s);
        assert_eq!(format!("{code}"), s);
    }
}

/// Diagnostics carry instruction-level spans: loop-body findings point
/// into the body (`#outer.inner`), top-level findings at the top.
#[test]
fn spans_are_instruction_level() {
    let cases = corpus::cases().unwrap();
    let v3 = cases.iter().find(|c| c.expected == Code::UndeclaredCarry).unwrap();
    let span = v3.report.diagnostics[0].span.expect("dataflow findings have spans");
    assert_eq!((span.outer, span.inner), (1, Some(0)), "the write is in the loop body");
    assert_eq!(format!("{span}"), "#1.0");
    let v1 = cases.iter().find(|c| c.expected == Code::UseBeforeDef).unwrap();
    let span = v1.report.diagnostics[0].span.unwrap();
    assert_eq!((span.outer, span.inner), (0, None));
    assert_eq!(format!("{span}"), "#0");
}
