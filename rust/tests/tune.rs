//! Autotuner acceptance (ported from the seed's
//! `python/tests/test_autotune_and_failures.py` scenarios, plus the
//! tuning-table durability matrix):
//!
//! * tuned output bit-identical to the default-config output for every
//!   tunable builtin (mm, softmax, sdpa, add);
//! * a candidate that fails to compile is skipped, not fatal — and an
//!   all-bogus candidate space is a clean error, never a panic;
//! * `NT_TUNE=off` (TuneMode::Off) is byte-for-byte the status quo;
//! * corrupt / stale-version / candidate-space-mismatched tables are
//!   ignored with a warning;
//! * concurrent first-use tuning of one key elects exactly one winner;
//! * a restart against a persisted table performs zero re-measurements
//!   and its first `prepare` compiles straight to the winner;
//! * `Meta::AttentionBlocks` clamps the block to the head dim
//!   (regression at head_dim 1).

use std::path::PathBuf;
use std::sync::Arc;

use ninetoothed_repro::exec::{self, compile, GridScheduler, PlanCache, TuneMode, TuneTable, Tuner};
use ninetoothed_repro::harness::golden;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::HostTensor;

/// Per-test scratch path (no tempfile crate in the offline set).
fn tmp_table(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("nt_tune_test_{}_{name}.json", std::process::id()))
}

fn shapes_of(inputs: &[HostTensor]) -> Vec<&[usize]> {
    inputs.iter().map(|t| t.shape.as_slice()).collect()
}

/// The acceptance mix: every tunable builtin the `repro stats` burst
/// serves.  Tuned serving must be **bit-identical** to the heuristic —
/// candidate spaces never vary accumulation-order symbols, and the
/// search skips any candidate whose output differs from candidate 0's.
#[test]
fn tuned_output_is_bit_identical_to_default() {
    let scheduler = GridScheduler::default();
    for kernel_name in ["mm", "softmax", "sdpa", "add"] {
        let mut rng = SplitMix64::new(42);
        let inputs = golden::native_task_inputs(kernel_name, &mut rng).unwrap();
        let kernel = exec::lookup(kernel_name).unwrap();
        let shapes = shapes_of(&inputs);
        let default_out = compile(&kernel, &shapes).unwrap().execute(&inputs, &scheduler).unwrap();

        let plans = Arc::new(PlanCache::new(64));
        let tuner = Tuner::new(TuneMode::FirstUse, None, plans.clone());
        tuner.maybe_tune(&kernel, "nt", &inputs, &scheduler).unwrap();
        let prepared = plans.prepare(&kernel, "nt", &shapes).unwrap();
        let tuned_out = prepared.execute(&inputs, &scheduler).unwrap();

        assert_eq!(default_out.len(), tuned_out.len());
        for (d, t) in default_out.iter().zip(&tuned_out) {
            assert_eq!(d, t, "{kernel_name}: tuned output must equal the default output");
        }
    }
}

/// A candidate that cannot compile (here: empty meta, leaving the mm
/// block symbols unbound) is skipped; candidate 0 failing is a clean
/// error because the heuristic is the guaranteed fallback.
#[test]
fn failing_candidate_is_skipped_not_fatal() {
    let kernel = exec::lookup("mm").unwrap();
    let mut rng = SplitMix64::new(7);
    let inputs = golden::native_task_inputs("mm", &mut rng).unwrap();
    let shapes = shapes_of(&inputs);
    let heuristic = kernel.meta_candidates(&shapes).unwrap()[0].clone();
    let bogus: Vec<(String, i64)> = Vec::new();

    let plans = Arc::new(PlanCache::new(8));
    let tuner = Tuner::new(TuneMode::FirstUse, None, plans);
    let outcome = tuner
        .tune_with_candidates(
            &kernel,
            "nt",
            &inputs,
            &[heuristic, bogus.clone()],
            &GridScheduler::serial(),
        )
        .unwrap();
    assert_eq!(outcome.winner_index, 0, "only the heuristic survived");
    assert_eq!(outcome.skipped, 1);

    let all_bogus =
        tuner.tune_with_candidates(&kernel, "nt", &inputs, &[bogus], &GridScheduler::serial());
    assert!(all_bogus.is_err(), "heuristic candidate failing must be a clean error");
}

/// `TuneMode::Off` performs no measurements, installs no winners, and
/// the cache compiles the plain heuristic plan — byte-for-byte the
/// pre-tuner behaviour.
#[test]
fn off_mode_is_the_status_quo() {
    let kernel = exec::lookup("mm").unwrap();
    let mut rng = SplitMix64::new(11);
    let inputs = golden::native_task_inputs("mm", &mut rng).unwrap();
    let shapes = shapes_of(&inputs);

    let plans = Arc::new(PlanCache::new(8));
    let tuner = Tuner::new(TuneMode::Off, None, plans.clone());
    let outcome = tuner.maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial()).unwrap();
    assert!(outcome.is_none());
    assert_eq!(tuner.measurements(), 0);
    assert_eq!(plans.tuned_plans(), 0);

    let prepared = plans.prepare(&kernel, "nt", &shapes).unwrap();
    assert!(prepared.meta.is_none(), "off mode must serve the heuristic plan");
    let default_out =
        compile(&kernel, &shapes).unwrap().execute(&inputs, &GridScheduler::serial()).unwrap();
    let served = prepared.execute(&inputs, &GridScheduler::serial()).unwrap();
    assert_eq!(default_out, served);
}

/// Corrupt and stale-version tables load as empty (with a warning on
/// stderr), and a tuner pointed at one starts clean — never a panic.
#[test]
fn corrupt_and_stale_tables_are_ignored() {
    let path = tmp_table("corrupt");
    std::fs::write(&path, "{definitely not json").unwrap();
    assert!(TuneTable::load(&path).entries.is_empty());

    std::fs::write(&path, "{\"version\":999,\"entries\":[]}").unwrap();
    assert!(TuneTable::load(&path).entries.is_empty());

    std::fs::write(&path, "][").unwrap();
    let plans = Arc::new(PlanCache::new(8));
    let tuner = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans);
    assert_eq!(tuner.restore(), 0);
    std::fs::remove_file(&path).ok();
}

/// A table whose candidate-space hash no longer matches (the heuristic
/// changed since it was written) is ignored on restore — the key
/// re-tunes at first use instead of serving a stale winner.
#[test]
fn candidate_space_mismatch_is_ignored_on_restore() {
    let path = tmp_table("mismatch");
    let kernel = exec::lookup("mm").unwrap();
    let mut rng = SplitMix64::new(13);
    let inputs = golden::native_task_inputs("mm", &mut rng).unwrap();
    let shapes = shapes_of(&inputs);

    let plans = Arc::new(PlanCache::new(8));
    let tuner = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans);
    tuner
        .maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial())
        .unwrap()
        .expect("first use must search");

    let mut table = TuneTable::load(&path);
    assert_eq!(table.entries.len(), 1);
    table.entries[0].space_hash ^= 1;
    table.save(&path).unwrap();

    let plans2 = Arc::new(PlanCache::new(8));
    let tuner2 = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans2.clone());
    assert_eq!(tuner2.restore(), 0, "mismatched space hash must not restore");
    assert!(plans2.winner("mm", "nt", &shapes).is_none());
    std::fs::remove_file(&path).ok();
}

/// 8 threads race first-use tuning of the same (kernel, shapes) key:
/// exactly one searches and installs the winner, the rest find it
/// installed and skip.
#[test]
fn concurrent_first_use_elects_one_winner() {
    let kernel = exec::lookup("mm").unwrap();
    let mut rng = SplitMix64::new(17);
    let inputs = Arc::new(golden::native_task_inputs("mm", &mut rng).unwrap());
    let plans = Arc::new(PlanCache::new(8));
    let tuner = Arc::new(Tuner::new(TuneMode::FirstUse, None, plans.clone()));

    let handles: Vec<_> = (0..8)
        .map(|_| {
            let (tuner, kernel, inputs) = (tuner.clone(), kernel.clone(), inputs.clone());
            std::thread::spawn(move || {
                tuner
                    .maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial())
                    .unwrap()
                    .is_some()
            })
        })
        .collect();
    let searched: usize = handles.into_iter().map(|h| h.join().unwrap() as usize).sum();
    assert_eq!(searched, 1, "exactly one thread may search");
    assert_eq!(tuner.tuned_plans(), 1);
    assert_eq!(plans.tuned_plans(), 1);
}

/// The warm-restart guarantee the CI smoke step gates on: a new process
/// pointed at a persisted table restores every winner lazily, performs
/// **zero** tuning measurements, and its first `prepare` compiles
/// straight to the winner's block bindings.
#[test]
fn restart_with_table_does_zero_measurements() {
    let path = tmp_table("restart");
    std::fs::remove_file(&path).ok();
    let kernels = ["mm", "add", "sdpa"];

    // "process 1": tune and persist
    let plans1 = Arc::new(PlanCache::new(16));
    let tuner1 = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans1);
    let mut rng = SplitMix64::new(23);
    for kernel_name in kernels {
        let inputs = golden::native_task_inputs(kernel_name, &mut rng).unwrap();
        let kernel = exec::lookup(kernel_name).unwrap();
        tuner1
            .maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial())
            .unwrap()
            .expect("first use must search");
    }
    assert!(tuner1.measurements() > 0);

    // "process 2": restore and serve — same shapes, fresh everything
    let plans2 = Arc::new(PlanCache::new(16));
    let tuner2 = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans2.clone());
    assert_eq!(tuner2.restore(), kernels.len());
    let mut rng = SplitMix64::new(23);
    for kernel_name in kernels {
        let inputs = golden::native_task_inputs(kernel_name, &mut rng).unwrap();
        let kernel = exec::lookup(kernel_name).unwrap();
        let outcome = tuner2.maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial()).unwrap();
        assert!(outcome.is_none(), "{kernel_name}: restored key must not re-search");
    }
    assert_eq!(tuner2.measurements(), 0, "restart against a table re-measures nothing");
    assert_eq!(tuner2.restored(), kernels.len() as u64);

    // first prepare compiles with the restored winner, not the heuristic
    let mut rng = SplitMix64::new(23);
    let inputs = golden::native_task_inputs("mm", &mut rng).unwrap();
    let shapes = shapes_of(&inputs);
    let kernel = exec::lookup("mm").unwrap();
    let winner = plans2.winner("mm", "nt", &shapes).expect("restored winner");
    let prepared = plans2.prepare(&kernel, "nt", &shapes).unwrap();
    assert_eq!(prepared.meta.as_ref(), Some(&*winner));
    std::fs::remove_file(&path).ok();
}

/// Regression (satellite 1): `Meta::AttentionBlocks` clamps its block to
/// the head-dim probe as well as seq.  At head_dim 1 the old seq-only
/// heuristic allocated a 64x64 score tile for 64x1 operand tiles.
#[test]
fn attention_blocks_clamp_to_head_dim() {
    let kernel = exec::lookup("sdpa").unwrap();
    let block_of = |shapes: &[&[usize]], sym: &str| -> i64 {
        kernel.meta_candidates(shapes).unwrap()[0].iter().find(|(k, _)| k == sym).unwrap().1
    };

    // head_dim 1, seq 64: clamp to 16 (the floor), not the seq-derived 64
    let degenerate: Vec<Vec<usize>> = vec![vec![1, 1, 64, 1]; 3];
    let shapes: Vec<&[usize]> = degenerate.iter().map(|s| s.as_slice()).collect();
    assert_eq!(block_of(&shapes, "BLOCK_SIZE_M"), 16);
    assert_eq!(block_of(&shapes, "BLOCK_SIZE_N"), 16);

    // realistic heads are unaffected: head 16 keeps the seq-derived 64
    let realistic: Vec<Vec<usize>> = vec![vec![2, 2, 100, 16]; 3];
    let shapes: Vec<&[usize]> = realistic.iter().map(|s| s.as_slice()).collect();
    assert_eq!(block_of(&shapes, "BLOCK_SIZE_M"), 64);

    // and the clamped plan is numerically right vs the naive oracle
    let mut rng = SplitMix64::new(3);
    let inputs: Vec<HostTensor> =
        (0..3).map(|_| HostTensor::randn(vec![1, 1, 64, 1], &mut rng)).collect();
    let shapes = shapes_of(&inputs);
    let out =
        compile(&kernel, &shapes).unwrap().execute(&inputs, &GridScheduler::serial()).unwrap();
    let expected = exec::reference::sdpa(&inputs[0], &inputs[1], &inputs[2]).unwrap();
    assert!(out[0].max_abs_diff(&expected).unwrap() <= 1e-3);
}

/// Exhaustive mode re-searches keys a restored table already answered
/// (its whole point is a fresh full sweep).
#[test]
fn exhaustive_mode_retunes_restored_keys() {
    let path = tmp_table("exhaustive");
    std::fs::remove_file(&path).ok();
    let kernel = exec::lookup("add").unwrap();
    let mut rng = SplitMix64::new(29);
    let inputs = golden::native_task_inputs("add", &mut rng).unwrap();

    let plans1 = Arc::new(PlanCache::new(8));
    let tuner1 = Tuner::new(TuneMode::FirstUse, Some(path.clone()), plans1);
    tuner1
        .maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial())
        .unwrap()
        .expect("first use must search");

    let plans2 = Arc::new(PlanCache::new(8));
    let tuner2 = Tuner::new(TuneMode::Exhaustive, Some(path.clone()), plans2);
    assert_eq!(tuner2.restore(), 1);
    let outcome = tuner2.maybe_tune(&kernel, "nt", &inputs, &GridScheduler::serial()).unwrap();
    assert!(outcome.is_some(), "exhaustive mode re-searches restored keys");
    assert!(tuner2.measurements() > 0);
    std::fs::remove_file(&path).ok();
}
