//! Wire-protocol integration tests: real TCP sockets against
//! [`ninetoothed_repro::coordinator::net::Server`].
//!
//! Covers the acceptance contract of the serving front door:
//! * results over the wire are **bit-identical** to in-process execution,
//! * flooding a queue-capacity-2 server yields structured `overloaded`
//!   replies (never hangs) and the shed count lands in the obs snapshot,
//! * frame/protocol violations get clean error replies with the documented
//!   connection policy (garbage JSON survives; framing violations close),
//! * a `trace_id`/`client_id`-tagged submit rides end to end: the reply
//!   echoes a span breakdown, the server-side trace carries both net spans
//!   and the identity fields, and the flight recorder logs it as NDJSON,
//! * every replayable example in `docs/wire-protocol.md` is replayed
//!   byte-for-byte (modulo the documented timing fields).

use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use ninetoothed_repro::coordinator::net::frame::{read_frame, write_frame, FrameError};
use ninetoothed_repro::coordinator::net::{Client, NetConfig, Server};
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::json::Json;
use ninetoothed_repro::obs::{render_waterfall, SpanKind};
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()))
}

fn start_server(config: CoordinatorConfig) -> (Arc<Coordinator>, Server) {
    let coordinator = Arc::new(Coordinator::start(manifest(), config).unwrap());
    let server = Server::start(coordinator.clone(), NetConfig::default()).unwrap();
    (coordinator, server)
}

/// The mixed burst of the acceptance criteria: add, mm, softmax and sdpa,
/// three rounds each, deterministic inputs.
fn burst_inputs() -> Vec<(&'static str, Vec<HostTensor>)> {
    let mut rng = SplitMix64::new(0xbeef);
    let mut requests = Vec::new();
    for _ in 0..3 {
        requests.push((
            "add",
            vec![
                HostTensor::randn(vec![1000], &mut rng),
                HostTensor::randn(vec![1000], &mut rng),
            ],
        ));
        requests.push((
            "mm",
            vec![
                HostTensor::randn(vec![70, 50], &mut rng),
                HostTensor::randn(vec![50, 90], &mut rng),
            ],
        ));
        requests.push(("softmax", vec![HostTensor::randn(vec![7, 301], &mut rng)]));
        requests.push((
            "sdpa",
            vec![
                HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
            ],
        ));
    }
    requests
}

fn bits(t: &HostTensor) -> Vec<u32> {
    t.as_f32().unwrap().iter().map(|x| x.to_bits()).collect()
}

#[test]
fn tcp_burst_is_bit_identical_to_in_process() {
    let requests = burst_inputs();

    // in-process reference: same inputs straight into a coordinator
    let local = Coordinator::start(manifest(), CoordinatorConfig::default()).unwrap();
    let mut expected = Vec::new();
    for (kernel, inputs) in &requests {
        let rx = local.submit(kernel, "nt", inputs.clone()).unwrap();
        expected.push(rx.recv().unwrap().unwrap().outputs);
    }
    local.shutdown();

    // the same burst over the wire, against a fresh server
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    for ((kernel, inputs), expect) in requests.iter().zip(&expected) {
        let reply = client.submit(kernel, "nt", inputs).unwrap();
        assert_eq!(reply.outputs.len(), expect.len(), "{kernel}: output count");
        for (got, want) in reply.outputs.iter().zip(expect) {
            assert_eq!(got.shape, want.shape, "{kernel}: output shape");
            assert_eq!(bits(got), bits(want), "{kernel}: outputs must be bit-identical");
        }
    }
    let stats = client.stats_json().unwrap();
    assert_eq!(
        stats.req("global").unwrap().usize("completed").unwrap(),
        requests.len(),
        "server must have completed the whole burst"
    );
    server.shutdown();
    coordinator.drain();
}

#[test]
fn flooding_a_small_queue_sheds_cleanly() {
    // one slow worker, a two-deep queue: concurrent clients must overrun
    // the watermark and receive structured overloaded replies, not hangs
    let (coordinator, server) = start_server(CoordinatorConfig {
        workers: 1,
        queue_capacity: 2,
        ..Default::default()
    });
    let addr = server.local_addr().to_string();

    const CLIENTS: usize = 8;
    const ROUNDS: usize = 12;
    let mut handles = Vec::new();
    for seed in 0..CLIENTS {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> (u64, u64) {
            let mut rng = SplitMix64::new(1000 + seed as u64);
            let mut client = Client::connect(&addr).unwrap();
            let (mut ok, mut shed) = (0u64, 0u64);
            for _ in 0..ROUNDS {
                let a = HostTensor::randn(vec![128, 128], &mut rng);
                let b = HostTensor::randn(vec![128, 128], &mut rng);
                let reply = client.submit_raw("mm", "nt", &[a, b]).unwrap();
                if reply.get("ok").and_then(Json::as_bool) == Some(true) {
                    ok += 1;
                } else {
                    let err = reply.req("error").unwrap();
                    assert_eq!(
                        err.str("code").unwrap(),
                        "overloaded",
                        "only load shedding may fail this burst: {reply}"
                    );
                    assert!(
                        err.usize("retry_after_ms").unwrap() >= 1,
                        "shed replies must carry a retry hint: {reply}"
                    );
                    assert_eq!(
                        err.str("reason").unwrap(),
                        "queue_full",
                        "no SLO is configured, so sheds must be plain queue_full: {reply}"
                    );
                    shed += 1;
                }
            }
            (ok, shed)
        }));
    }
    let (mut ok_total, mut shed_total) = (0u64, 0u64);
    for handle in handles {
        let (ok, shed) = handle.join().unwrap();
        ok_total += ok;
        shed_total += shed;
    }
    assert_eq!(ok_total + shed_total, (CLIENTS * ROUNDS) as u64, "no request may hang");
    assert!(shed_total > 0, "8 concurrent clients against queue depth 2 must shed");

    // the shed count surfaces in the metrics and the obs snapshot
    let metrics = coordinator.metrics();
    assert_eq!(metrics.shed, shed_total);
    assert_eq!(metrics.completed, ok_total);
    let snapshot = coordinator.obs_snapshot();
    assert_eq!(
        snapshot.to_json().req("global").unwrap().usize("shed").unwrap(),
        shed_total as usize
    );
    assert!(
        snapshot.render_prometheus().contains(&format!(
            "nt_requests_total{{event=\"shed\"}} {shed_total}"
        )),
        "shed must appear in the Prometheus exposition"
    );
    server.shutdown();
    coordinator.drain();
}

#[test]
fn garbage_json_gets_error_reply_and_connection_survives() {
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();

    // a well-formed frame with unparseable JSON: clean error, stay open
    write_frame(&mut stream, "this is not json").unwrap();
    let reply = Json::parse(&read_frame(&mut stream, 1 << 20).unwrap()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "bad_request");

    // valid JSON that is not an object: same code, connection still fine
    write_frame(&mut stream, "[1,2]").unwrap();
    let reply = Json::parse(&read_frame(&mut stream, 1 << 20).unwrap()).unwrap();
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "bad_request");

    // the connection survived both: a health request still answers
    write_frame(&mut stream, r#"{"id":1,"op":"health"}"#).unwrap();
    let reply = Json::parse(&read_frame(&mut stream, 1 << 20).unwrap()).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(reply.str("status").unwrap(), "ok");

    server.shutdown();
    coordinator.drain();
}

#[test]
fn oversized_length_prefix_gets_bad_frame_then_close() {
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // a hostile 4 GiB length prefix: bad_frame reply, then the server
    // closes (the stream cannot be resynchronized)
    use std::io::Write;
    stream.write_all(&u32::MAX.to_be_bytes()).unwrap();
    stream.flush().unwrap();
    let reply = Json::parse(&read_frame(&mut stream, 1 << 20).unwrap()).unwrap();
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "bad_frame");
    assert!(
        matches!(read_frame(&mut stream, 1 << 20), Err(FrameError::Closed)),
        "server must close after a framing violation"
    );
    server.shutdown();
    coordinator.drain();
}

#[test]
fn truncated_frame_gets_bad_frame_then_close() {
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

    // declare 100 payload bytes, deliver 3, hang up the write side
    use std::io::Write;
    stream.write_all(&100u32.to_be_bytes()).unwrap();
    stream.write_all(b"abc").unwrap();
    stream.flush().unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let reply = Json::parse(&read_frame(&mut stream, 1 << 20).unwrap()).unwrap();
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "bad_frame");
    assert!(matches!(read_frame(&mut stream, 1 << 20), Err(FrameError::Closed)));
    server.shutdown();
    coordinator.drain();
}

#[test]
fn submit_errors_carry_protocol_codes() {
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

    // a request the router can never serve: invalid_argument
    let t = HostTensor::f32(vec![1], vec![1.0]).unwrap();
    let reply = client.submit_raw("no_such_kernel", "nt", &[t]).unwrap();
    assert_eq!(reply.get("ok").and_then(Json::as_bool), Some(false));
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "invalid_argument");

    // an op that does not exist: unknown_op, id echoed
    let raw = client.call_raw(r#"{"id":77,"op":"frobnicate"}"#).unwrap();
    let reply = Json::parse(&raw).unwrap();
    assert_eq!(reply.usize("id").unwrap(), 77);
    assert_eq!(reply.req("error").unwrap().str("code").unwrap(), "unknown_op");

    // the rejection was counted as such (not shed)
    assert_eq!(coordinator.metrics().rejected, 1);
    assert_eq!(coordinator.metrics().shed, 0);
    server.shutdown();
    coordinator.drain();
}

#[test]
fn traced_submit_rides_end_to_end_into_waterfall_and_event_log() {
    // the acceptance path of the observability plane in one round trip: a
    // trace_id-tagged TCP submit must (1) echo a span breakdown in the
    // reply, (2) land in the trace ring with both net spans and the tenant
    // identity, and (3) be captured by the flight recorder as a
    // slow_request event (NT_SLOW_US=1 makes every request "slow")
    let log_path =
        std::env::temp_dir().join(format!("nt_net_events_{}.ndjson", std::process::id()));
    let _ = std::fs::remove_file(&log_path);
    let _ = std::fs::remove_file(ninetoothed_repro::obs::events::rotated_path(&log_path));
    let (coordinator, server) = start_server(CoordinatorConfig {
        event_log: Some(log_path.clone()),
        slow_us: Some(1),
        ..Default::default()
    });
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    client.set_client_id("acme");
    let mut rng = SplitMix64::new(5);
    let x = HostTensor::randn(vec![7, 301], &mut rng);
    let reply = client.submit_traced("softmax", "nt", &[x], Some("trace-e2e-1")).unwrap();

    // (1) the echoed breakdown: id round-trips, net_read leads, spans
    // telescope inside the server's own total
    let breakdown = reply.trace.expect("wire submits must return a span breakdown");
    assert_eq!(breakdown.trace_id.as_deref(), Some("trace-e2e-1"));
    assert_eq!(
        breakdown.spans.first().map(|(kind, _)| kind.as_str()),
        Some("net_read"),
        "breakdown must start with the net_read span: {:?}",
        breakdown.spans
    );
    let span_sum: u64 = breakdown.spans.iter().map(|(_, us)| us).sum();
    assert!(
        span_sum <= breakdown.total_us,
        "span sum {span_sum}µs exceeds the server total {}µs",
        breakdown.total_us
    );

    // the reply write happens before the trace is recorded server-side;
    // joining the connection threads makes the recording visible
    drop(client);
    server.shutdown();

    // (2) the server-side trace: identity fields, both net spans, rendered
    let traces = coordinator.obs().traces.recent();
    let trace = traces
        .iter()
        .find(|t| t.trace_id.as_deref() == Some("trace-e2e-1"))
        .expect("the traced submit must land in the trace ring");
    assert_eq!(trace.client_id.as_deref(), Some("acme"));
    assert_eq!(trace.kernel, "softmax");
    assert!(trace.spans.iter().any(|s| matches!(s.kind, SpanKind::NetRead)));
    assert!(
        matches!(trace.spans.last().map(|s| s.kind), Some(SpanKind::NetWrite)),
        "net_write must be the final span: {:?}",
        trace.spans
    );
    let waterfall = render_waterfall(std::slice::from_ref(trace));
    for marker in ["trace=trace-e2e-1", "client=acme", "net_read", "net_write"] {
        assert!(waterfall.contains(marker), "waterfall missing {marker:?}:\n{waterfall}");
    }

    // the per-tenant metrics row exists alongside the trace
    let snapshot = coordinator.obs_snapshot();
    assert!(
        snapshot.kernels.iter().any(|row| row.kernel == "softmax" && row.client == "acme"),
        "expected a (softmax, acme) metrics row"
    );
    coordinator.drain();

    // (3) the flight recorder: a parseable slow_request NDJSON line with
    // the trace identity and the span array
    let text = std::fs::read_to_string(&log_path).expect("the event log must exist");
    let event = text
        .lines()
        .filter(|line| !line.trim().is_empty())
        .map(|line| Json::parse(line).unwrap_or_else(|e| panic!("bad NDJSON {line:?}: {e}")))
        .find(|e| {
            e.get("event").and_then(Json::as_str) == Some("slow_request")
                && e.get("trace_id").and_then(Json::as_str) == Some("trace-e2e-1")
        })
        .expect("the traced submit must be recorded as a slow_request event");
    assert_eq!(event.get("client_id").and_then(Json::as_str), Some("acme"));
    assert_eq!(event.get("kernel").and_then(Json::as_str), Some("softmax"));
    assert!(
        matches!(event.get("spans"), Some(Json::Arr(spans)) if !spans.is_empty()),
        "slow_request must carry the span array: {event}"
    );
    let _ = std::fs::remove_file(&log_path);
    let _ = std::fs::remove_file(ninetoothed_repro::obs::events::rotated_path(&log_path));
}

// ---------------------------------------------------------------------------
// docs/wire-protocol.md replay
// ---------------------------------------------------------------------------

/// Extract the replayable `request`/`reply` example pairs from the
/// protocol doc: fenced blocks tagged ```` ```json request ```` must be
/// followed by a ```` ```json reply ```` block.
fn doc_examples(doc: &str) -> Vec<(String, String)> {
    let mut blocks = Vec::new();
    let mut lines = doc.lines();
    while let Some(line) = lines.next() {
        let tag = line.trim();
        if tag != "```json request" && tag != "```json reply" {
            continue;
        }
        let mut body = String::new();
        for content in lines.by_ref() {
            if content.trim() == "```" {
                break;
            }
            if !body.is_empty() {
                body.push('\n');
            }
            body.push_str(content);
        }
        blocks.push((tag == "```json request", body));
    }
    let mut pairs = Vec::new();
    let mut i = 0;
    while i < blocks.len() {
        assert!(blocks[i].0, "found a reply block with no preceding request");
        assert!(
            i + 1 < blocks.len() && !blocks[i + 1].0,
            "request block without a following reply block: {}",
            blocks[i].1
        );
        pairs.push((blocks[i].1.clone(), blocks[i + 1].1.clone()));
        i += 2;
    }
    pairs
}

/// Zero the documented timing fields so a reply can be compared
/// byte-for-byte against the doc (which explains this normalization):
/// top-level `queue_us`/`exec_us`, and inside a `trace` breakdown the
/// `total_us` plus every span's `us`.  Span kinds and their order stay
/// verbatim, so the doc pins the span sequence.
fn normalize_timings(reply: &str) -> String {
    let mut v = Json::parse(reply).unwrap_or_else(|e| panic!("unparseable reply {reply:?}: {e}"));
    if let Json::Obj(map) = &mut v {
        for key in ["queue_us", "exec_us"] {
            if map.contains_key(key) {
                map.insert(key.to_string(), Json::Num(0.0));
            }
        }
        if let Some(Json::Obj(trace)) = map.get_mut("trace") {
            trace.insert("total_us".to_string(), Json::Num(0.0));
            if let Some(Json::Arr(spans)) = trace.get_mut("spans") {
                for span in spans {
                    if let Json::Obj(span) = span {
                        span.insert("us".to_string(), Json::Num(0.0));
                    }
                }
            }
        }
    }
    v.to_string()
}

#[test]
fn wire_protocol_doc_examples_replay_byte_for_byte() {
    // the documented examples assume the native backend; with AOT
    // artifacts present routing (and the backend field) changes
    if Manifest::load(&ninetoothed_repro::artifacts_dir()).is_ok() {
        eprintln!("skipping doc replay: AOT artifacts present, doc documents the native build");
        return;
    }
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/wire-protocol.md");
    let doc = std::fs::read_to_string(path).expect("docs/wire-protocol.md must exist");
    let pairs = doc_examples(&doc);
    assert!(pairs.len() >= 5, "expected at least 5 replayable examples, found {}", pairs.len());

    // the doc documents a server at the default config
    let (coordinator, server) = start_server(CoordinatorConfig::default());
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
    for (request, documented) in &pairs {
        let actual = client.call_raw(request).unwrap();
        assert_eq!(
            normalize_timings(&actual),
            normalize_timings(documented),
            "documented reply for {request:?} diverged (doc: {documented:?}, got: {actual:?})"
        );
    }
    server.shutdown();
    coordinator.drain();
}
