//! Integration tests.
//!
//! The native tile-execution backend makes most of the system testable
//! with no AOT artifacts at all: the coordinator serves kernels through
//! `exec`, and numerics are checked against the in-crate reference
//! oracles.  Tests that genuinely need compiled artifacts (goldens from
//! the Python oracle, the inference engine, Table 2 metrics) detect their
//! absence and skip with a visible message instead of failing — run
//! `make artifacts` on a PJRT-enabled machine to activate them.

use std::sync::Arc;

use ninetoothed_repro::arrange;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::exec;
use ninetoothed_repro::harness::fig6;
use ninetoothed_repro::inference::Engine;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{Backend, BackendKind, HostTensor, Manifest, Registry, Runtime};

/// The manifest to serve from: real artifacts when present, builtin
/// (native-only) otherwise.
fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()))
}

/// Artifact-backed registry, when both artifacts and a PJRT runtime
/// exist.  `None` in the offline build.
fn artifact_registry(test: &str) -> Option<Registry> {
    let manifest = Manifest::load(&ninetoothed_repro::artifacts_dir()).ok()?;
    match Runtime::cpu() {
        Ok(runtime) => Some(Registry::new(runtime, Arc::new(manifest))),
        Err(e) => {
            eprintln!("skipping {test}: no PJRT runtime ({e:#})");
            None
        }
    }
}

fn artifact_manifest(test: &str) -> Option<Arc<Manifest>> {
    match Manifest::load(&ninetoothed_repro::artifacts_dir()) {
        Ok(m) => Some(Arc::new(m)),
        Err(e) => {
            eprintln!("skipping {test}: no AOT artifacts ({e:#})");
            None
        }
    }
}

// ---------------------------------------------------------------------------
// native backend end-to-end (no artifacts required)
// ---------------------------------------------------------------------------

#[test]
fn native_goldens_pass_for_all_kernels() {
    // every native tile program vs its reference oracle, serial + pooled
    let cases = ninetoothed_repro::harness::golden::check_native().unwrap();
    assert!(cases >= 16, "expected ≥ 8 kernels x 2 schedulers, got {cases}");
}

#[test]
fn registry_resolves_native_fallback() {
    let registry = Registry::native_only(Arc::new(Manifest::builtin()));
    let mm = registry.resolve("mm", "nt").unwrap();
    assert_eq!(mm.kind(), BackendKind::Native);
    let reference = registry.resolve("mm", "ref").unwrap();
    assert_eq!(reference.kind(), BackendKind::Reference);
    assert!(registry.resolve("no_such_kernel", "nt").is_err());
    assert_eq!(registry.resolved_count(), 2);

    // and the two backends agree numerically
    let mut rng = SplitMix64::new(3);
    let a = HostTensor::randn(vec![40, 30], &mut rng);
    let b = HostTensor::randn(vec![30, 20], &mut rng);
    let got = mm.run(&[a.clone(), b.clone()]).unwrap();
    let want = reference.run(&[a, b]).unwrap();
    assert!(got[0].max_abs_diff(&want[0]).unwrap() <= 1e-4);
}

#[test]
fn coordinator_serves_artifactless_kernels_natively() {
    // the fallback integration test: a coordinator over a manifest with
    // NO artifact for these kernels serves them via the native backend
    let manifest = Arc::new(Manifest::builtin());
    let coordinator = Coordinator::start(
        manifest,
        CoordinatorConfig { workers: 2, queue_capacity: 128, max_fanin: 8, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(21);

    // mixed workload: variable-length adds, an mm, a softmax
    let mut cases = Vec::new();
    for i in 0..4 {
        let n = 500 + i * 137;
        let x = HostTensor::randn(vec![n], &mut rng);
        let y = HostTensor::randn(vec![n], &mut rng);
        let rx = coordinator.submit("add", "nt", vec![x.clone(), y.clone()]).unwrap();
        cases.push((vec![x, y], "add", rx));
    }
    let a = HostTensor::randn(vec![70, 50], &mut rng);
    let b = HostTensor::randn(vec![50, 90], &mut rng);
    let rx = coordinator.submit("mm", "nt", vec![a.clone(), b.clone()]).unwrap();
    cases.push((vec![a, b], "mm", rx));
    let s = HostTensor::randn(vec![9, 129], &mut rng);
    let rx = coordinator.submit("softmax", "nt", vec![s.clone()]).unwrap();
    cases.push((vec![s], "softmax", rx));

    for (inputs, kernel, rx) in cases {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.backend, "native", "{kernel} must fall back to the native backend");
        let expected = exec::reference::run(kernel, &inputs).unwrap();
        let diff = resp.outputs[0].max_abs_diff(&expected[0]).unwrap();
        assert!(diff <= 1e-4, "{kernel} served natively: max|diff| = {diff}");
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.completed, 6);
    assert_eq!(metrics.rejected, 0);
    coordinator.shutdown();
}

#[test]
fn coordinator_rejects_malformed_requests() {
    let coordinator = Coordinator::start(manifest(), CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(1);
    let x = HostTensor::randn(vec![16], &mut rng);
    // wrong arity
    assert!(coordinator.submit("add", "nt", vec![x.clone()]).is_err());
    // unknown kernel
    assert!(coordinator.submit("nope", "nt", vec![x.clone()]).is_err());
    // incompatible mm shapes (k mismatch)
    let a = HostTensor::randn(vec![8, 3], &mut rng);
    let b = HostTensor::randn(vec![4, 8], &mut rng);
    assert!(coordinator.submit("mm", "nt", vec![a, b]).is_err());
    // zero-length tensor (regression: must reject cleanly, not panic)
    let empty = HostTensor::f32(vec![0], vec![]).unwrap();
    let err = coordinator
        .submit("add", "nt", vec![empty.clone(), empty])
        .unwrap_err();
    assert!(format!("{err:#}").contains("zero-length"), "{err:#}");
    // rank-0 tensor where a vector is expected (regression: clean error)
    let scalar = HostTensor::f32(vec![], vec![1.0]).unwrap();
    assert!(coordinator
        .submit("silu", "nt", vec![scalar])
        .is_err());
    // no input tensors at all
    assert!(coordinator.submit("add", "nt", vec![]).is_err());
    assert_eq!(coordinator.metrics().rejected, 6);
    coordinator.shutdown();
}

#[test]
fn coordinator_backpressure() {
    // capacity 2, one worker: a burst of expensive requests must trip the
    // queue-full rejection path
    let manifest = manifest();
    let coordinator = Coordinator::start(
        manifest.clone(),
        CoordinatorConfig { workers: 1, queue_capacity: 2, max_fanin: 1, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(2);
    // artifact runs must use the compiled shape (requests of any other
    // shape are rejected at admission, which would make this test
    // vacuous); native runs use a deliberately large softmax
    let shape = manifest
        .kernel("softmax", "nt")
        .map(|a| a.args[0].shape.clone())
        .unwrap_or_else(|_| vec![512, 2048]);
    // one tensor, cloned per request: submission is a memcpy while
    // execution is an O(rows x cols) softmax — the queue fills first
    let x = HostTensor::randn(shape, &mut rng);
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..16 {
        match coordinator.submit("softmax", "nt", vec![x.clone()]) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "queue of capacity 2 must reject part of a 16-burst");
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    coordinator.shutdown();
}

#[test]
fn coordinator_serves_addmm_natively() {
    let manifest = Arc::new(Manifest::builtin());
    let coordinator =
        Coordinator::start(manifest, CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(41);
    let bias = HostTensor::randn(vec![31], &mut rng);
    let a = HostTensor::randn(vec![45, 20], &mut rng);
    let b = HostTensor::randn(vec![20, 31], &mut rng);
    let inputs = vec![bias, a, b];
    let rx = coordinator.submit("addmm", "nt", inputs.clone()).unwrap();
    let resp = rx.recv().unwrap().unwrap();
    assert_eq!(resp.backend, "native");
    let expected = exec::reference::run("addmm", &inputs).unwrap();
    let diff = resp.outputs[0].max_abs_diff(&expected[0]).unwrap();
    assert!(diff <= 1e-4, "addmm served natively: max|diff| = {diff}");
    // non-broadcastable bias is rejected at admission, not mid-pipeline
    let mut rng = SplitMix64::new(42);
    let bad = HostTensor::randn(vec![7], &mut rng);
    let a = HostTensor::randn(vec![5, 4], &mut rng);
    let b = HostTensor::randn(vec![4, 6], &mut rng);
    assert!(coordinator.submit("addmm", "nt", vec![bad, a, b]).is_err());
    coordinator.shutdown();
}

#[test]
fn second_same_shape_request_hits_the_plan_cache() {
    // the compile-once/execute-many acceptance: request #1 misses (one
    // specialization), request #2 with the same shapes performs zero
    // specialization work — proven by the shared cache's counters
    let manifest = Arc::new(Manifest::builtin());
    let coordinator =
        Coordinator::start(manifest, CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(51);
    let a = HostTensor::randn(vec![33, 21], &mut rng);
    let b = HostTensor::randn(vec![21, 17], &mut rng);
    // sequential submits: each response is awaited before the next goes in
    let first = coordinator
        .submit("mm", "nt", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let m1 = coordinator.metrics();
    assert_eq!((m1.plan_misses, m1.plan_hits), (1, 0), "first request compiles");
    let second = coordinator
        .submit("mm", "nt", vec![a.clone(), b.clone()])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    let m2 = coordinator.metrics();
    assert_eq!(m2.plan_misses, 1, "second same-shape request must not recompile");
    assert_eq!(m2.plan_hits, 1, "second same-shape request must hit the cache");
    assert_eq!(first.outputs[0], second.outputs[0], "same inputs, bit-identical outputs");
    // a different shape signature (same rank) compiles its own plan —
    // even when served by the *other* worker, the cache is shared
    let c = HostTensor::randn(vec![21, 19], &mut rng);
    coordinator
        .submit("mm", "nt", vec![a, c])
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(coordinator.metrics().plan_misses, 2);
    coordinator.shutdown();
}

#[test]
fn coordinator_coalesces_same_shape_native_requests() {
    // one worker; the head-of-line mm (~2 * 192^3 FLOPs, milliseconds)
    // keeps it busy while the same-shape softmax burst queues behind it —
    // the next drain stacks the whole run into one grid launch
    let manifest = Arc::new(Manifest::builtin());
    let coordinator = Coordinator::start(
        manifest,
        CoordinatorConfig { workers: 1, queue_capacity: 128, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(61);
    let a = HostTensor::randn(vec![192, 192], &mut rng);
    let b = HostTensor::randn(vec![192, 192], &mut rng);
    let mm_rx = coordinator.submit("mm", "nt", vec![a, b]).unwrap();
    let mut cases = Vec::new();
    for _ in 0..6 {
        let x = HostTensor::randn(vec![9, 65], &mut rng);
        let rx = coordinator.submit("softmax", "nt", vec![x.clone()]).unwrap();
        cases.push((x, rx));
    }
    mm_rx.recv().unwrap().unwrap();
    for (x, rx) in cases {
        let resp = rx.recv().unwrap().unwrap();
        let expected = exec::reference::run("softmax", &[x]).unwrap();
        let diff = resp.outputs[0].max_abs_diff(&expected[0]).unwrap();
        assert!(diff <= 1e-4, "coalesced softmax: max|diff| = {diff}");
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.completed, 7);
    assert!(
        metrics.coalesced >= 2,
        "expected the queued softmax burst to coalesce, metrics: {}",
        metrics.render()
    );
    assert!(metrics.executions < 7, "coalescing must fuse executions");
    coordinator.shutdown();
}

#[test]
fn native_mm_parallel_matches_serial() {
    // the §3.2.1 non-overlap argument in practice: pooled and serial grid
    // execution write identical outputs
    let mut rng = SplitMix64::new(77);
    let a = HostTensor::randn(vec![130, 70], &mut rng);
    let b = HostTensor::randn(vec![70, 110], &mut rng);
    let serial = exec::run_native("mm", &[a.clone(), b.clone()], &exec::GridScheduler::serial())
        .unwrap();
    let pooled = exec::run_native("mm", &[a, b], &exec::GridScheduler::pooled(8)).unwrap();
    assert_eq!(serial[0], pooled[0], "parallel scatter must be bit-identical to serial");
}

// ---------------------------------------------------------------------------
// artifact-backed paths (skip with a message when `make artifacts` has not
// run — the offline container has no PJRT plugin)
// ---------------------------------------------------------------------------

#[test]
fn golden_cases_pass_for_all_variants() {
    let Some(registry) = artifact_registry("golden_cases_pass_for_all_variants") else {
        return;
    };
    ninetoothed_repro::harness::golden::check_all(&registry).unwrap();
}

#[test]
fn all_kernels_nt_matches_ref() {
    let Some(registry) = artifact_registry("all_kernels_nt_matches_ref") else {
        return;
    };
    let manifest = registry.manifest();
    for name in manifest.kernel_names() {
        let inputs = fig6::task_inputs(manifest, &name, 123).unwrap();
        let nt = registry.kernel(&name, "nt").unwrap().run(&inputs).unwrap();
        let reference = registry.kernel(&name, "ref").unwrap().run(&inputs).unwrap();
        let diff = nt[0].max_abs_diff(&reference[0]).unwrap();
        // mm-family accumulate different orders; scaled tolerance
        assert!(diff < 5e-3, "{name}: nt vs ref max|diff| = {diff}");
    }
}

#[test]
fn all_kernels_baseline_matches_ref() {
    let Some(registry) = artifact_registry("all_kernels_baseline_matches_ref") else {
        return;
    };
    let manifest = registry.manifest();
    for name in manifest.kernel_names() {
        let inputs = fig6::task_inputs(manifest, &name, 321).unwrap();
        let baseline = registry.kernel(&name, "baseline").unwrap().run(&inputs).unwrap();
        let reference = registry.kernel(&name, "ref").unwrap().run(&inputs).unwrap();
        let diff = baseline[0].max_abs_diff(&reference[0]).unwrap();
        assert!(diff < 5e-3, "{name}: baseline vs ref max|diff| = {diff}");
    }
}

#[test]
fn arrangements_validate_and_goldens_replay() {
    let Some(manifest) = artifact_manifest("arrangements_validate_and_goldens_replay") else {
        return;
    };
    let arrangements = arrange::load_all(&manifest.raw).unwrap();
    assert!(arrangements.len() >= 10);
    let mut goldens = 0;
    for a in &arrangements {
        a.validate_structure().unwrap();
        goldens += a.check_goldens().unwrap();
    }
    assert!(goldens > 50, "expected many golden evaluations, got {goldens}");
}

#[test]
fn catalog_matches_manifest_geometry() {
    let Some(manifest) = artifact_manifest("catalog_matches_manifest_geometry") else {
        return;
    };
    ninetoothed_repro::harness::validate::catalog_parity(&manifest).unwrap();
}

#[test]
fn native_catalog_specializes() {
    // the artifact-free counterpart of catalog parity: every native kernel
    // specializes at its smoke shapes
    ninetoothed_repro::harness::validate::native_catalog().unwrap();
}

#[test]
fn launch_plan_reports_grid_and_vmem() {
    let Some(manifest) = artifact_manifest("launch_plan_reports_grid_and_vmem") else {
        return;
    };
    let arrangements = arrange::load_all(&manifest.raw).unwrap();
    let mm = arrangements.iter().find(|a| a.kernel == "mm").unwrap();
    // bind every symbol the arrangement references
    let mut env = std::collections::BTreeMap::new();
    for p in &mm.params {
        for e in &p.indices {
            for s in e.free_symbols() {
                env.entry(s.clone()).or_insert(256);
            }
        }
        for (size, _) in p.levels.iter().flatten() {
            for s in size.free_symbols() {
                env.entry(s.clone()).or_insert(256);
            }
        }
    }
    // block sizes: 64
    for (k, v) in env.iter_mut() {
        if !k.contains("_size_") {
            *v = 64;
        }
    }
    let plan = mm.launch_plan(&env).unwrap();
    assert_eq!(plan.grid, vec![4, 4]);
    assert!(plan.vmem_bytes_per_program() > 0);
}

#[test]
fn coordinator_packs_and_verifies() {
    // slot packing applies to artifact routes (fixed compiled shapes)
    let Some(manifest) = artifact_manifest("coordinator_packs_and_verifies") else {
        return;
    };
    let coordinator = Coordinator::start(
        manifest.clone(),
        CoordinatorConfig { workers: 1, queue_capacity: 128, max_fanin: 8, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(9);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..6 {
        let n = 700 + i * 131;
        let x = HostTensor::randn(vec![n], &mut rng);
        let y = HostTensor::randn(vec![n], &mut rng);
        let want: Vec<f32> = x
            .as_f32()
            .unwrap()
            .iter()
            .zip(y.as_f32().unwrap())
            .map(|(a, b)| a + b)
            .collect();
        expected.push(want);
        rxs.push(coordinator.submit("add", "nt", vec![x, y]).unwrap());
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().unwrap().unwrap();
        let got = resp.outputs[0].as_f32().unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.completed, 6);
    assert!(metrics.executions < 6, "expected packing to fuse executions");
    coordinator.shutdown();
}

#[test]
fn engine_generates_and_backends_agree() {
    let Some(registry) = artifact_registry("engine_generates_and_backends_agree") else {
        return;
    };
    let registry = Arc::new(registry);
    let mut all_tokens = Vec::new();
    for variant in ["nt", "ref"] {
        let engine = Engine::new(registry.clone(), variant).unwrap();
        let prompt = engine.synth_prompt(5);
        let result = engine.generate(&prompt, 4).unwrap();
        assert_eq!(result.tokens.len(), engine.batch);
        assert_eq!(result.tokens[0].len(), 4);
        assert!(result.tokens_per_s > 0.0);
        all_tokens.push(result.tokens);
    }
    assert_eq!(all_tokens[0], all_tokens[1], "nt vs ref greedy decode diverged");
}

#[test]
fn engine_rejects_overlong_generation() {
    let Some(registry) = artifact_registry("engine_rejects_overlong_generation") else {
        return;
    };
    let engine = Engine::new(Arc::new(registry), "ref").unwrap();
    let prompt = engine.synth_prompt(1);
    let too_many = engine.max_seq - engine.prompt_len + 1;
    assert!(engine.generate(&prompt, too_many).is_err());
}

#[test]
fn table2_metrics_present_and_favorable() {
    let Some(manifest) = artifact_manifest("table2_metrics_present_and_favorable") else {
        return;
    };
    // MI favors NineToothed on most kernels (paper: all 10; our baseline is
    // Pallas, which hides some of Triton's pointer arithmetic — DESIGN.md §6)
    let rows = manifest.raw.req("metrics").unwrap().arr("rows").unwrap();
    assert_eq!(rows.len(), 20);
    let mut wins = 0;
    for kernel in
        ["add", "addmm", "bmm", "conv2d", "mm", "silu", "softmax", "sdpa", "rms_norm", "rope"]
    {
        let get = |variant: &str| {
            rows.iter()
                .find(|r| {
                    r.str("kernel").unwrap() == kernel && r.str("variant").unwrap() == variant
                })
                .unwrap()
                .f64("mi")
                .unwrap()
        };
        if get("nt") > get("baseline") {
            wins += 1;
        }
    }
    assert!(wins >= 8, "NineToothed should win MI on nearly all kernels, won {wins}/10");
}
