//! Integration tests over the real AOT artifacts (require `make artifacts`).
//!
//! Covers: runtime loading + numerics, all-kernel NT/baseline/ref agreement,
//! arrangement validation + golden replay, launch-plan geometry, the
//! coordinator (routing, packing, backpressure, rejection), and the
//! end-to-end inference engine.

use std::sync::Arc;

use ninetoothed_repro::arrange;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::harness::fig6;
use ninetoothed_repro::inference::Engine;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest, Registry, Runtime};

fn manifest() -> Arc<Manifest> {
    Arc::new(Manifest::load(&ninetoothed_repro::artifacts_dir()).expect("run `make artifacts`"))
}

fn registry() -> Registry {
    Registry::new(Runtime::cpu().expect("pjrt cpu"), manifest())
}

#[test]
fn golden_cases_pass_for_all_variants() {
    let registry = registry();
    ninetoothed_repro::harness::golden::check_all(&registry).unwrap();
}

#[test]
fn all_kernels_nt_matches_ref() {
    let registry = registry();
    let manifest = registry.manifest();
    for name in manifest.kernel_names() {
        let inputs = fig6::task_inputs(manifest, &name, 123).unwrap();
        let nt = registry.kernel(&name, "nt").unwrap().run(&inputs).unwrap();
        let reference = registry.kernel(&name, "ref").unwrap().run(&inputs).unwrap();
        let diff = nt[0].max_abs_diff(&reference[0]).unwrap();
        // mm-family accumulate different orders; scaled tolerance
        assert!(diff < 5e-3, "{name}: nt vs ref max|diff| = {diff}");
    }
}

#[test]
fn all_kernels_baseline_matches_ref() {
    let registry = registry();
    let manifest = registry.manifest();
    for name in manifest.kernel_names() {
        let inputs = fig6::task_inputs(manifest, &name, 321).unwrap();
        let baseline = registry.kernel(&name, "baseline").unwrap().run(&inputs).unwrap();
        let reference = registry.kernel(&name, "ref").unwrap().run(&inputs).unwrap();
        let diff = baseline[0].max_abs_diff(&reference[0]).unwrap();
        assert!(diff < 5e-3, "{name}: baseline vs ref max|diff| = {diff}");
    }
}

#[test]
fn arrangements_validate_and_goldens_replay() {
    let manifest = manifest();
    let arrangements = arrange::load_all(&manifest.raw).unwrap();
    assert!(arrangements.len() >= 10);
    let mut goldens = 0;
    for a in &arrangements {
        a.validate_structure().unwrap();
        goldens += a.check_goldens().unwrap();
    }
    assert!(goldens > 50, "expected many golden evaluations, got {goldens}");
}

#[test]
fn catalog_matches_manifest_geometry() {
    ninetoothed_repro::harness::validate::catalog_parity(&manifest()).unwrap();
}

#[test]
fn launch_plan_reports_grid_and_vmem() {
    let manifest = manifest();
    let arrangements = arrange::load_all(&manifest.raw).unwrap();
    let mm = arrangements.iter().find(|a| a.kernel == "mm").unwrap();
    // bind every symbol the arrangement references
    let mut env = std::collections::BTreeMap::new();
    for p in &mm.params {
        for e in &p.indices {
            for s in e.free_symbols() {
                env.entry(s.clone()).or_insert(256);
            }
        }
        for (size, _) in p.levels.iter().flatten() {
            for s in size.free_symbols() {
                env.entry(s.clone()).or_insert(256);
            }
        }
    }
    // block sizes: 64
    for (k, v) in env.iter_mut() {
        if !k.contains("_size_") {
            *v = 64;
        }
    }
    let plan = mm.launch_plan(&env).unwrap();
    assert_eq!(plan.grid, vec![4, 4]);
    assert!(plan.vmem_bytes_per_program() > 0);
}

#[test]
fn coordinator_packs_and_verifies() {
    let manifest = manifest();
    let coordinator = Coordinator::start(
        manifest.clone(),
        CoordinatorConfig { workers: 1, queue_capacity: 128, max_fanin: 8 },
    );
    let mut rng = SplitMix64::new(9);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..6 {
        let n = 700 + i * 131;
        let x = HostTensor::randn(vec![n], &mut rng);
        let y = HostTensor::randn(vec![n], &mut rng);
        let want: Vec<f32> = x
            .as_f32()
            .unwrap()
            .iter()
            .zip(y.as_f32().unwrap())
            .map(|(a, b)| a + b)
            .collect();
        expected.push(want);
        rxs.push(coordinator.submit("add", "nt", vec![x, y]).unwrap());
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().unwrap().unwrap();
        let got = resp.outputs[0].as_f32().unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.completed, 6);
    assert!(metrics.executions < 6, "expected packing to fuse executions");
    coordinator.shutdown();
}

#[test]
fn coordinator_rejects_malformed_requests() {
    let manifest = manifest();
    let coordinator = Coordinator::start(manifest.clone(), CoordinatorConfig::default());
    let mut rng = SplitMix64::new(1);
    // wrong arity
    let x = HostTensor::randn(vec![16], &mut rng);
    assert!(coordinator.submit("add", "nt", vec![x.clone()]).is_err());
    // unknown kernel
    assert!(coordinator.submit("nope", "nt", vec![x.clone()]).is_err());
    // oversized packable request
    let slot = manifest.kernel("add", "nt").unwrap().args[0].shape[0];
    let big = HostTensor::randn(vec![slot + 1], &mut rng);
    assert!(coordinator
        .submit("add", "nt", vec![big.clone(), big])
        .is_err());
    // wrong shape for a non-packable kernel
    let bad = HostTensor::randn(vec![3, 3], &mut rng);
    assert!(coordinator.submit("mm", "nt", vec![bad.clone(), bad]).is_err());
    assert_eq!(coordinator.metrics().rejected, 4);
    coordinator.shutdown();
}

#[test]
fn coordinator_backpressure() {
    let manifest = manifest();
    // capacity 2, zero workers draining slowly: start coordinator with 1
    // worker but saturate with many requests before it can drain
    let coordinator = Coordinator::start(
        manifest.clone(),
        CoordinatorConfig { workers: 1, queue_capacity: 2, max_fanin: 1 },
    );
    let mut rng = SplitMix64::new(2);
    let shape = manifest.kernel("softmax", "nt").unwrap().args[0].shape.clone();
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for _ in 0..12 {
        let x = HostTensor::randn(shape.clone(), &mut rng);
        match coordinator.submit("softmax", "nt", vec![x]) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "queue of capacity 2 must reject part of a 12-burst");
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    coordinator.shutdown();
}

#[test]
fn engine_generates_and_backends_agree() {
    let registry = Arc::new(registry());
    let mut all_tokens = Vec::new();
    for variant in ["nt", "ref"] {
        let engine = Engine::new(registry.clone(), variant).unwrap();
        let prompt = engine.synth_prompt(5);
        let result = engine.generate(&prompt, 4).unwrap();
        assert_eq!(result.tokens.len(), engine.batch);
        assert_eq!(result.tokens[0].len(), 4);
        assert!(result.tokens_per_s > 0.0);
        all_tokens.push(result.tokens);
    }
    assert_eq!(all_tokens[0], all_tokens[1], "nt vs ref greedy decode diverged");
}

#[test]
fn engine_rejects_overlong_generation() {
    let registry = Arc::new(registry());
    let engine = Engine::new(registry, "ref").unwrap();
    let prompt = engine.synth_prompt(1);
    let too_many = engine.max_seq - engine.prompt_len + 1;
    assert!(engine.generate(&prompt, too_many).is_err());
}

#[test]
fn table2_metrics_present_and_favorable() {
    let manifest = manifest();
    // MI favors NineToothed on most kernels (paper: all 10; our baseline is
    // Pallas, which hides some of Triton's pointer arithmetic — DESIGN.md §6)
    let rows = manifest.raw.req("metrics").unwrap().arr("rows").unwrap();
    assert_eq!(rows.len(), 20);
    let mut wins = 0;
    for kernel in ["add", "addmm", "bmm", "conv2d", "mm", "silu", "softmax", "sdpa", "rms_norm", "rope"] {
        let get = |variant: &str| {
            rows.iter()
                .find(|r| r.str("kernel").unwrap() == kernel && r.str("variant").unwrap() == variant)
                .unwrap()
                .f64("mi")
                .unwrap()
        };
        if get("nt") > get("baseline") {
            wins += 1;
        }
    }
    assert!(wins >= 8, "NineToothed should win MI on nearly all kernels, won {wins}/10");
}
