//! Acceptance for the loop-carried reduction subsystem and its proof
//! kernel: flash-style scaled dot-product attention, declared **only**
//! through `kernel::make` (no hand-written specializer anywhere).
//!
//! * property sweep: the online-softmax tile program vs the naive
//!   `softmax(QK^T / sqrt(d)) V` f64 oracle over ragged sequence lengths
//!   (including seq not divisible by the block size), head_dim 1 and
//!   single-row inputs — within 1e-3 everywhere, serial and pooled;
//! * causal masking through the `sdpa_bias` variant's `[s, s]` additive
//!   score bias;
//! * coalesce derivation: `sdpa` *is* batch-stackable (and stacking is
//!   bit-identical), `sdpa_bias` is not (its bias lacks the batch dim)
//!   and the router/coordinator never stack it;
//! * end-to-end serving: plan-cache miss then hit, bit-identical outputs
//!   across the hit.

use std::sync::Arc;

use ninetoothed_repro::coordinator::{Coalescer, Coordinator, CoordinatorConfig};
use ninetoothed_repro::exec::{self, GridScheduler};
use ninetoothed_repro::kernel;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

/// ISSUE acceptance tolerance: flash-style f32 vs the naive f64 oracle.
const TOL: f32 = 1e-3;

/// The additive-mask value the kernels use (finite, so the online
/// softmax never computes `-inf - -inf`).
const MASK: f32 = -1e30;

fn qkv(b: usize, h: usize, s: usize, d: usize, rng: &mut SplitMix64) -> Vec<HostTensor> {
    (0..3).map(|_| HostTensor::randn(vec![b, h, s, d], rng)).collect()
}

/// `[s, s]` causal mask: 0 at or below the diagonal, -1e30 above it.
fn causal_bias(s: usize) -> HostTensor {
    let mut data = vec![0.0f32; s * s];
    for i in 0..s {
        for (j, v) in data[i * s..(i + 1) * s].iter_mut().enumerate() {
            if j > i {
                *v = MASK;
            }
        }
    }
    HostTensor::f32(vec![s, s], data).unwrap()
}

/// The sweep shapes: block-aligned, ragged, multi-block, head_dim 1,
/// single-row, and single-element.  The attention blocks are
/// `min(64, next_pow2(s))`, so s = 65/100/130 exercise padded key tails
/// and multi-step online-softmax loops.
const SWEEP: &[(usize, usize, usize, usize)] = &[
    (1, 1, 1, 1),
    (1, 1, 1, 8),
    (1, 2, 3, 5),
    (2, 2, 37, 16),
    (1, 1, 64, 8),
    (1, 3, 65, 4),
    (2, 1, 100, 32),
    (1, 1, 5, 1),
    (1, 1, 130, 4),
];

#[test]
fn sdpa_property_sweep_matches_the_naive_oracle() {
    let sdpa = kernel::lookup("sdpa").expect("sdpa is registered via kernel::make");
    let mut rng = SplitMix64::new(2026);
    for &(b, h, s, d) in SWEEP {
        let inputs = qkv(b, h, s, d, &mut rng);
        let expected = exec::reference::sdpa(&inputs[0], &inputs[1], &inputs[2]).unwrap();
        let serial = sdpa.run(&inputs, &GridScheduler::serial()).unwrap();
        let diff = serial[0].max_abs_diff(&expected).unwrap();
        assert!(diff <= TOL, "sdpa [{b},{h},{s},{d}] serial: max|diff| = {diff}");
        let pooled = sdpa.run(&inputs, &GridScheduler::pooled(4)).unwrap();
        assert_eq!(serial[0], pooled[0], "sdpa [{b},{h},{s},{d}]: pooled must be bit-identical");
    }
}

#[test]
fn sdpa_bias_expresses_causal_masking() {
    let sdpa_bias = kernel::lookup("sdpa_bias").expect("sdpa_bias is registered");
    let mut rng = SplitMix64::new(2027);
    for &(b, h, s, d) in SWEEP {
        let mut inputs = qkv(b, h, s, d, &mut rng);
        inputs.push(causal_bias(s));
        let expected =
            exec::reference::sdpa_bias(&inputs[0], &inputs[1], &inputs[2], &inputs[3]).unwrap();
        let got = sdpa_bias.run(&inputs, &GridScheduler::serial()).unwrap();
        let diff = got[0].max_abs_diff(&expected).unwrap();
        assert!(diff <= TOL, "sdpa_bias causal [{b},{h},{s},{d}]: max|diff| = {diff}");
        // causal row 0 attends only to position 0: output row 0 == v row 0
        let out = got[0].as_f32().unwrap();
        let v = inputs[2].as_f32().unwrap();
        for bh in 0..b * h {
            for di in 0..d {
                let (o, w) = (out[bh * s * d + di], v[bh * s * d + di]);
                assert!((o - w).abs() <= TOL, "causal first row must copy v: {o} vs {w}");
            }
        }
    }
}

#[test]
fn sdpa_shape_preconditions_reject_cleanly() {
    let sdpa = kernel::lookup("sdpa").unwrap();
    let sdpa_bias = kernel::lookup("sdpa_bias").unwrap();
    // unified dims: q/k/v must agree everywhere
    assert!(sdpa.check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 4], &[2, 2, 8, 4]]).is_ok());
    let err = sdpa.check_shapes(&[&[2, 2, 8, 4], &[2, 2, 9, 4], &[2, 2, 8, 4]]).unwrap_err();
    assert!(format!("{err:#}").contains("size s"), "{err:#}");
    let err = sdpa.check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 5], &[2, 2, 8, 4]]).unwrap_err();
    assert!(format!("{err:#}").contains("size d"), "{err:#}");
    // rank and arity
    assert!(sdpa.check_shapes(&[&[2, 8, 4], &[2, 8, 4], &[2, 8, 4]]).is_err());
    assert!(sdpa.check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 4]]).is_err());
    // the bias must be [s, s]
    assert!(sdpa_bias
        .check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 4], &[2, 2, 8, 4], &[8, 8]])
        .is_ok());
    assert!(sdpa_bias
        .check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 4], &[2, 2, 8, 4], &[8, 9]])
        .is_err());
    assert!(sdpa_bias
        .check_shapes(&[&[2, 2, 8, 4], &[2, 2, 8, 4], &[2, 2, 8, 4], &[7, 7]])
        .is_err());
    // output inference never takes an output argument
    assert_eq!(
        sdpa.output_shapes(&[&[2, 3, 10, 8], &[2, 3, 10, 8], &[2, 3, 10, 8]]).unwrap(),
        vec![vec![2, 3, 10, 8]]
    );
}

#[test]
fn sdpa_stacks_batchwise_bit_identically_and_bias_variant_never_stacks() {
    // derivation: sdpa's parameters all lead with the batch symbol, the
    // carried loop walks the sequence dim — batch-stackable; sdpa_bias's
    // [s, s] bias has no batch dim — not stackable
    let sdpa = kernel::lookup("sdpa").unwrap();
    let sdpa_bias = kernel::lookup("sdpa_bias").unwrap();
    assert!(sdpa.coalesce, "sdpa must derive as batch-stackable");
    assert!(!sdpa_bias.coalesce, "sdpa_bias must never derive as stackable");

    // and stacking is bit-identical to per-request execution
    let mut rng = SplitMix64::new(2028);
    let sched = GridScheduler::pooled(4);
    let per_request: Vec<Vec<HostTensor>> = (0..3).map(|_| qkv(1, 2, 37, 8, &mut rng)).collect();
    let singles: Vec<Vec<HostTensor>> =
        per_request.iter().map(|inputs| sdpa.run(inputs, &sched).unwrap()).collect();
    let refs: Vec<Vec<&HostTensor>> =
        per_request.iter().map(|inputs| inputs.iter().collect()).collect();
    let stacked = Coalescer::stack(&refs).unwrap();
    assert_eq!(stacked[0].shape, vec![3, 2, 37, 8]);
    let outs = sdpa.run(&stacked, &sched).unwrap();
    let unstacked = Coalescer::unstack(3, outs).unwrap();
    for (got, want) in unstacked.iter().zip(&singles) {
        assert_eq!(got[0], want[0], "stacked sdpa must be bit-identical to per-request");
    }
}

#[test]
fn sdpa_bias_burst_is_never_fused_by_the_coordinator() {
    // a queued same-shape burst of the non-stackable variant must execute
    // one launch per request — the router routes off the derived flag
    let coordinator = Coordinator::start(
        Arc::new(Manifest::builtin()),
        CoordinatorConfig { workers: 1, queue_capacity: 128, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(2029);
    let a = HostTensor::randn(vec![192, 192], &mut rng);
    let b = HostTensor::randn(vec![192, 192], &mut rng);
    // head-of-line mm keeps the single worker busy so the burst queues
    let mm_rx = coordinator.submit("mm", "nt", vec![a, b]).unwrap();
    let base = qkv(1, 2, 20, 8, &mut rng);
    let bias = causal_bias(20);
    let mut rxs = Vec::new();
    for _ in 0..4 {
        let mut inputs = base.clone();
        inputs.push(bias.clone());
        rxs.push(coordinator.submit("sdpa_bias", "nt", inputs).unwrap());
    }
    mm_rx.recv().unwrap().unwrap();
    let mut outputs = Vec::new();
    for rx in rxs {
        outputs.push(rx.recv().unwrap().unwrap());
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.coalesced, 0, "sdpa_bias must never stack: {}", metrics.render());
    assert_eq!(metrics.executions, 5, "every sdpa_bias request executes alone");
    // same inputs -> same bits, and all correct vs the oracle
    let expected = exec::reference::sdpa_bias(&base[0], &base[1], &base[2], &bias).unwrap();
    for resp in &outputs {
        assert_eq!(resp.outputs[0], outputs[0].outputs[0]);
        assert!(resp.outputs[0].max_abs_diff(&expected).unwrap() <= TOL);
    }
    coordinator.shutdown();
}

#[test]
fn sdpa_serves_end_to_end_with_plan_cache_hits() {
    // the acceptance path: declared only through kernel::make, served by
    // the coordinator with a plan-cache hit on the second same-shape
    // request, bit-identical across hits, 1e-3 of the f64 oracle
    let coordinator =
        Coordinator::start(Arc::new(Manifest::builtin()), CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(2030);
    let inputs = qkv(1, 4, 100, 32, &mut rng);
    let first =
        coordinator.submit("sdpa", "nt", inputs.clone()).unwrap().recv().unwrap().unwrap();
    assert_eq!(first.backend, "native");
    let expected = exec::reference::sdpa(&inputs[0], &inputs[1], &inputs[2]).unwrap();
    let diff = first.outputs[0].max_abs_diff(&expected).unwrap();
    assert!(diff <= TOL, "served sdpa vs oracle: max|diff| = {diff}");
    let m1 = coordinator.metrics();
    assert_eq!((m1.plan_misses, m1.plan_hits), (1, 0), "first sdpa request compiles");
    let second =
        coordinator.submit("sdpa", "nt", inputs.clone()).unwrap().recv().unwrap().unwrap();
    let m2 = coordinator.metrics();
    assert_eq!((m2.plan_misses, m2.plan_hits), (1, 1), "same-shape sdpa request must hit");
    assert_eq!(first.outputs[0], second.outputs[0], "bit-identical across the cache hit");
    // admission rejects mismatched q/k/v before anything executes
    let bad = HostTensor::randn(vec![1, 4, 99, 32], &mut rng);
    assert!(coordinator
        .submit("sdpa", "nt", vec![inputs[0].clone(), bad, inputs[2].clone()])
        .is_err());
    coordinator.shutdown();
}
