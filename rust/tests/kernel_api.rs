//! Migration acceptance for the `kernel::make` API redesign.
//!
//! Before this redesign every native kernel was a hand-wired entry in a
//! static slice: bespoke shape-check, specializer, arity and coalesce
//! code per kernel.  These tests pin the migration:
//!
//! * the **pre-migration specializers** are ported verbatim below as an
//!   oracle, and every migrated builtin must produce **bit-identical**
//!   outputs through the `make`-derived path;
//! * the **derived shape preconditions** must accept/reject exactly the
//!   same shape sets as the old hand-written checks (property sweep);
//! * the **derived coalescibility** must keep non-row-independent
//!   kernels (mm, addmm, rope) out of the batcher's stacking path;
//! * **rope** — defined only through `make` — must serve end-to-end
//!   through the coordinator with plan-cache hits and golden-verified
//!   outputs, and a kernel registered at runtime must serve with zero
//!   additional wiring.

use std::collections::BTreeMap;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use anyhow::{bail, Result};
use ninetoothed_repro::arrange::catalog;
use ninetoothed_repro::coordinator::router::RouteKey;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig, Request, Router};
use ninetoothed_repro::exec::{
    self, BinOp, GridScheduler, Instr, ParamView, ReduceOp, TileProgram, UnaryOp,
};
use ninetoothed_repro::harness::golden::native_task_inputs;
use ninetoothed_repro::kernel::{self, dim, make, AppBuilder, Arrangement, Meta, TensorSpec};
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};
use ninetoothed_repro::tensor::SymTensor;

// ===========================================================================
// The pre-migration native catalog, ported verbatim from the hand-wired
// `exec/native.rs` that `kernel::make` replaced.  This is the oracle the
// migrated definitions are pinned against — do not "improve" it.
// ===========================================================================

struct OldSpec {
    views: Vec<ParamView>,
    output_shapes: Vec<Vec<usize>>,
}

fn bind(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

fn bind_sizes(bindings: &mut BTreeMap<String, i64>, name: &str, shape: &[usize]) {
    for (d, &s) in shape.iter().enumerate() {
        bindings.insert(format!("{name}_size_{d}"), s as i64);
    }
}

fn elementwise_block(n: usize) -> i64 {
    (n.next_power_of_two() as i64).min(4096)
}

const MM_BLOCK: i64 = 32;

fn mm_blocks(m: usize, k: usize, n: usize) -> (i64, i64, i64) {
    if m.max(n).max(k) <= 128 {
        (MM_BLOCK, MM_BLOCK, MM_BLOCK)
    } else {
        (64, 64, k.min(256) as i64)
    }
}

fn build_spec(
    tensors: &[SymTensor],
    bindings: &BTreeMap<String, i64>,
    shapes: &[&[usize]],
    is_output: &[bool],
    pad_values: &[f32],
) -> Result<OldSpec> {
    let mut views = Vec::new();
    for (((t, shape), &out), &pad) in tensors.iter().zip(shapes).zip(is_output).zip(pad_values) {
        views.push(ParamView::specialize(t, bindings, shape, out, pad)?);
    }
    let output_shapes = views
        .iter()
        .zip(shapes)
        .filter(|(v, _)| v.is_output)
        .map(|(_, s)| s.to_vec())
        .collect();
    Ok(OldSpec { views, output_shapes })
}

fn check_add(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 1 || a != b {
        bail!("add expects two equal 1-D tensors, got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_1d(shapes: &[&[usize]]) -> Result<()> {
    if shapes[0].len() != 1 {
        bail!("expected a 1-D tensor, got {:?}", shapes[0]);
    }
    Ok(())
}

fn check_2d(shapes: &[&[usize]]) -> Result<()> {
    if shapes[0].len() != 2 {
        bail!("expected a 2-D tensor, got {:?}", shapes[0]);
    }
    Ok(())
}

fn check_mm(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
        bail!("mm expects [m,k] x [k,n], got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_bmm(shapes: &[&[usize]]) -> Result<()> {
    let (a, b) = (shapes[0], shapes[1]);
    if a.len() != 3 || b.len() != 3 || a[0] != b[0] || a[2] != b[1] {
        bail!("bmm expects [b,m,k] x [b,k,n], got {a:?} and {b:?}");
    }
    Ok(())
}

fn check_addmm(shapes: &[&[usize]]) -> Result<()> {
    let (bias, a, b) = (shapes[0], shapes[1], shapes[2]);
    if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
        bail!("addmm expects mat1 [m,k] x mat2 [k,n], got {a:?} and {b:?}");
    }
    let (m, n) = (a[0], b[1]);
    let broadcastable = match bias.len() {
        1 => bias[0] == n,
        2 => (bias[0] == 1 || bias[0] == m) && bias[1] == n,
        _ => false,
    };
    if !broadcastable {
        bail!("addmm bias {bias:?} does not broadcast to the [{m}, {n}] output");
    }
    Ok(())
}

fn spec_add(shapes: &[&[usize]]) -> Result<OldSpec> {
    check_add(shapes)?;
    let a = shapes[0];
    let n = a[0];
    let tensors = catalog::add()?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(n))]);
    for name in ["input", "other", "output"] {
        bind_sizes(&mut bindings, name, a);
    }
    build_spec(&tensors, &bindings, &[a, a, a], &[false, false, true], &[0.0, 0.0, 0.0])
}

fn spec_silu(shapes: &[&[usize]]) -> Result<OldSpec> {
    check_1d(shapes)?;
    let a = shapes[0];
    let tensors = catalog::elementwise_1d(&["input", "output"])?;
    let mut bindings = bind(&[("BLOCK_SIZE", elementwise_block(a[0]))]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "output", a);
    build_spec(&tensors, &bindings, &[a, a], &[false, true], &[0.0, 0.0])
}

fn spec_rowwise(pad: f32, shapes: &[&[usize]]) -> Result<OldSpec> {
    check_2d(shapes)?;
    let a = shapes[0];
    let tensors = catalog::rowwise()?;
    let mut bindings = BTreeMap::new();
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "output", a);
    build_spec(&tensors, &bindings, &[a, a], &[false, true], &[pad, 0.0])
}

fn spec_mm(shapes: &[&[usize]]) -> Result<OldSpec> {
    check_mm(shapes)?;
    let (a, b) = (shapes[0], shapes[1]);
    let out = vec![a[0], b[1]];
    let tensors = catalog::mm()?;
    let (bm, bn, bk) = mm_blocks(a[0], a[1], b[1]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(&tensors, &bindings, &[a, b, &out], &[false, false, true], &[0.0, 0.0, 0.0])
}

fn spec_bmm(shapes: &[&[usize]]) -> Result<OldSpec> {
    check_bmm(shapes)?;
    let (a, b) = (shapes[0], shapes[1]);
    let out = vec![a[0], a[1], b[2]];
    let tensors = catalog::bmm()?;
    let (bm, bn, bk) = mm_blocks(a[1], a[2], b[2]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(&tensors, &bindings, &[a, b, &out], &[false, false, true], &[0.0, 0.0, 0.0])
}

fn spec_addmm(shapes: &[&[usize]]) -> Result<OldSpec> {
    check_addmm(shapes)?;
    let (bias, a, b) = (shapes[0], shapes[1], shapes[2]);
    let out = vec![a[0], b[1]];
    let bias2d: Vec<usize> = if bias.len() == 1 { vec![1, bias[0]] } else { bias.to_vec() };
    let row_bias = bias2d[0] == 1;
    let tensors = catalog::addmm(row_bias)?;
    let (bm, bn, bk) = mm_blocks(a[0], a[1], b[1]);
    let mut bindings = bind(&[("BLOCK_SIZE_M", bm), ("BLOCK_SIZE_N", bn), ("BLOCK_SIZE_K", bk)]);
    bind_sizes(&mut bindings, "bias", &bias2d);
    bind_sizes(&mut bindings, "input", a);
    bind_sizes(&mut bindings, "other", b);
    bind_sizes(&mut bindings, "output", &out);
    build_spec(
        &tensors,
        &bindings,
        &[&bias2d, a, b, &out],
        &[false, false, false, true],
        &[0.0, 0.0, 0.0, 0.0],
    )
}

fn program_add() -> TileProgram {
    TileProgram {
        name: "add",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Load { dst: 1, param: 1 },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Add },
            Instr::Store { param: 2, src: 2 },
        ],
    }
}

fn program_silu() -> TileProgram {
    TileProgram {
        name: "silu",
        regs: 3,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Unary { dst: 1, a: 0, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Mul },
            Instr::Store { param: 1, src: 2 },
        ],
    }
}

fn program_gelu() -> TileProgram {
    const TWO_SQRT_2_OVER_PI: f32 = 1.595_769_1;
    const CUBIC: f32 = 0.044_715;
    TileProgram {
        name: "gelu",
        regs: 10,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Binary { dst: 2, a: 1, b: 0, op: BinOp::Mul },
            Instr::Const { dst: 3, value: CUBIC },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Mul },
            Instr::Binary { dst: 5, a: 0, b: 4, op: BinOp::Add },
            Instr::Const { dst: 6, value: TWO_SQRT_2_OVER_PI },
            Instr::Binary { dst: 7, a: 5, b: 6, op: BinOp::Mul },
            Instr::Unary { dst: 8, a: 7, op: UnaryOp::Sigmoid },
            Instr::Binary { dst: 9, a: 0, b: 8, op: BinOp::Mul },
            Instr::Store { param: 1, src: 9 },
        ],
    }
}

fn program_softmax() -> TileProgram {
    TileProgram {
        name: "softmax",
        regs: 6,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Max },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Unary { dst: 3, a: 2, op: UnaryOp::Exp },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Sum },
            Instr::Binary { dst: 5, a: 3, b: 4, op: BinOp::Div },
            Instr::Store { param: 1, src: 5 },
        ],
    }
}

fn program_rms_norm() -> TileProgram {
    TileProgram {
        name: "rms_norm",
        regs: 7,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Binary { dst: 1, a: 0, b: 0, op: BinOp::Mul },
            Instr::Reduce { dst: 2, a: 1, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 3, value: 1e-6 },
            Instr::Binary { dst: 4, a: 2, b: 3, op: BinOp::Add },
            Instr::Unary { dst: 5, a: 4, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 6, a: 0, b: 5, op: BinOp::Mul },
            Instr::Store { param: 1, src: 6 },
        ],
    }
}

fn program_layer_norm() -> TileProgram {
    TileProgram {
        name: "layer_norm",
        regs: 9,
        instrs: vec![
            Instr::Load { dst: 0, param: 0 },
            Instr::Reduce { dst: 1, a: 0, axis: None, op: ReduceOp::Mean },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Sub },
            Instr::Binary { dst: 3, a: 2, b: 2, op: BinOp::Mul },
            Instr::Reduce { dst: 4, a: 3, axis: None, op: ReduceOp::Mean },
            Instr::Const { dst: 5, value: 1e-6 },
            Instr::Binary { dst: 6, a: 4, b: 5, op: BinOp::Add },
            Instr::Unary { dst: 7, a: 6, op: UnaryOp::Rsqrt },
            Instr::Binary { dst: 8, a: 2, b: 7, op: BinOp::Mul },
            Instr::Store { param: 1, src: 8 },
        ],
    }
}

// NOTE: the IR's `Loop` now requires its carried registers to be declared
// (the implicit-persistence special case was deleted); the accumulator
// carry below is the only change from the pre-migration originals — the
// executed computation is identical, which the bitwise assertions prove.
fn program_matmul(name: &'static str) -> TileProgram {
    TileProgram {
        name,
        regs: 1,
        instrs: vec![
            Instr::Zeros { dst: 0, like_param: 2 },
            Instr::Loop {
                carried: vec![0],
                body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
            },
            Instr::Store { param: 2, src: 0 },
        ],
    }
}

fn program_addmm() -> TileProgram {
    TileProgram {
        name: "addmm",
        regs: 3,
        instrs: vec![
            Instr::Zeros { dst: 0, like_param: 3 },
            Instr::Loop {
                carried: vec![0],
                body: vec![Instr::DotAcc { acc: 0, a_param: 1, b_param: 2 }],
            },
            Instr::Load { dst: 1, param: 0 },
            Instr::Binary { dst: 2, a: 0, b: 1, op: BinOp::Add },
            Instr::Store { param: 3, src: 2 },
        ],
    }
}

/// The nine pre-migration builtins.
const OLD_KERNELS: &[&str] =
    &["add", "silu", "gelu", "softmax", "rms_norm", "layer_norm", "mm", "bmm", "addmm"];

fn old_compile(name: &str, shapes: &[&[usize]]) -> Result<(TileProgram, OldSpec)> {
    Ok(match name {
        "add" => (program_add(), spec_add(shapes)?),
        "silu" => (program_silu(), spec_silu(shapes)?),
        "gelu" => (program_gelu(), spec_silu(shapes)?),
        "softmax" => (program_softmax(), spec_rowwise(f32::NEG_INFINITY, shapes)?),
        "rms_norm" => (program_rms_norm(), spec_rowwise(0.0, shapes)?),
        "layer_norm" => (program_layer_norm(), spec_rowwise(0.0, shapes)?),
        "mm" => (program_matmul("mm"), spec_mm(shapes)?),
        "bmm" => (program_matmul("bmm"), spec_bmm(shapes)?),
        "addmm" => (program_addmm(), spec_addmm(shapes)?),
        other => bail!("no pre-migration oracle for {other}"),
    })
}

/// Execute through the ported pre-migration path (serial, like-for-like
/// with the bit-deterministic scheduler).
fn old_run(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
    let (program, spec) = old_compile(name, &shapes)?;
    let refs: Vec<&HostTensor> = inputs.iter().collect();
    GridScheduler::serial().run(&program, &spec.views, &refs, &spec.output_shapes)
}

/// The old `NativeKernel::check_shapes`: arity, rank-0 / zero-length, and
/// the hand-written per-kernel precondition.
fn old_check_shapes(name: &str, shapes: &[&[usize]]) -> Result<()> {
    let (arity, check): (usize, fn(&[&[usize]]) -> Result<()>) = match name {
        "add" => (2, check_add),
        "silu" | "gelu" => (1, check_1d),
        "softmax" | "rms_norm" | "layer_norm" => (1, check_2d),
        "mm" => (2, check_mm),
        "bmm" => (2, check_bmm),
        "addmm" => (3, check_addmm),
        other => bail!("no pre-migration checks for {other}"),
    };
    if shapes.len() != arity {
        bail!("expected {arity} inputs, got {}", shapes.len());
    }
    for s in shapes {
        if s.is_empty() {
            bail!("rank-0 input");
        }
        if s.iter().any(|&d| d == 0) {
            bail!("zero-length dimension");
        }
    }
    check(shapes)
}

// ===========================================================================
// the acceptance tests
// ===========================================================================

#[test]
fn migrated_builtins_are_bit_identical_to_the_pre_migration_specializers() {
    let mut rng = SplitMix64::new(2025);
    let sched = GridScheduler::serial();
    for name in OLD_KERNELS {
        let inputs = native_task_inputs(name, &mut rng).unwrap();
        let old = old_run(name, &inputs).unwrap();
        let new = kernel::lookup(name).unwrap().run(&inputs, &sched).unwrap();
        assert_eq!(old.len(), new.len());
        for (o, n) in old.iter().zip(&new) {
            assert_eq!(o, n, "{name}: make-derived path must match pre-migration bitwise");
        }
    }
    // addmm across every admitted bias rank (the arrangement-variant path)
    let addmm = kernel::lookup("addmm").unwrap();
    let a = HostTensor::randn(vec![33, 21], &mut rng);
    let b = HostTensor::randn(vec![21, 17], &mut rng);
    for bias_shape in [vec![17usize], vec![1, 17], vec![33, 17]] {
        let bias = HostTensor::randn(bias_shape.clone(), &mut rng);
        let inputs = vec![bias, a.clone(), b.clone()];
        let old = old_run("addmm", &inputs).unwrap();
        let new = addmm.run(&inputs, &sched).unwrap();
        assert_eq!(old[0], new[0], "addmm bias {bias_shape:?}: bitwise mismatch");
    }
}

fn random_shape(rng: &mut SplitMix64, max_rank: usize) -> Vec<usize> {
    let rank = rng.below(max_rank as u64 + 1) as usize;
    (0..rank).map(|_| rng.below(6) as usize).collect()
}

#[test]
fn derived_preconditions_match_the_old_hand_written_checks() {
    let mut rng = SplitMix64::new(7);
    for name in OLD_KERNELS {
        let def = kernel::lookup(name).unwrap();
        // adversarial sweep: random ranks (0..=4), random dims (0..=5,
        // zero-length included), arity-1 ..= arity+1 argument counts
        for _ in 0..400 {
            let count = (def.arity + rng.below(3) as usize).saturating_sub(1);
            let shapes: Vec<Vec<usize>> = (0..count).map(|_| random_shape(&mut rng, 4)).collect();
            let refs: Vec<&[usize]> = shapes.iter().map(|s| s.as_slice()).collect();
            let old_ok = old_check_shapes(name, &refs).is_ok();
            let new_ok = def.check_shapes(&refs).is_ok();
            assert_eq!(old_ok, new_ok, "{name}: precondition divergence on {shapes:?}");
        }
        // and the known-good shapes are accepted by both
        let inputs = native_task_inputs(name, &mut rng).unwrap();
        let refs: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        assert!(old_check_shapes(name, &refs).is_ok());
        assert!(def.check_shapes(&refs).is_ok(), "{name}: valid shapes rejected");
    }
}

fn admit(router: &Router, name: &str, inputs: Vec<HostTensor>) -> RouteKey {
    let (tx, _rx) = mpsc::channel();
    std::mem::forget(_rx);
    let shape_sig = {
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        ninetoothed_repro::obs::shape_sig(&shapes)
    };
    let req = Request {
        kernel: name.to_string(),
        variant: "nt".to_string(),
        inputs,
        submitted: Instant::now(),
        shape_sig,
        sampled: false,
        reply: tx,
    };
    router.admit(&req).unwrap()
}

#[test]
fn non_row_independent_kernels_are_never_coalesced() {
    // the flag is derived at definition time, not asserted by hand
    for (name, want) in [
        ("add", true),
        ("silu", true),
        ("gelu", true),
        ("softmax", true),
        ("rms_norm", true),
        ("layer_norm", true),
        // bmm stacks along its batch dim: every parameter shares it and
        // batches are independent — the derivation discovers this
        ("bmm", true),
        // ...and so does loop-carried sdpa: the online-softmax loop walks
        // the sequence dim, the carries live per program instance
        ("sdpa", true),
        // mm/addmm read `other` rows via the k loop; rope's cos/sin
        // tables and sdpa_bias's [s, s] score bias lack the stacking dim
        ("mm", false),
        ("addmm", false),
        ("rope", false),
        ("sdpa_bias", false),
    ] {
        assert_eq!(kernel::lookup(name).unwrap().coalesce, want, "{name}");
    }
    // and the router routes straight off the derived flag
    let router = Router::new(Arc::new(Manifest::builtin()));
    let mut rng = SplitMix64::new(5);
    for (name, want) in [
        ("softmax", true),
        ("bmm", true),
        ("sdpa", true),
        ("mm", false),
        ("rope", false),
        ("sdpa_bias", false),
    ] {
        let inputs = native_task_inputs(name, &mut rng).unwrap();
        let route = admit(&router, name, inputs);
        assert!(route.native, "{name} must route natively");
        assert_eq!(route.coalescible, want, "{name} route coalescibility");
    }
}

#[test]
fn rope_burst_is_never_fused_into_one_launch() {
    // regression for the satellite: a queued same-shape burst of a
    // non-row-independent kernel must execute one launch per request
    let coordinator = Coordinator::start(
        Arc::new(Manifest::builtin()),
        CoordinatorConfig { workers: 1, queue_capacity: 128, ..Default::default() },
    )
    .unwrap();
    let mut rng = SplitMix64::new(61);
    let a = HostTensor::randn(vec![192, 192], &mut rng);
    let b = HostTensor::randn(vec![192, 192], &mut rng);
    // head-of-line mm keeps the single worker busy so the rope burst queues
    let mm_rx = coordinator.submit("mm", "nt", vec![a, b]).unwrap();
    let cos = HostTensor::randn(vec![9, 8], &mut rng);
    let sin = HostTensor::randn(vec![9, 8], &mut rng);
    let mut rxs = Vec::new();
    for _ in 0..5 {
        let x = HostTensor::randn(vec![2, 9, 3, 16], &mut rng);
        rxs.push(coordinator.submit("rope", "nt", vec![x, cos.clone(), sin.clone()]).unwrap());
    }
    mm_rx.recv().unwrap().unwrap();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let metrics = coordinator.metrics();
    assert_eq!(metrics.coalesced, 0, "rope must never stack: {}", metrics.render());
    assert_eq!(metrics.executions, 6, "every rope request executes alone");
    coordinator.shutdown();
}

#[test]
fn rope_serves_end_to_end_through_the_coordinator() {
    // the API's proof: rope exists only as a `make` declaration, yet it
    // serves through admission, the plan cache and the native backend
    let coordinator =
        Coordinator::start(Arc::new(Manifest::builtin()), CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(71);
    let input = HostTensor::randn(vec![2, 9, 4, 32], &mut rng);
    let cos = HostTensor::randn(vec![9, 16], &mut rng);
    let sin = HostTensor::randn(vec![9, 16], &mut rng);
    let inputs = vec![input, cos, sin];
    let first =
        coordinator.submit("rope", "nt", inputs.clone()).unwrap().recv().unwrap().unwrap();
    assert_eq!(first.backend, "native");
    let expected = exec::reference::run("rope", &inputs).unwrap();
    let diff = first.outputs[0].max_abs_diff(&expected[0]).unwrap();
    assert!(diff <= 1e-4, "rope vs oracle: max|diff| = {diff}");
    let m1 = coordinator.metrics();
    assert_eq!((m1.plan_misses, m1.plan_hits), (1, 0), "first rope request compiles");
    let second =
        coordinator.submit("rope", "nt", inputs.clone()).unwrap().recv().unwrap().unwrap();
    let m2 = coordinator.metrics();
    assert_eq!((m2.plan_misses, m2.plan_hits), (1, 1), "same-shape rope request must hit");
    assert_eq!(first.outputs[0], second.outputs[0], "bit-identical across cache hit");
    // derived preconditions reject at admission: odd head dim, wrong table
    let odd = HostTensor::randn(vec![2, 9, 4, 31], &mut rng);
    assert!(coordinator
        .submit("rope", "nt", vec![odd, inputs[1].clone(), inputs[2].clone()])
        .is_err());
    let bad_cos = HostTensor::randn(vec![9, 15], &mut rng);
    assert!(coordinator
        .submit("rope", "nt", vec![inputs[0].clone(), bad_cos, inputs[2].clone()])
        .is_err());
    coordinator.shutdown();
}

#[test]
fn runtime_registered_kernel_serves_with_zero_additional_wiring() {
    // declare y = 3x through the public API, register it, and serve it
    // through a coordinator that has no special knowledge of it
    let arrangement = Arrangement::new(
        "1-D element-wise: BLOCK_SIZE tiles",
        |_| catalog::elementwise_1d(&["input", "output"]),
    )
    .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" });
    let mut app = AppBuilder::new("scale3");
    let x = app.load(0);
    let three = app.constant(3.0);
    let y = app.binary(x, three, BinOp::Mul);
    app.store(1, y);
    let def = make(
        arrangement,
        app.build(),
        vec![
            TensorSpec::input("input", vec![dim("n", 11)]),
            TensorSpec::output("output", vec![dim("n", 11)]),
        ],
    )
    .unwrap();
    assert!(def.coalesce, "element-wise kernels derive as coalescible");
    kernel::registry().register(def).unwrap();

    let coordinator =
        Coordinator::start(Arc::new(Manifest::builtin()), CoordinatorConfig::default()).unwrap();
    let mut rng = SplitMix64::new(81);
    let x = HostTensor::randn(vec![1234], &mut rng);
    let rx = coordinator.submit("scale3", "nt", vec![x.clone()]).unwrap();
    let resp = rx.recv().unwrap().unwrap();
    assert_eq!(resp.backend, "native");
    let got = resp.outputs[0].as_f32().unwrap();
    for (g, w) in got.iter().zip(x.as_f32().unwrap()) {
        assert!((g - 3.0 * w).abs() < 1e-6);
    }
    coordinator.shutdown();
}
