//! Arrangement explorer: derive the paper's arrangements with the Rust
//! algebra mirror and print their hierarchy, index expressions, grids and
//! padded extents for a chosen problem size — a debugging/teaching tool
//! for the tensor-oriented metaprogramming model.
//!
//! ```bash
//! cargo run --release --example arrangement_explorer -- mm --m 70 --k 50 --n 90
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Result};
use ninetoothed_repro::arrange::catalog;
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::tensor::SymTensor;

fn show(tensors: &[SymTensor], bindings: &BTreeMap<String, i64>) -> Result<()> {
    for t in tensors {
        println!("parameter {}:", t.name);
        for (i, level) in t.levels.iter().enumerate() {
            let sizes: Vec<String> = level.iter().map(|d| d.size.to_string()).collect();
            let label = match i {
                0 => "outermost (tile-to-program)",
                _ if i + 1 == t.levels.len() => "innermost (application tile)",
                _ => "loop level",
            };
            println!("  level {i} [{label}]: ({})", sizes.join(", "));
        }
        for (d, expr) in t.indices.iter().enumerate() {
            println!("  source dim {d} <- {expr}");
        }
        let grid = t.grid(bindings)?;
        let extents = t.padded_extents(bindings)?;
        println!("  grid contribution: {grid:?}; padded extents: {extents:?}");
    }
    let (grid, _) = catalog::geometry(tensors, bindings)?;
    let programs: i64 = grid.iter().product();
    println!("\ntile-to-program mapping: grid {grid:?} -> {programs} programs");
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let kernel = args.command.clone().unwrap_or_else(|| "mm".to_string());
    let block = args.opt_usize("block", 32) as i64;

    let mut bindings: BTreeMap<String, i64> = BTreeMap::new();
    for key in ["BLOCK_SIZE", "BLOCK_SIZE_M", "BLOCK_SIZE_N", "BLOCK_SIZE_K"] {
        bindings.insert(key.to_string(), block);
    }

    let tensors = match kernel.as_str() {
        "add" => {
            let n = args.opt_usize("n", 4097) as i64;
            for t in ["input", "other", "output"] {
                bindings.insert(format!("{t}_size_0"), n);
            }
            catalog::add()?
        }
        "mm" => {
            let (m, k, n) = (
                args.opt_usize("m", 70) as i64,
                args.opt_usize("k", 50) as i64,
                args.opt_usize("n", 90) as i64,
            );
            for (key, value) in [
                ("input_size_0", m), ("input_size_1", k),
                ("other_size_0", k), ("other_size_1", n),
                ("output_size_0", m), ("output_size_1", n),
            ] {
                bindings.insert(key.to_string(), value);
            }
            catalog::mm()?
        }
        "conv2d" => {
            let (n, c, h, w) = (2i64, 3, 12, 12);
            let (k, r, s) = (4i64, 3, 3);
            for (key, value) in [
                ("input_size_0", n), ("input_size_1", c), ("input_size_2", h), ("input_size_3", w),
                ("filter_size_0", k), ("filter_size_1", c), ("filter_size_2", r), ("filter_size_3", s),
                ("output_size_0", n), ("output_size_1", k),
                ("output_size_2", h - r + 1), ("output_size_3", w - s + 1),
            ] {
                bindings.insert(key.to_string(), value);
            }
            catalog::conv2d()?
        }
        "bmm" => {
            let (b, m, k, n) = (
                args.opt_usize("b", 3) as i64,
                args.opt_usize("m", 70) as i64,
                args.opt_usize("k", 50) as i64,
                args.opt_usize("n", 90) as i64,
            );
            for (key, value) in [
                ("input_size_0", b), ("input_size_1", m), ("input_size_2", k),
                ("other_size_0", b), ("other_size_1", k), ("other_size_2", n),
                ("output_size_0", b), ("output_size_1", m), ("output_size_2", n),
            ] {
                bindings.insert(key.to_string(), value);
            }
            catalog::bmm()?
        }
        "sdpa" => {
            let (b, h, s, d) = (2i64, 4, 128, 32);
            for t in ["query", "key", "value", "output"] {
                for (i, v) in [b, h, s, d].iter().enumerate() {
                    bindings.insert(format!("{t}_size_{i}"), *v);
                }
            }
            catalog::sdpa(false)?
        }
        other => bail!("unknown arrangement {other:?} (try add, mm, bmm, conv2d, sdpa)"),
    };

    println!("=== {kernel} arrangement (block = {block}) ===\n");
    show(&tensors, &bindings)
}
