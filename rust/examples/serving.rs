//! Serving example: start the coordinator, submit a bursty mixed workload
//! from several client threads, and observe routing, slot-packed batching,
//! backpressure and the observability layer (per-kernel stats table and
//! the slowest traced requests, printed at exit).
//!
//! ```bash
//! cargo run --release --example serving -- --workers 2 --clients 4
//! ```

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let workers = args.opt_usize("workers", 2);
    let clients = args.opt_usize("clients", 4);
    let per_client = args.opt_usize("requests", 12);

    // with artifacts the add kernel has a fixed packing slot; natively any
    // length works — use the artifact slot when present, 64k otherwise
    let manifest = Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()));
    let slot = manifest
        .kernel("add", "nt")
        .map(|a| a.args[0].shape[0])
        .unwrap_or(65536);
    let coordinator = Arc::new(Coordinator::start(
        manifest.clone(),
        CoordinatorConfig { workers, queue_capacity: 256, max_fanin: 16, ..Default::default() },
    )?);

    // warm the per-worker compile caches
    let mut rng = SplitMix64::new(0);
    let warm = HostTensor::randn(vec![slot], &mut rng);
    for _ in 0..workers {
        coordinator
            .submit("add", "nt", vec![warm.clone(), warm.clone()])?
            .recv()??;
    }

    println!("{clients} clients x {per_client} requests, slot = {slot}");
    let mut handles = Vec::new();
    for client in 0..clients {
        let coordinator = coordinator.clone();
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let mut rng = SplitMix64::new(100 + client as u64);
            let mut ok = 0;
            for _ in 0..per_client {
                let n = 512 + rng.below((slot / 4) as u64) as usize;
                let x = HostTensor::randn(vec![n], &mut rng);
                let y = HostTensor::randn(vec![n], &mut rng);
                // verify the response on the client side
                let expect: Vec<f32> = x
                    .as_f32()?
                    .iter()
                    .zip(y.as_f32()?)
                    .map(|(a, b)| a + b)
                    .collect();
                let rx = coordinator.submit("add", "nt", vec![x, y])?;
                let resp = rx.recv()??;
                let got = resp.outputs[0].as_f32()?;
                anyhow::ensure!(got.len() == n, "length mismatch");
                let max_diff = got
                    .iter()
                    .zip(&expect)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0f32, f32::max);
                anyhow::ensure!(max_diff < 1e-5, "bad result: {max_diff}");
                ok += 1;
            }
            Ok(ok)
        }));
    }
    let mut total = 0;
    for handle in handles {
        total += handle.join().expect("client thread")?;
    }
    println!("all {total} responses verified element-exact");
    // per-kernel/per-shape stats table (includes the global section)
    print!("{}", coordinator.obs_snapshot().render_table());
    // and the top-3 slowest sampled traces as a span waterfall
    let slowest = coordinator.obs().traces.slowest(3);
    if !slowest.is_empty() {
        println!("top-{} slowest requests:", slowest.len());
        print!("{}", ninetoothed_repro::obs::render_waterfall(&slowest));
    }
    Ok(())
}
