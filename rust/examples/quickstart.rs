//! Quickstart: resolve a kernel through the registry and run it — via its
//! AOT artifact when `make artifacts` ran on a PJRT-enabled machine, via
//! the native tile-execution backend otherwise (no setup needed).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{Backend, HostTensor, Manifest, Registry};

fn main() -> Result<()> {
    let manifest = Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()));
    let registry = Registry::auto(manifest.clone());
    println!(
        "artifacts: {} kernels; PJRT runtime: {}",
        manifest.kernels.len(),
        if registry.runtime().is_some() { "yes" } else { "no (native fallback)" }
    );

    // the paper's Listing 3 kernel: shape taken from the artifact when one
    // exists, arbitrary otherwise (native kernels are shape-polymorphic)
    let n = manifest.kernel("add", "nt").map(|a| a.args[0].shape[0]).unwrap_or(5000);
    let mut rng = SplitMix64::new(1);
    let x = HostTensor::randn(vec![n], &mut rng);
    let y = HostTensor::randn(vec![n], &mut rng);

    let nt = registry.resolve("add", "nt")?;
    println!("add.nt resolves to {} ({})", nt.name(), nt.kind().as_str());
    let outputs = nt.run(&[x.clone(), y.clone()])?;

    // compare against the reference backend
    let reference = registry.resolve("add", "ref")?;
    let expected = reference.run(&[x, y])?;
    let diff = outputs[0].max_abs_diff(&expected[0])?;
    println!("max |nt - ref| = {diff:.3e}");
    assert!(diff < 1e-5);

    // matrix multiplication (Listings 5-7)
    let (m, k, n2) = match manifest.kernel("mm", "nt") {
        Ok(art) => (art.args[0].shape[0], art.args[0].shape[1], art.args[1].shape[1]),
        Err(_) => (70, 50, 90),
    };
    println!("mm: ({m}x{k}) @ ({k}x{n2})");
    let a = HostTensor::randn(vec![m, k], &mut rng);
    let b = HostTensor::randn(vec![k, n2], &mut rng);
    let mm = registry.resolve("mm", "nt")?;
    let mm_ref = registry.resolve("mm", "ref")?;
    let got = mm.run(&[a.clone(), b.clone()])?;
    let want = mm_ref.run(&[a, b])?;
    println!("max |nt - ref| = {:.3e}", got[0].max_abs_diff(&want[0])?);

    println!("quickstart OK");
    Ok(())
}
