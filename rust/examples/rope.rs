//! Rope, end to end, through the `kernel::make` API: the kernel exists
//! only as a declaration (arrangement + application + symbolic tensors),
//! yet admission, output inference, plan caching and execution all come
//! derived — no per-kernel wiring anywhere in the serving stack.
//!
//! ```bash
//! cargo run --release --example rope
//! ```

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::exec::{self, GridScheduler};
use ninetoothed_repro::kernel;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

fn main() -> Result<()> {
    let rope = kernel::lookup("rope").expect("rope is registered via kernel::make");
    println!(
        "rope: arity={} coalesce={} native={} — {}",
        rope.arity,
        rope.coalesce,
        rope.executable(),
        rope.arrangement.summary
    );

    // (batch, seq, heads, head_dim) activations + [seq, head_dim/2] tables
    let mut rng = SplitMix64::new(7);
    let input = HostTensor::randn(vec![2, 16, 4, 64], &mut rng);
    let cos = HostTensor::randn(vec![16, 32], &mut rng);
    let sin = HostTensor::randn(vec![16, 32], &mut rng);
    let inputs = vec![input, cos, sin];

    // direct execution: output shapes are inferred, never passed
    let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
    println!("inferred output shapes: {:?}", rope.output_shapes(&shapes)?);
    let direct = rope.run(&inputs, &GridScheduler::pooled(4))?;
    let oracle = exec::reference::run("rope", &inputs)?;
    println!("direct vs f64 oracle: max|diff| = {:.3e}", direct[0].max_abs_diff(&oracle[0])?);

    // served execution: same request twice — the second hits the plan cache
    let manifest = Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()));
    let coordinator = Coordinator::start(manifest, CoordinatorConfig::default())?;
    let first = coordinator.submit("rope", "nt", inputs.clone())?.recv()??;
    let second = coordinator.submit("rope", "nt", inputs.clone())?.recv()??;
    let metrics = coordinator.metrics();
    println!(
        "served twice via {} backend: plan misses={} hits={} (compile-once/execute-many)",
        first.backend, metrics.plan_misses, metrics.plan_hits
    );
    assert_eq!(first.outputs[0], second.outputs[0], "bit-identical across the cache hit");
    assert!(first.outputs[0].max_abs_diff(&oracle[0])? <= 1e-4);
    coordinator.shutdown();
    println!("rope OK");
    Ok(())
}
