//! Wire-protocol client example: drive a running `repro serve --addr`
//! server over TCP with a mixed kernel burst, then scrape and sanity-check
//! the Prometheus stats exposition.  The CI serving-smoke step runs
//! exactly this pair:
//!
//! ```bash
//! cargo run --release -- serve --addr 127.0.0.1:7071 &
//! cargo run --release --example client -- --addr 127.0.0.1:7071 --shutdown
//! ```
//!
//! Every submit carries a `trace_id` (and, with `--client-id NAME`, a
//! tenant identity); the server echoes a per-span breakdown in each
//! reply, rendered for the first round and checked for consistency
//! (span durations must fit inside the server's own total).
//!
//! `--dump-prom PATH` writes the scraped Prometheus exposition to a file
//! (CI greps it for `nt_slo_` series); `--shutdown` asks the server to
//! drain and exit after the burst.

use std::time::Duration;

use anyhow::{ensure, Result};
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::coordinator::net::{Client, TraceBreakdown};
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::HostTensor;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7071").to_string();
    let rounds = args.opt_usize("rounds", 4);

    // the server may still be binding (CI starts it in the background)
    let mut client = Client::connect_with_retry(&addr, Duration::from_secs(10))?;
    if let Some(client_id) = args.opt("client-id") {
        client.set_client_id(client_id);
    }

    let health = client.health()?;
    println!(
        "connected to {addr}: protocol v{}, {} kernels, {} workers, queue {} (shed at {})",
        health.usize("protocol")?,
        health.usize("kernels")?,
        health.usize("workers")?,
        health.usize("queue_capacity")?,
        health.usize("shed_watermark")?,
    );

    // a mixed burst: elementwise (coalescible), matmul, rowwise softmax and
    // flash-style attention all through the same four-byte-prefix frames
    let mut rng = SplitMix64::new(42);
    let mut completed = 0;
    let mut traced = 0;
    for round in 0..rounds {
        let x = HostTensor::randn(vec![1000], &mut rng);
        let y = HostTensor::randn(vec![1000], &mut rng);
        // verify the elementwise result client-side
        let expect: Vec<f32> = x.as_f32()?.iter().zip(y.as_f32()?).map(|(a, b)| a + b).collect();
        let trace_id = format!("burst-{round}-add");
        let reply = client.submit_traced("add", "nt", &[x, y], Some(&trace_id))?;
        ensure!(
            reply.outputs[0].as_f32()? == expect.as_slice(),
            "add result differs from the client-side sum"
        );
        check_breakdown(&trace_id, reply.trace.as_ref(), round == 0)?;
        completed += 1;
        traced += 1;

        for (kernel, inputs, out_shape) in [
            (
                "mm",
                vec![
                    HostTensor::randn(vec![70, 50], &mut rng),
                    HostTensor::randn(vec![50, 90], &mut rng),
                ],
                vec![70, 90],
            ),
            ("softmax", vec![HostTensor::randn(vec![7, 301], &mut rng)], vec![7, 301]),
            (
                "sdpa",
                vec![
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                ],
                vec![2, 2, 100, 16],
            ),
        ] {
            let trace_id = format!("burst-{round}-{kernel}");
            let reply = client.submit_traced(kernel, "nt", &inputs, Some(&trace_id))?;
            ensure!(
                reply.outputs[0].shape == out_shape,
                "{kernel} output shape {:?} != {out_shape:?}",
                reply.outputs[0].shape
            );
            if round == 0 {
                println!(
                    "  {kernel}: backend={} batch={} queue={}µs exec={}µs",
                    reply.backend, reply.batch_size, reply.queue_us, reply.exec_us
                );
            }
            check_breakdown(&trace_id, reply.trace.as_ref(), round == 0)?;
            completed += 1;
            traced += 1;
        }
    }
    println!(
        "burst complete: {completed} requests verified over the wire, \
         {traced} with consistent span breakdowns"
    );

    // scrape the server-side metrics and sanity-check the exposition
    let prom = client.stats_prometheus()?;
    let submitted = prom
        .lines()
        .find(|l| l.starts_with("nt_requests_total{event=\"submitted\"}"))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse::<u64>().ok())
        .ok_or_else(|| anyhow::anyhow!("no submitted counter in the exposition"))?;
    ensure!(
        submitted >= completed,
        "server saw {submitted} submits, client completed {completed}"
    );
    ensure!(
        prom.contains("# TYPE nt_request_latency_us histogram"),
        "latency histogram missing from the exposition"
    );
    println!("stats scrape OK: server counted {submitted} submitted requests");
    if let Some(path) = args.opt("dump-prom") {
        std::fs::write(path, &prom)?;
        println!("prometheus exposition written to {path}");
    }

    if args.flag("shutdown") {
        client.shutdown_server()?;
        println!("server draining");
    }
    Ok(())
}

/// Validate one echoed breakdown: the trace id round-trips, a `net_read`
/// span is present (the request was wire-originated), and the span
/// durations are consistent with the server's own total — they must not
/// exceed it, and the only un-spanned gap (batch-end to plan-start) must
/// stay a small fraction of it.
fn check_breakdown(trace_id: &str, trace: Option<&TraceBreakdown>, render: bool) -> Result<()> {
    let trace = trace
        .ok_or_else(|| anyhow::anyhow!("submit {trace_id} returned no span breakdown"))?;
    ensure!(
        trace.trace_id.as_deref() == Some(trace_id),
        "trace id {:?} did not round-trip (sent {trace_id:?})",
        trace.trace_id
    );
    ensure!(
        trace.spans.iter().any(|(kind, _)| kind == "net_read"),
        "breakdown for {trace_id} has no net_read span: {:?}",
        trace.spans
    );
    if render {
        let rendered: Vec<String> =
            trace.spans.iter().map(|(kind, us)| format!("{kind}={us}µs")).collect();
        println!("    trace {trace_id}: total={}µs [{}]", trace.total_us, rendered.join(" "));
    }
    let span_sum: u64 = trace.spans.iter().map(|(_, us)| us).sum();
    if trace.total_us > 0 {
        ensure!(
            span_sum <= trace.total_us,
            "span sum {span_sum}µs exceeds server total {}µs for {trace_id}",
            trace.total_us
        );
        let gap = trace.total_us - span_sum;
        ensure!(
            gap <= trace.total_us / 4 + 1000,
            "unaccounted {gap}µs of {}µs for {trace_id} (spans {:?})",
            trace.total_us,
            trace.spans
        );
    }
    Ok(())
}
