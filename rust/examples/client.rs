//! Wire-protocol client example: drive a running `repro serve --addr`
//! server over TCP with a mixed kernel burst, then scrape and sanity-check
//! the Prometheus stats exposition.  The CI serving-smoke step runs
//! exactly this pair:
//!
//! ```bash
//! cargo run --release -- serve --addr 127.0.0.1:7071 &
//! cargo run --release --example client -- --addr 127.0.0.1:7071 --shutdown
//! ```
//!
//! `--shutdown` asks the server to drain and exit after the burst (the
//! serve process prints its final stats table and returns).

use std::time::Duration;

use anyhow::{ensure, Result};
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::coordinator::net::Client;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::HostTensor;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let addr = args.opt("addr").unwrap_or("127.0.0.1:7071").to_string();
    let rounds = args.opt_usize("rounds", 4);

    // the server may still be binding (CI starts it in the background)
    let mut client = Client::connect_with_retry(&addr, Duration::from_secs(10))?;

    let health = client.health()?;
    println!(
        "connected to {addr}: protocol v{}, {} kernels, {} workers, queue {} (shed at {})",
        health.usize("protocol")?,
        health.usize("kernels")?,
        health.usize("workers")?,
        health.usize("queue_capacity")?,
        health.usize("shed_watermark")?,
    );

    // a mixed burst: elementwise (coalescible), matmul, rowwise softmax and
    // flash-style attention all through the same four-byte-prefix frames
    let mut rng = SplitMix64::new(42);
    let mut completed = 0;
    for round in 0..rounds {
        let x = HostTensor::randn(vec![1000], &mut rng);
        let y = HostTensor::randn(vec![1000], &mut rng);
        // verify the elementwise result client-side
        let expect: Vec<f32> = x.as_f32()?.iter().zip(y.as_f32()?).map(|(a, b)| a + b).collect();
        let reply = client.submit("add", "nt", &[x, y])?;
        ensure!(
            reply.outputs[0].as_f32()? == expect.as_slice(),
            "add result differs from the client-side sum"
        );
        completed += 1;

        for (kernel, inputs, out_shape) in [
            (
                "mm",
                vec![
                    HostTensor::randn(vec![70, 50], &mut rng),
                    HostTensor::randn(vec![50, 90], &mut rng),
                ],
                vec![70, 90],
            ),
            ("softmax", vec![HostTensor::randn(vec![7, 301], &mut rng)], vec![7, 301]),
            (
                "sdpa",
                vec![
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                    HostTensor::randn(vec![2, 2, 100, 16], &mut rng),
                ],
                vec![2, 2, 100, 16],
            ),
        ] {
            let reply = client.submit(kernel, "nt", &inputs)?;
            ensure!(
                reply.outputs[0].shape == out_shape,
                "{kernel} output shape {:?} != {out_shape:?}",
                reply.outputs[0].shape
            );
            if round == 0 {
                println!(
                    "  {kernel}: backend={} batch={} queue={}µs exec={}µs",
                    reply.backend, reply.batch_size, reply.queue_us, reply.exec_us
                );
            }
            completed += 1;
        }
    }
    println!("burst complete: {completed} requests verified over the wire");

    // scrape the server-side metrics and sanity-check the exposition
    let prom = client.stats_prometheus()?;
    let submitted = prom
        .lines()
        .find(|l| l.starts_with("nt_requests_total{event=\"submitted\"}"))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse::<u64>().ok())
        .ok_or_else(|| anyhow::anyhow!("no submitted counter in the exposition"))?;
    ensure!(
        submitted >= completed,
        "server saw {submitted} submits, client completed {completed}"
    );
    ensure!(
        prom.contains("# TYPE nt_request_latency_us histogram"),
        "latency histogram missing from the exposition"
    );
    println!("stats scrape OK: server counted {submitted} submitted requests");

    if args.flag("shutdown") {
        client.shutdown_server()?;
        println!("server draining");
    }
    Ok(())
}
