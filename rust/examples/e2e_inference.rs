//! End-to-end driver (the required full-system example): load the
//! tiny-Llama weights + AOT artifacts, serve a batched generation request
//! through the NineToothed-kernel model, report latency/throughput, and
//! prove all layers compose by checking the generated tokens are
//! *identical* across the three kernel backends (nt / baseline / ref) —
//! greedy decoding is exact, so any cross-layer bug shows up as a token
//! mismatch.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference -- --steps 16
//! ```

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::cli::Args;
use ninetoothed_repro::inference::Engine;
use ninetoothed_repro::runtime::{Manifest, Registry, Runtime};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.opt_usize("steps", 16);

    let manifest = Arc::new(Manifest::load(&ninetoothed_repro::artifacts_dir())?);
    let registry = Arc::new(Registry::new(Runtime::cpu()?, manifest));

    let mut outputs = Vec::new();
    for variant in ["nt", "baseline", "ref"] {
        let engine = Engine::new(registry.clone(), variant)?;
        let prompt = engine.synth_prompt(7);
        let result = engine.generate(&prompt, steps)?;
        println!(
            "{variant:>9}: prefill {:>8.1?}  decode {:>8.1?}  {:.2} tok/s  first tokens {:?}",
            result.prefill_time,
            result.decode_time,
            result.tokens_per_s,
            &result.tokens[0][..result.tokens[0].len().min(8)],
        );
        outputs.push(result.tokens);
    }

    anyhow::ensure!(
        outputs[0] == outputs[1] && outputs[1] == outputs[2],
        "greedy decodes diverged across kernel backends"
    );
    println!("\nall three kernel backends produced token-identical greedy decodes ({steps} steps)");
    Ok(())
}
