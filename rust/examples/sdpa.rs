//! Flash-style scaled dot-product attention, end to end, through the
//! `kernel::make` API: the kernel exists only as a declaration — an
//! arrangement whose key/value column-blocks form a loop level, plus an
//! online-softmax application whose running max / running denominator /
//! accumulator are **loop-carried registers** — yet admission, output
//! inference, plan caching and execution all come derived.  The
//! `sdpa_bias` variant adds causal masking through an `[s, s]` additive
//! score bias, again with zero hand-wiring.
//!
//! ```bash
//! cargo run --release --example sdpa
//! ```

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
use ninetoothed_repro::exec::{self, GridScheduler};
use ninetoothed_repro::kernel;
use ninetoothed_repro::prng::SplitMix64;
use ninetoothed_repro::runtime::{HostTensor, Manifest};

fn main() -> Result<()> {
    let sdpa = kernel::lookup("sdpa").expect("sdpa is registered via kernel::make");
    println!(
        "sdpa: arity={} coalesce={} native={} loop-carried={:?} — {}",
        sdpa.arity,
        sdpa.coalesce,
        sdpa.executable(),
        sdpa.loop_carries(),
        sdpa.arrangement.summary
    );

    // [batch, heads, seq, head_dim] — seq 100 is deliberately not a
    // multiple of the 64-wide attention blocks, so the online-softmax
    // loop takes a padded second step
    let mut rng = SplitMix64::new(9);
    let (b, h, s, d) = (1usize, 4usize, 100usize, 32usize);
    let inputs: Vec<HostTensor> =
        (0..3).map(|_| HostTensor::randn(vec![b, h, s, d], &mut rng)).collect();

    // direct execution: output shapes are inferred, never passed
    let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
    println!("inferred output shapes: {:?}", sdpa.output_shapes(&shapes)?);
    let direct = sdpa.run(&inputs, &GridScheduler::pooled(4))?;
    let oracle = exec::reference::sdpa(&inputs[0], &inputs[1], &inputs[2])?;
    println!("direct vs f64 oracle: max|diff| = {:.3e}", direct[0].max_abs_diff(&oracle)?);
    assert!(direct[0].max_abs_diff(&oracle)? <= 1e-3);

    // causal masking via the bias variant: an [s, s] lower-triangular
    // 0 / -1e30 mask, broadcast over batch and heads by the arrangement
    let mut mask = vec![0.0f32; s * s];
    for i in 0..s {
        for (j, v) in mask[i * s..(i + 1) * s].iter_mut().enumerate() {
            if j > i {
                *v = -1e30;
            }
        }
    }
    let bias = HostTensor::f32(vec![s, s], mask)?;
    let sdpa_bias = kernel::lookup("sdpa_bias").expect("sdpa_bias is registered");
    let mut causal_inputs = inputs.clone();
    causal_inputs.push(bias.clone());
    let causal = sdpa_bias.run(&causal_inputs, &GridScheduler::pooled(4))?;
    let causal_oracle = exec::reference::sdpa_bias(&inputs[0], &inputs[1], &inputs[2], &bias)?;
    println!(
        "causal (sdpa_bias) vs f64 oracle: max|diff| = {:.3e}",
        causal[0].max_abs_diff(&causal_oracle)?
    );
    assert!(causal[0].max_abs_diff(&causal_oracle)? <= 1e-3);

    // served execution: same request twice — the second hits the plan cache
    let manifest = Arc::new(Manifest::load_or_builtin(&ninetoothed_repro::artifacts_dir()));
    let coordinator = Coordinator::start(manifest, CoordinatorConfig::default())?;
    let first = coordinator.submit("sdpa", "nt", inputs.clone())?.recv()??;
    let second = coordinator.submit("sdpa", "nt", inputs.clone())?.recv()??;
    let metrics = coordinator.metrics();
    println!(
        "served twice via {} backend: plan misses={} hits={} (compile-once/execute-many)",
        first.backend, metrics.plan_misses, metrics.plan_hits
    );
    assert_eq!(first.outputs[0], second.outputs[0], "bit-identical across the cache hit");
    assert!(first.outputs[0].max_abs_diff(&oracle)? <= 1e-3);
    coordinator.shutdown();
    println!("sdpa OK");
    Ok(())
}
