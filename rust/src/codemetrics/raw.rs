//! Raw metrics (LOC/LLOC/SLOC) and cyclomatic complexity over the lexer's
//! logical lines.

use super::lexer::{LogicalLine, Tok};

#[derive(Debug, Clone)]
pub struct RawMetrics {
    pub loc: usize,
    pub lloc: usize,
    pub sloc: usize,
}

pub fn raw_metrics(source: &str, lines: &[LogicalLine]) -> RawMetrics {
    let loc = source.lines().count();
    // SLOC: physical lines holding code (non-blank, non-comment-only)
    let sloc = source
        .lines()
        .filter(|l| {
            let t = l.trim();
            !t.is_empty() && !t.starts_with('#')
        })
        .count();
    // LLOC: one per logical statement; `;` separates statements, and a
    // compound header with inline body (`if x: y = 1`) counts the body too.
    let mut lloc = 0;
    for line in lines {
        lloc += 1;
        lloc += line
            .tokens
            .iter()
            .filter(|t| matches!(t, Tok::Op(op) if op == ";"))
            .count();
        // inline compound statement: `:` not at end and header keyword first
        if let Some(Tok::Keyword(k)) = line.tokens.first() {
            if matches!(
                k.as_str(),
                "if" | "elif" | "else" | "for" | "while" | "def" | "with" | "try" | "except" | "finally" | "class"
            ) {
                if let Some(pos) = line
                    .tokens
                    .iter()
                    .rposition(|t| matches!(t, Tok::Op(op) if op == ":"))
                {
                    if pos + 1 < line.tokens.len() {
                        lloc += 1;
                    }
                }
            }
        }
    }
    RawMetrics { loc, lloc, sloc }
}

/// Cyclomatic complexity: sum over functions of (1 + decision points).
///
/// Decision points: `if` / `elif` / `while` / `except` / ternary `if` /
/// comprehension `if`s (all `if` tokens), `for` (statement or
/// comprehension), boolean `and` / `or`, `assert`.  Module-level decision
/// points attach to a synthetic module function only if no `def` exists.
pub fn cyclomatic(lines: &[LogicalLine]) -> usize {
    let mut functions = 0usize;
    let mut decisions = 0usize;
    for line in lines {
        // module-level statements (indent 0, no def) are not part of any
        // function; radon-style per-function complexity ignores their
        // decision tokens (e.g. the `for` in `tuple(Tensor(1) for _ in ...)`)
        let in_function = line.indent > 0;
        for tok in &line.tokens {
            if let Tok::Keyword(k) = tok {
                match k.as_str() {
                    "def" | "lambda" => functions += 1,
                    "if" | "elif" | "while" | "for" | "except" | "and" | "or" | "assert"
                        if in_function =>
                    {
                        decisions += 1;
                    }
                    _ => {}
                }
            }
        }
    }
    if functions == 0 {
        1 + decisions
    } else {
        functions + decisions
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::tokenize;
    use super::*;

    #[test]
    fn raw_counts() {
        let src = "# comment\n\nx = 1\ny = 2; z = 3\n";
        let lines = tokenize(src);
        let m = raw_metrics(src, &lines);
        assert_eq!(m.loc, 4);
        assert_eq!(m.sloc, 2);
        assert_eq!(m.lloc, 3);
    }

    #[test]
    fn cyclomatic_counts_functions_and_decisions() {
        let src = "\
def f(x):
    if x and x > 1:
        return 1
    return 0


def g(xs):
    return [x for x in xs if x]
";
        let lines = tokenize(src);
        // f: 1 + if + and = 3; g: 1 + for + if = 3 -> 6
        assert_eq!(cyclomatic(&lines), 6);
    }

    #[test]
    fn module_level_fallback() {
        // module-level decision tokens are outside any function and are
        // not counted (radon per-function semantics)
        let lines = tokenize("x = 1 if y else 2\n");
        assert_eq!(cyclomatic(&lines), 1);
    }
}
