//! A Python lexer sufficient for the metric suite: strings (incl. triple-
//! quoted and prefixes), comments, numbers, names/keywords, operators,
//! implicit line joining inside brackets, explicit joining with `\`.

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    Name(String),
    Keyword(String),
    Number(String),
    Str,
    Op(String),
}

/// One logical line: physical span + tokens.
#[derive(Debug, Clone)]
pub struct LogicalLine {
    pub first_line: usize,
    pub tokens: Vec<Tok>,
    /// indentation (spaces) of the first physical line
    pub indent: usize,
}

pub const KEYWORDS: &[&str] = &[
    "False", "None", "True", "and", "as", "assert", "async", "await", "break", "class",
    "continue", "def", "del", "elif", "else", "except", "finally", "for", "from", "global",
    "if", "import", "in", "is", "lambda", "nonlocal", "not", "or", "pass", "raise", "return",
    "try", "while", "with", "yield",
];

const OPERATORS: &[&str] = &[
    "**=", "//=", ">>=", "<<=", "...", "!=", ">=", "<=", "==", "->", ":=", "+=", "-=", "*=",
    "/=", "%=", "&=", "|=", "^=", "**", "//", ">>", "<<", "+", "-", "*", "/", "%", "@", "&",
    "|", "^", "~", "<", ">", "(", ")", "[", "]", "{", "}", ",", ":", ".", ";", "=",
];

pub fn tokenize(source: &str) -> Vec<LogicalLine> {
    let bytes = source.as_bytes();
    let mut lines = Vec::new();
    let mut current: Option<LogicalLine> = None;
    let mut depth = 0usize; // bracket nesting
    let mut i = 0usize;
    let mut line_no = 1usize;
    let mut at_line_start = true;
    let mut indent = 0usize;

    while i < bytes.len() {
        let c = bytes[i] as char;

        if at_line_start {
            indent = 0;
            while i < bytes.len() && (bytes[i] == b' ' || bytes[i] == b'\t') {
                indent += if bytes[i] == b'\t' { 8 } else { 1 };
                i += 1;
            }
            at_line_start = false;
            continue;
        }

        match c {
            '\n' => {
                line_no += 1;
                i += 1;
                at_line_start = true;
                if depth == 0 {
                    if let Some(line) = current.take() {
                        if !line.tokens.is_empty() {
                            lines.push(line);
                        }
                    }
                }
            }
            '\\' if i + 1 < bytes.len() && bytes[i + 1] == b'\n' => {
                // explicit line joining
                line_no += 1;
                i += 2;
            }
            '#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            ' ' | '\t' | '\r' => i += 1,
            '\'' | '"' => {
                let (consumed, newlines) = scan_string(&bytes[i..]);
                i += consumed;
                line_no += newlines;
                push_tok(&mut current, line_no, indent, Tok::Str);
            }
            c if c.is_ascii_digit() => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'.' || bytes[i] == b'_')
                {
                    i += 1;
                }
                let text = source[start..i].to_string();
                push_tok(&mut current, line_no, indent, Tok::Number(text));
            }
            c if c == '_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i] == b'_' || bytes[i].is_ascii_alphanumeric())
                {
                    i += 1;
                }
                let text = &source[start..i];
                // string prefixes (r"...", f"...", b"...", rb"...")
                if i < bytes.len()
                    && (bytes[i] == b'"' || bytes[i] == b'\'')
                    && text.len() <= 2
                    && text.chars().all(|ch| "rbfuRBFU".contains(ch))
                {
                    let (consumed, newlines) = scan_string(&bytes[i..]);
                    i += consumed;
                    line_no += newlines;
                    push_tok(&mut current, line_no, indent, Tok::Str);
                } else if KEYWORDS.contains(&text) {
                    push_tok(&mut current, line_no, indent, Tok::Keyword(text.to_string()));
                } else {
                    push_tok(&mut current, line_no, indent, Tok::Name(text.to_string()));
                }
            }
            _ => {
                let mut matched = false;
                for op in OPERATORS {
                    if source[i..].starts_with(op) {
                        match *op {
                            "(" | "[" | "{" => depth += 1,
                            ")" | "]" | "}" => depth = depth.saturating_sub(1),
                            _ => {}
                        }
                        push_tok(&mut current, line_no, indent, Tok::Op(op.to_string()));
                        i += op.len();
                        matched = true;
                        break;
                    }
                }
                if !matched {
                    i += 1; // unknown byte: skip
                }
            }
        }
    }
    if let Some(line) = current.take() {
        if !line.tokens.is_empty() {
            lines.push(line);
        }
    }
    lines
}

fn push_tok(current: &mut Option<LogicalLine>, line_no: usize, indent: usize, tok: Tok) {
    current
        .get_or_insert_with(|| LogicalLine { first_line: line_no, tokens: Vec::new(), indent })
        .tokens
        .push(tok);
}

/// Scan a string literal starting at a quote; returns (bytes consumed,
/// newlines crossed).
fn scan_string(bytes: &[u8]) -> (usize, usize) {
    let quote = bytes[0];
    let triple = bytes.len() >= 3 && bytes[1] == quote && bytes[2] == quote;
    let mut i = if triple { 3 } else { 1 };
    let mut newlines = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'\n' => {
                if !triple {
                    return (i, newlines); // unterminated single-line string
                }
                newlines += 1;
                i += 1;
            }
            q if q == quote => {
                if triple {
                    if i + 2 < bytes.len() && bytes[i + 1] == quote && bytes[i + 2] == quote {
                        return (i + 3, newlines);
                    }
                    i += 1;
                } else {
                    return (i + 1, newlines);
                }
            }
            _ => i += 1,
        }
    }
    (bytes.len(), newlines)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_tokens() {
        let lines = tokenize("x = a + 42\n");
        assert_eq!(lines.len(), 1);
        assert_eq!(
            lines[0].tokens,
            vec![
                Tok::Name("x".into()),
                Tok::Op("=".into()),
                Tok::Name("a".into()),
                Tok::Op("+".into()),
                Tok::Number("42".into()),
            ]
        );
    }

    #[test]
    fn comments_and_blank_lines_skipped() {
        let lines = tokenize("# comment\n\nx = 1  # trailing\n");
        assert_eq!(lines.len(), 1);
    }

    #[test]
    fn implicit_joining() {
        let lines = tokenize("f(a,\n  b)\ny = 2\n");
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].tokens.len(), 6); // f ( a , b )
    }

    #[test]
    fn triple_strings() {
        let lines = tokenize("\"\"\"doc\nstring\"\"\"\nx = 1\n");
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].tokens, vec![Tok::Str]);
    }

    #[test]
    fn keywords_detected() {
        let lines = tokenize("for k in range(n):\n    pass\n");
        assert!(matches!(lines[0].tokens[0], Tok::Keyword(ref k) if k == "for"));
    }

    #[test]
    fn string_prefixes() {
        let lines = tokenize("s = f\"hello {x}\"\n");
        assert_eq!(lines[0].tokens.last(), Some(&Tok::Str));
    }

    #[test]
    fn operators_longest_match() {
        let lines = tokenize("a //= b ** c\n");
        assert!(lines[0].tokens.contains(&Tok::Op("//=".into())));
        assert!(lines[0].tokens.contains(&Tok::Op("**".into())));
    }
}
