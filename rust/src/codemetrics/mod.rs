//! Code-complexity metric suite (Table 2): raw metrics, cyclomatic
//! complexity, Halstead family, maintainability index — computed over
//! Python kernel sources by an in-crate Python lexer.
//!
//! Two implementations exist in this repo: the AST-exact one in
//! `python/compile/metrics.py` (radon-equivalent; its rows are embedded in
//! the manifest at AOT time) and this lexer-level one, implemented
//! independently in Rust.  LOC/SLOC/G are computed identically; the
//! Halstead counts here are a token-neighborhood approximation of radon's
//! AST walk (documented deviation; the Table 2 harness prints both and
//! flags disagreements).

mod halstead;
mod lexer;
mod raw;

pub use halstead::halstead;
pub use lexer::{tokenize, LogicalLine, Tok};
pub use raw::{cyclomatic, raw_metrics, RawMetrics};

/// All Table 2 columns for one source region.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub loc: usize,
    pub lloc: usize,
    pub sloc: usize,
    pub cyclomatic: usize,
    pub vocabulary: usize,
    pub length: usize,
    pub volume: f64,
    pub difficulty: f64,
    pub mi: f64,
}

/// The SEI/radon maintainability-index formula.
pub fn maintainability_index(volume: f64, complexity: usize, sloc: usize) -> f64 {
    if sloc == 0 {
        return 100.0;
    }
    let v = if volume > 0.0 { volume.ln() } else { 0.0 };
    let mi = 171.0 - 5.2 * v - 0.23 * complexity as f64 - 16.2 * (sloc as f64).ln();
    (mi * 100.0 / 171.0).max(0.0)
}

pub fn analyze(source: &str) -> Metrics {
    let lines = tokenize(source);
    let raw = raw_metrics(source, &lines);
    let g = cyclomatic(&lines);
    let h = halstead(&lines);
    let mi = maintainability_index(h.volume, g, raw.sloc);
    Metrics {
        loc: raw.loc,
        lloc: raw.lloc,
        sloc: raw.sloc,
        cyclomatic: g,
        vocabulary: h.vocabulary,
        length: h.length,
        volume: h.volume,
        difficulty: h.difficulty,
        mi,
    }
}

/// Extract the measured region of a kernel file (mirrors metrics.py):
/// marker comments if present, else everything after imports/docstring.
pub fn measured_region(source: &str) -> String {
    const BEGIN: &str = "# --- metrics:begin ---";
    const END: &str = "# --- metrics:end ---";
    if let Some(start) = source.find(BEGIN) {
        let rest = &source[start + BEGIN.len()..];
        let end = rest.find(END).unwrap_or(rest.len());
        return rest[..end].trim().to_string() + "\n";
    }
    // skip docstring + import block
    let mut out = Vec::new();
    let mut in_docstring = false;
    let mut docstring_done = false;
    let mut body_started = false;
    for line in source.lines() {
        let trimmed = line.trim_start();
        if !body_started {
            if !docstring_done && !in_docstring && (trimmed.starts_with("\"\"\"") || trimmed.starts_with("'''")) {
                // docstring start; single-line?
                let rest = &trimmed[3..];
                if rest.contains("\"\"\"") || rest.contains("'''") {
                    docstring_done = true;
                } else {
                    in_docstring = true;
                }
                continue;
            }
            if in_docstring {
                if trimmed.contains("\"\"\"") || trimmed.contains("'''") {
                    in_docstring = false;
                    docstring_done = true;
                }
                continue;
            }
            if trimmed.is_empty()
                || trimmed.starts_with("import ")
                || trimmed.starts_with("from ")
                || trimmed.starts_with('#')
            {
                continue;
            }
            body_started = true;
        }
        out.push(line);
    }
    out.join("\n").trim().to_string() + "\n"
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Listing-3 application body: `output = input + other`.
    #[test]
    fn listing3_application_halstead() {
        let src = "def application(input, other, output):\n    output = input + other\n";
        let m = analyze(src);
        // one `+` with operands input/other: eta = 3, N = 3, V = 4.75
        assert_eq!(m.vocabulary, 3);
        assert_eq!(m.length, 3);
        assert!((m.volume - 4.754_887).abs() < 1e-3);
        assert!((m.difficulty - 0.5).abs() < 1e-9);
    }

    #[test]
    fn mm_like_complexity() {
        let src = "\
def arrangement(a, b):
    return a, b


def application(a, b, c):
    acc = zeros()
    for k in range(a.shape[0]):
        acc += dot(a[k], b[k])
    c = acc
";
        let m = analyze(src);
        // two functions (1 + 1) plus one `for` = 3 — the paper's mm G
        assert_eq!(m.cyclomatic, 3);
    }

    #[test]
    fn mi_monotone_in_volume() {
        let lo = maintainability_index(10.0, 1, 10);
        let hi = maintainability_index(1000.0, 1, 10);
        assert!(lo > hi);
    }

    #[test]
    fn measured_region_skips_docstring_and_imports() {
        let src = "\"\"\"Doc.\"\"\"\n\nimport x\nfrom y import z\n\nBLOCK = 1\n\ndef f():\n    pass\n";
        let region = measured_region(src);
        assert!(region.starts_with("BLOCK = 1"));
        assert!(!region.contains("import"));
    }

    #[test]
    fn measured_region_markers() {
        let src = "import x\n# --- metrics:begin ---\ndef k():\n    pass\n# --- metrics:end ---\nrest\n";
        let region = measured_region(src);
        assert!(region.starts_with("def k()"));
        assert!(!region.contains("rest"));
    }
}
