//! Halstead metrics, token-neighborhood approximation of radon's AST walk.
//!
//! radon counts operator occurrences of BinOp/UnaryOp/BoolOp/Compare/
//! AugAssign nodes and their direct operand children.  At token level we
//! count the same operator tokens and, for each occurrence, the nearest
//! name/number/string on each side (skipping balanced brackets), which
//! coincides with the AST counts on flat expressions and over-counts
//! shared middles of chains like `a + b + c` by one occurrence — a
//! documented approximation the Table 2 harness cross-checks against the
//! AST-exact numbers embedded in the manifest.

use std::collections::BTreeSet;

use super::lexer::{LogicalLine, Tok};

const H_OPERATORS: &[&str] = &[
    "+", "-", "*", "/", "//", "%", "**", "==", "!=", "<", ">", "<=", ">=", "&", "|", "^",
    "<<", ">>", "~", "+=", "-=", "*=", "/=", "//=", "**=", ">>=", "<<=",
];
const H_KEYWORD_OPERATORS: &[&str] = &["and", "or", "not", "in", "is"];

#[derive(Debug, Clone)]
pub struct Halstead {
    pub eta1: usize,
    pub eta2: usize,
    pub n1: usize,
    pub n2: usize,
    pub vocabulary: usize,
    pub length: usize,
    pub volume: f64,
    pub difficulty: f64,
}

fn operand_text(tok: &Tok) -> Option<String> {
    match tok {
        Tok::Name(n) => Some(n.clone()),
        Tok::Number(n) => Some(n.clone()),
        Tok::Str => Some("<str>".to_string()),
        _ => None,
    }
}

/// Nearest operand left of `idx`, skipping balanced brackets.
fn operand_left(tokens: &[Tok], idx: usize) -> Option<String> {
    let mut depth = 0i64;
    for j in (0..idx).rev() {
        match &tokens[j] {
            Tok::Op(op) if op == ")" || op == "]" || op == "}" => depth += 1,
            Tok::Op(op) if op == "(" || op == "[" || op == "{" => {
                if depth == 0 {
                    return None;
                }
                depth -= 1;
            }
            tok if depth == 0 => {
                if let Some(text) = operand_text(tok) {
                    return Some(text);
                }
                if matches!(tok, Tok::Op(op) if op == "," || op == "=" || op == ":") {
                    return None;
                }
            }
            _ => {}
        }
    }
    None
}

fn operand_right(tokens: &[Tok], idx: usize) -> Option<String> {
    let mut depth = 0i64;
    for tok in tokens.iter().skip(idx + 1) {
        match tok {
            Tok::Op(op) if op == "(" || op == "[" || op == "{" => depth += 1,
            Tok::Op(op) if op == ")" || op == "]" || op == "}" => {
                if depth == 0 {
                    return None;
                }
                depth -= 1;
            }
            tok if depth == 0 => {
                if let Some(text) = operand_text(tok) {
                    return Some(text);
                }
                if matches!(tok, Tok::Op(op) if op == "," || op == "=" || op == ":") {
                    return None;
                }
            }
            _ => {}
        }
    }
    None
}

/// `*` / `**` directly after `(` or `,` are argument-unpacking, not
/// arithmetic; leading `-`/`+` after operators or `(`/`,`/`=` are signs.
fn is_non_arith_context(tokens: &[Tok], idx: usize, op: &str) -> bool {
    let prev = if idx == 0 { None } else { Some(&tokens[idx - 1]) };
    match op {
        "*" | "**" => match prev {
            None => true,
            Some(Tok::Op(p)) => p == "(" || p == ",",
            _ => false,
        },
        "-" | "+" => matches!(prev, None | Some(Tok::Op(_)) | Some(Tok::Keyword(_))),
        _ => false,
    }
}

pub fn halstead(lines: &[LogicalLine]) -> Halstead {
    let mut operators: Vec<String> = Vec::new();
    let mut operands: Vec<String> = Vec::new();

    for line in lines {
        let toks = &line.tokens;
        for (i, tok) in toks.iter().enumerate() {
            let op_name = match tok {
                Tok::Op(op) if H_OPERATORS.contains(&op.as_str()) => {
                    if is_non_arith_context(toks, i, op) {
                        // unary sign: count operator + right operand only
                        if (op == "-" || op == "+") && !matches!(toks.get(i), None) {
                            if let Some(r) = operand_right(toks, i) {
                                operators.push(format!("u{op}"));
                                operands.push(r);
                            }
                        }
                        continue;
                    }
                    op.clone()
                }
                Tok::Keyword(k) if H_KEYWORD_OPERATORS.contains(&k.as_str()) => {
                    // `for x in xs` — `in` is part of the for/comprehension
                    let is_loop_in = k == "in"
                        && toks.iter().take(i).any(
                            |t| matches!(t, Tok::Keyword(kw) if kw == "for"),
                        );
                    if is_loop_in || k == "not" {
                        continue;
                    }
                    k.clone()
                }
                _ => continue,
            };
            operators.push(op_name);
            if let Some(l) = operand_left(toks, i) {
                operands.push(l);
            }
            if let Some(r) = operand_right(toks, i) {
                operands.push(r);
            }
        }
    }

    let eta1 = operators.iter().collect::<BTreeSet<_>>().len();
    let eta2 = operands.iter().collect::<BTreeSet<_>>().len();
    let n1 = operators.len();
    let n2 = operands.len();
    let vocabulary = eta1 + eta2;
    let length = n1 + n2;
    let volume = if vocabulary > 1 {
        length as f64 * (vocabulary as f64).log2()
    } else {
        length as f64
    };
    let difficulty = if eta2 > 0 {
        (eta1 as f64 / 2.0) * (n2 as f64 / eta2 as f64)
    } else {
        0.0
    };
    Halstead { eta1, eta2, n1, n2, vocabulary, length, volume, difficulty }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::tokenize;
    use super::*;

    #[test]
    fn simple_addition() {
        // `output = input + other` — the paper's add application:
        // eta = 3 (one operator + two operands), N = 3, V = 3 log2 3
        let h = halstead(&tokenize("output = input + other\n"));
        assert_eq!((h.eta1, h.eta2, h.n1, h.n2), (1, 2, 1, 2));
        assert!((h.volume - 4.754_887).abs() < 1e-3);
        assert!((h.difficulty - 0.5).abs() < 1e-9);
    }

    #[test]
    fn plain_assignment_not_counted() {
        let h = halstead(&tokenize("x = f(y)\n"));
        assert_eq!(h.length, 0);
    }

    #[test]
    fn argument_star_not_counted() {
        let h = halstead(&tokenize("f(*args)\n"));
        assert_eq!(h.n1, 0);
    }

    #[test]
    fn comparison_and_bool() {
        let h = halstead(&tokenize("ok = a < b and b < c\n"));
        // operators: <, and, < ; operands: a,b | (a<b as left? skipped via keyword), ...
        assert!(h.n1 >= 3);
        assert!(h.eta1 >= 2);
    }

    #[test]
    fn augmented_assignment() {
        let h = halstead(&tokenize("acc += x\n"));
        assert_eq!(h.n1, 1);
        assert_eq!(h.n2, 2);
    }

    #[test]
    fn loop_in_excluded() {
        let h = halstead(&tokenize("for k in range(n):\n    pass\n"));
        assert_eq!(h.n1, 0);
    }
}
