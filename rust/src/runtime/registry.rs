//! Executable registry: lazy resolve-on-first-use cache over the manifest
//! *and* the native kernel catalog.
//!
//! One backend per (kernel, variant) — the Rust analogue of the DSL's
//! per-specialization cache.  Resolution order:
//!
//! 1. a compiled AOT artifact, when the manifest has one **and** a PJRT
//!    runtime is available;
//! 2. otherwise the native tile program for the kernel (`crate::exec`),
//!    with the reference oracle serving the `ref` variant.
//!
//! Artifact executables hold `Rc`-based PJRT handles, so a registry is not
//! `Send`: the coordinator's workers each own one, built from the shared
//! manifest.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::{ArtifactBackend, Backend, Executable, Manifest, NativeBackend, RefBackend, Runtime};

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ExecKey {
    pub name: String,
    pub variant: String,
}

pub struct Registry {
    runtime: Option<Runtime>,
    manifest: Arc<Manifest>,
    cache: Mutex<HashMap<ExecKey, Arc<Executable>>>,
    backends: Mutex<HashMap<ExecKey, Arc<dyn Backend>>>,
    /// parallelism budget per native grid execution
    native_threads: usize,
    /// compiled-plan cache the native backends share; `Send + Sync`, so
    /// one instance can (and in the coordinator does) span every worker's
    /// registry — a shape warmed by any worker is warm for all
    plan_cache: Arc<crate::exec::PlanCache>,
}

impl Registry {
    pub fn new(runtime: Runtime, manifest: Arc<Manifest>) -> Registry {
        Registry {
            runtime: Some(runtime),
            manifest,
            cache: Mutex::new(HashMap::new()),
            backends: Mutex::new(HashMap::new()),
            native_threads: default_native_threads(),
            plan_cache: Arc::new(crate::exec::PlanCache::new(
                crate::exec::PlanCache::DEFAULT_CAPACITY,
            )),
        }
    }

    /// A registry with no PJRT runtime: every kernel resolves natively.
    pub fn native_only(manifest: Arc<Manifest>) -> Registry {
        Registry {
            runtime: None,
            manifest,
            cache: Mutex::new(HashMap::new()),
            backends: Mutex::new(HashMap::new()),
            native_threads: default_native_threads(),
            plan_cache: Arc::new(crate::exec::PlanCache::new(
                crate::exec::PlanCache::DEFAULT_CAPACITY,
            )),
        }
    }

    /// Use a PJRT runtime if one can be created, else run native-only —
    /// the constructor the coordinator workers use.
    pub fn auto(manifest: Arc<Manifest>) -> Registry {
        match Runtime::cpu() {
            Ok(runtime) => Registry::new(runtime, manifest),
            Err(_) => Registry::native_only(manifest),
        }
    }

    /// Override the native grid scheduler's thread count.
    pub fn with_native_threads(mut self, threads: usize) -> Registry {
        self.native_threads = threads.max(1);
        self
    }

    /// Share a plan cache with other registries (the coordinator hands
    /// every worker's registry one cache, so compiled programs are
    /// process-wide).
    pub fn with_plan_cache(mut self, plan_cache: Arc<crate::exec::PlanCache>) -> Registry {
        self.plan_cache = plan_cache;
        self
    }

    /// The compiled-plan cache native backends resolve through.
    pub fn plan_cache(&self) -> &Arc<crate::exec::PlanCache> {
        &self.plan_cache
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn manifest_arc(&self) -> Arc<Manifest> {
        self.manifest.clone()
    }

    pub fn runtime(&self) -> Option<&Runtime> {
        self.runtime.as_ref()
    }

    /// Resolve (kernel, variant) to an executable backend: artifact when
    /// possible, native tile program otherwise.
    pub fn resolve(&self, name: &str, variant: &str) -> Result<Arc<dyn Backend>> {
        let key = ExecKey { name: name.to_string(), variant: variant.to_string() };
        if let Some(backend) = self.backends.lock().unwrap().get(&key) {
            return Ok(backend.clone());
        }
        let backend: Arc<dyn Backend> = match self.try_artifact(name, variant) {
            Ok(exe) => Arc::new(ArtifactBackend { exe }),
            Err(artifact_err) => match super::native_fallback_kind(name, variant) {
                Ok(super::BackendKind::Reference) => Arc::new(RefBackend::new(name)),
                Ok(_) => {
                    let kernel = crate::kernel::lookup(name)
                        .expect("classifier only returns Native when a definition exists");
                    Arc::new(NativeBackend::new(
                        kernel,
                        variant,
                        self.native_threads,
                        self.plan_cache.clone(),
                    ))
                }
                Err(fallback_err) => {
                    return Err(anyhow!(
                        "kernel {name}.{variant}: no artifact ({artifact_err:#}); \
                         {fallback_err:#}"
                    ));
                }
            },
        };
        self.backends.lock().unwrap().insert(key, backend.clone());
        Ok(backend)
    }

    fn try_artifact(&self, name: &str, variant: &str) -> Result<Arc<Executable>> {
        let art = self.manifest.kernel(name, variant)?;
        let runtime = self
            .runtime
            .as_ref()
            .ok_or_else(|| anyhow!("no PJRT runtime in this registry"))?;
        let key = ExecKey { name: name.to_string(), variant: variant.to_string() };
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(runtime.load_artifact(
            &self.manifest.artifact_path(&art.path),
            &format!("{name}.{variant}"),
            art.outputs.len(),
        )?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Fetch (compiling if needed) the artifact executable for a kernel
    /// task.  Artifact-only — harness paths that measure AOT execution
    /// use this; serving paths use [`Registry::resolve`].
    pub fn kernel(&self, name: &str, variant: &str) -> Result<Arc<Executable>> {
        self.try_artifact(name, variant)
    }

    /// Fetch a model-step executable (prefill/decode return 3 outputs).
    pub fn model_step(&self, kind: &str, variant: &str) -> Result<Arc<Executable>> {
        let key = ExecKey { name: format!("model.{kind}"), variant: variant.to_string() };
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let runtime = self
            .runtime
            .as_ref()
            .ok_or_else(|| anyhow!("no PJRT runtime in this registry"))?;
        let model = self
            .manifest
            .model
            .as_ref()
            .ok_or_else(|| anyhow!("manifest has no model section"))?;
        let step = model
            .steps
            .iter()
            .find(|s| s.kind == kind && s.variant == variant)
            .ok_or_else(|| anyhow!("no model step {kind}.{variant}"))?;
        let exe = Arc::new(runtime.load_artifact(
            &self.manifest.artifact_path(&step.path),
            &format!("model.{kind}.{variant}"),
            3,
        )?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of compiled artifact executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Number of resolved backends currently cached.
    pub fn resolved_count(&self) -> usize {
        self.backends.lock().unwrap().len()
    }
}

fn default_native_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
