//! Executable registry: lazy compile-on-first-use cache over the manifest.
//!
//! One compiled executable per (kernel, variant) — the Rust analogue of the
//! DSL's per-specialization cache.  Thread-safe: the coordinator's worker
//! pool shares one registry.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::{Executable, Manifest, Runtime};

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ExecKey {
    pub name: String,
    pub variant: String,
}

pub struct Registry {
    runtime: Runtime,
    manifest: Arc<Manifest>,
    cache: Mutex<HashMap<ExecKey, Arc<Executable>>>,
}

impl Registry {
    pub fn new(runtime: Runtime, manifest: Arc<Manifest>) -> Registry {
        Registry { runtime, manifest, cache: Mutex::new(HashMap::new()) }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn manifest_arc(&self) -> Arc<Manifest> {
        self.manifest.clone()
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Fetch (compiling if needed) the executable for a kernel task.
    pub fn kernel(&self, name: &str, variant: &str) -> Result<Arc<Executable>> {
        let key = ExecKey { name: name.to_string(), variant: variant.to_string() };
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let art = self.manifest.kernel(name, variant)?;
        let exe = Arc::new(self.runtime.load_artifact(
            &self.manifest.artifact_path(&art.path),
            &format!("{name}.{variant}"),
            art.outputs.len(),
        )?);
        self.cache
            .lock()
            .unwrap()
            .insert(key, exe.clone());
        Ok(exe)
    }

    /// Fetch a model-step executable (prefill/decode return 3 outputs).
    pub fn model_step(&self, kind: &str, variant: &str) -> Result<Arc<Executable>> {
        let key = ExecKey { name: format!("model.{kind}"), variant: variant.to_string() };
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let model = self
            .manifest
            .model
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest has no model section"))?;
        let step = model
            .steps
            .iter()
            .find(|s| s.kind == kind && s.variant == variant)
            .ok_or_else(|| anyhow::anyhow!("no model step {kind}.{variant}"))?;
        let exe = Arc::new(self.runtime.load_artifact(
            &self.manifest.artifact_path(&step.path),
            &format!("model.{kind}.{variant}"),
            3,
        )?);
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}
