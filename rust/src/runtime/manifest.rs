//! Typed view of `artifacts/manifest.json` (produced by python/compile/aot.py).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::json::Json;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub name: String,
    pub variant: String,
    pub path: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub flops: u64,
}

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug, Clone)]
pub struct ModelStep {
    pub kind: String,
    pub variant: String,
    pub path: String,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub batch: usize,
    pub prompt: usize,
    pub weights_path: String,
    pub weights: Vec<WeightEntry>,
    pub steps: Vec<ModelStep>,
}

#[derive(Debug, Clone)]
pub struct GoldenCase {
    pub kernel: String,
    pub inputs: Vec<String>,
    pub output: String,
    pub shape: Vec<usize>,
}

/// Everything the Rust side needs from the AOT step.
pub struct Manifest {
    pub dir: PathBuf,
    pub full: bool,
    pub kernels: Vec<KernelArtifact>,
    pub model: Option<ModelInfo>,
    pub goldens: Vec<GoldenCase>,
    pub raw: Json,
}

fn arg_specs(items: &[Json]) -> Result<Vec<ArgSpec>> {
    items
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                shape: a.usize_vec("shape")?,
                dtype: a.str("dtype").unwrap_or("float32").to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// An artifact-free manifest: no kernels, no model, no goldens.  The
    /// registry resolves every kernel against the native tile-program
    /// catalog instead — this is what lets the system serve requests on a
    /// machine where `make artifacts` never ran.
    pub fn builtin() -> Manifest {
        Manifest {
            dir: PathBuf::from("artifacts"),
            full: false,
            kernels: Vec::new(),
            model: None,
            goldens: Vec::new(),
            raw: Json::Obj(std::collections::BTreeMap::new()),
        }
    }

    /// Load `manifest.json` if present, else fall back to the builtin
    /// (native-only) manifest.  A manifest that *exists but fails to
    /// load* is a loud warning, not a silent downgrade — otherwise a
    /// corrupt file would quietly reroute every benchmark and request to
    /// the native backend.
    pub fn load_or_builtin(dir: &Path) -> Manifest {
        match Manifest::load(dir) {
            Ok(m) => m,
            Err(e) => {
                if dir.join("manifest.json").exists() {
                    eprintln!(
                        "warning: artifacts manifest at {} exists but failed to load \
                         ({e:#}); falling back to native-only serving",
                        dir.display()
                    );
                }
                Manifest::builtin()
            }
        }
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let raw = Json::parse(&text).context("parsing manifest.json")?;

        let mut kernels = Vec::new();
        for k in raw.arr("kernels")? {
            kernels.push(KernelArtifact {
                name: k.str("name")?.to_string(),
                variant: k.str("variant")?.to_string(),
                path: k.str("path")?.to_string(),
                args: arg_specs(k.arr("args")?)?,
                outputs: arg_specs(k.arr("outputs")?)?,
                flops: k.f64("flops").unwrap_or(0.0) as u64,
            });
        }

        let model = match raw.get("model") {
            Some(m) => {
                let cfg = m.req("config")?;
                let mut weights = Vec::new();
                for w in m.arr("weights")? {
                    weights.push(WeightEntry {
                        name: w.str("name")?.to_string(),
                        shape: w.usize_vec("shape")?,
                        offset: w.usize("offset")?,
                        nbytes: w.usize("nbytes")?,
                    });
                }
                let mut steps = Vec::new();
                for s in m.arr("steps")? {
                    steps.push(ModelStep {
                        kind: s.str("kind")?.to_string(),
                        variant: s.str("variant")?.to_string(),
                        path: s.str("path")?.to_string(),
                    });
                }
                Some(ModelInfo {
                    vocab_size: cfg.usize("vocab_size")?,
                    d_model: cfg.usize("d_model")?,
                    n_layers: cfg.usize("n_layers")?,
                    n_heads: cfg.usize("n_heads")?,
                    d_ff: cfg.usize("d_ff")?,
                    max_seq: cfg.usize("max_seq")?,
                    batch: m.usize("batch")?,
                    prompt: m.usize("prompt")?,
                    weights_path: m.str("weights_path")?.to_string(),
                    weights,
                    steps,
                })
            }
            None => None,
        };

        let mut goldens = Vec::new();
        for g in raw.get("golden").and_then(|g| g.as_arr()).unwrap_or(&[]) {
            goldens.push(GoldenCase {
                kernel: g.str("kernel")?.to_string(),
                inputs: g
                    .arr("inputs")?
                    .iter()
                    .filter_map(|s| s.as_str().map(str::to_string))
                    .collect(),
                output: g.str("output")?.to_string(),
                shape: g.usize_vec("shape")?,
            });
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            full: raw.get("full").and_then(Json::as_bool).unwrap_or(false),
            kernels,
            model,
            goldens,
            raw,
        })
    }

    pub fn kernel(&self, name: &str, variant: &str) -> Result<&KernelArtifact> {
        self.kernels
            .iter()
            .find(|k| k.name == name && k.variant == variant)
            .with_context(|| format!("no artifact for kernel {name}.{variant}"))
    }

    pub fn kernel_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.kernels.iter().map(|k| k.name.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    pub fn artifact_path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}
