//! Host-side tensors: the coordinator's own nd-array type for staging
//! kernel inputs/outputs (f32 / i32, row-major contiguous).

use anyhow::{bail, Context, Result};

use crate::prng::SplitMix64;

#[derive(Debug, Clone, PartialEq)]
pub enum HostData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: HostData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: HostData::F32(data) })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("shape {shape:?} needs {n} elements, got {}", data.len());
        }
        Ok(HostTensor { shape, data: HostData::I32(data) })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: HostData::F32(vec![0.0; n]) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: HostData::I32(vec![v]) }
    }

    pub fn randn(shape: Vec<usize>, rng: &mut SplitMix64) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: HostData::F32(rng.normal_vec(n)) }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            HostData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            HostData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Build an `xla::Literal` (copies the data into XLA's layout).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            HostData::F32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
            HostData::I32(v) => xla::Literal::vec1(v.as_slice()).reshape(&dims)?,
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor { shape: dims, data: HostData::F32(lit.to_vec::<f32>()?) })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor { shape: dims, data: HostData::I32(lit.to_vec::<i32>()?) })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Max |a - b| against another tensor (validation helper).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max))
    }

    /// Read a raw little-endian f32 blob (the golden/weight format).
    pub fn from_f32_file(path: &std::path::Path, shape: Vec<usize>) -> Result<HostTensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let n: usize = shape.iter().product();
        if bytes.len() != n * 4 {
            bail!("{}: expected {} bytes, got {}", path.display(), n * 4, bytes.len());
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        HostTensor::f32(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::f32(vec![3], vec![1.0, 2.5, 3.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = SplitMix64::new(5);
        let mut r2 = SplitMix64::new(5);
        assert_eq!(
            HostTensor::randn(vec![4, 4], &mut r1),
            HostTensor::randn(vec![4, 4], &mut r2)
        );
    }
}
