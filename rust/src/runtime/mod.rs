//! Runtime: execution backends + AOT artifact loading (L3 <-> L2 bridge).
//!
//! Two ways to execute a kernel meet behind the [`Backend`] trait:
//!
//! * **artifacts** — the `xla` crate (PJRT C API): `PjRtClient::cpu()` ->
//!   `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//!   HLO *text* is the interchange format (see python/compile/aot.py).
//!   Shape-specialized, fast, but only available when the AOT step ran
//!   and a PJRT plugin exists.
//! * **native tile programs** — `crate::exec`: the arrangement executed
//!   directly over host buffers by the grid scheduler.  Shape-polymorphic
//!   and always available; the [`Registry`] falls back to it when an
//!   artifact is missing.

mod host;
mod manifest;
mod registry;

pub use host::{HostData, HostTensor};
pub use manifest::{GoldenCase, KernelArtifact, Manifest, ModelInfo, WeightEntry};
pub use registry::{ExecKey, Registry};

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

/// A compiled, loaded executable plus its output arity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Executable {
    /// Execute with host tensors, returning host tensors.
    ///
    /// Handles both root conventions jax's HLO dialect produces: a plain
    /// array root for single-output functions and a tuple root for
    /// multi-output functions.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.run_literals(&literals)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with pre-built literals (hot-path variant: callers keep
    /// reusable input literals — weights are passed by reference so the
    /// decode loop never re-serializes them).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let buffers = &result[0];
        let mut literals = Vec::with_capacity(self.n_outputs);
        if buffers.len() == 1 && self.n_outputs > 1 {
            // tuple root: one buffer holding the whole tuple
            let lit = buffers[0].to_literal_sync()?;
            literals.extend(lit.to_tuple()?);
        } else {
            for b in buffers.iter() {
                let lit = b.to_literal_sync()?;
                // a 1-tuple root still needs unwrapping
                if self.n_outputs == 1 && matches!(lit.shape(), Ok(xla::Shape::Tuple(_))) {
                    literals.extend(lit.to_tuple()?);
                } else {
                    literals.push(lit);
                }
            }
        }
        anyhow::ensure!(
            literals.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            literals.len()
        );
        Ok(literals)
    }
}

/// Variants the native fallback may serve when no artifact exists.  The
/// tile programs implement the `nt` application semantics; `baseline`
/// computes the same mathematical function, so serving it natively is
/// sound; `ref` goes to the reference oracle.  Anything else (a typo, a
/// future variant) is rejected at admission instead of silently served.
pub const NATIVE_VARIANTS: &[&str] = &["nt", "baseline", "native", "ref"];

/// Decide how a (kernel, variant) with no artifact is served — the single
/// classifier both router admission and [`Registry::resolve`] consult, so
/// the two can never drift apart.
pub fn native_fallback_kind(name: &str, variant: &str) -> Result<BackendKind> {
    if !NATIVE_VARIANTS.contains(&variant) {
        anyhow::bail!(
            "the native fallback serves only variants {NATIVE_VARIANTS:?}, not {variant:?}"
        );
    }
    if variant == "ref" && crate::exec::reference::supports(name) {
        return Ok(BackendKind::Reference);
    }
    if crate::exec::lookup(name).is_some() {
        return Ok(BackendKind::Native);
    }
    anyhow::bail!("kernel {name} has no native tile program or reference oracle")
}

/// Which execution path a resolved backend uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// compiled AOT artifact via PJRT
    Artifact,
    /// native tile-program execution (`crate::exec`)
    Native,
    /// straightforward reference implementation (`crate::exec::reference`)
    Reference,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Artifact => "artifact",
            BackendKind::Native => "native",
            BackendKind::Reference => "reference",
        }
    }
}

/// Something that can execute one kernel: an AOT artifact or a native
/// tile program.  Not `Send` — artifact executables hold `Rc`-based PJRT
/// handles, so each coordinator worker owns its own registry, exactly as
/// before.
pub trait Backend {
    fn name(&self) -> &str;
    fn kind(&self) -> BackendKind;
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// [`Backend`] over a compiled AOT artifact.
pub struct ArtifactBackend {
    pub exe: Arc<Executable>,
}

impl Backend for ArtifactBackend {
    fn name(&self) -> &str {
        &self.exe.name
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Artifact
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.exe.run(inputs)
    }
}

/// [`Backend`] over a native tile program.
pub struct NativeBackend {
    kernel: &'static crate::exec::NativeKernel,
    scheduler: crate::exec::GridScheduler,
    label: String,
}

impl NativeBackend {
    pub fn new(kernel: &'static crate::exec::NativeKernel, threads: usize) -> NativeBackend {
        NativeBackend {
            kernel,
            scheduler: crate::exec::GridScheduler::pooled(threads),
            label: format!("{}.native", kernel.name),
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.kernel.run(inputs, &self.scheduler)
    }
}

/// [`Backend`] over the reference oracles (the `ref` variant when no
/// artifact exists).
pub struct RefBackend {
    kernel: String,
    label: String,
}

impl RefBackend {
    pub fn new(kernel: &str) -> RefBackend {
        RefBackend { kernel: kernel.to_string(), label: format!("{kernel}.ref-native") }
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Reference
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        crate::exec::reference::run(&self.kernel, inputs)
    }
}

/// The PJRT client plus compile cache — shared by coordinator and harness.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load_artifact(&self, path: &Path, name: &str, n_outputs: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { name: name.to_string(), exe, n_outputs })
    }
}
