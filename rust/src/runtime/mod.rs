//! Runtime: execution backends + AOT artifact loading (L3 <-> L2 bridge).
//!
//! Two ways to execute a kernel meet behind the [`Backend`] trait:
//!
//! * **artifacts** — the `xla` crate (PJRT C API): `PjRtClient::cpu()` ->
//!   `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//!   HLO *text* is the interchange format (see python/compile/aot.py).
//!   Shape-specialized, fast, but only available when the AOT step ran
//!   and a PJRT plugin exists.
//! * **native tile programs** — `crate::exec`: the arrangement compiled
//!   per shape signature (memoized in the registry's shared
//!   [`crate::exec::PlanCache`]) and executed over host buffers by the
//!   grid scheduler.  Shape-polymorphic and always available; the
//!   [`Registry`] falls back to it when an artifact is missing.
//!
//! Both meet behind [`Backend`]'s `prepare(shapes) -> Prepared` /
//! `execute(prepared, inputs)` split, so the coordinator drives one
//! compile-once/execute-many lifecycle regardless of the path.

mod host;
mod manifest;
mod registry;

pub use host::{HostData, HostTensor};
pub use manifest::{GoldenCase, KernelArtifact, Manifest, ModelInfo, WeightEntry};
pub use registry::{ExecKey, Registry};

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

/// A compiled, loaded executable plus its output arity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Executable {
    /// Execute with host tensors, returning host tensors.
    ///
    /// Handles both root conventions jax's HLO dialect produces: a plain
    /// array root for single-output functions and a tuple root for
    /// multi-output functions.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.run_literals(&literals)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with pre-built literals (hot-path variant: callers keep
    /// reusable input literals — weights are passed by reference so the
    /// decode loop never re-serializes them).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let buffers = &result[0];
        let mut literals = Vec::with_capacity(self.n_outputs);
        if buffers.len() == 1 && self.n_outputs > 1 {
            // tuple root: one buffer holding the whole tuple
            let lit = buffers[0].to_literal_sync()?;
            literals.extend(lit.to_tuple()?);
        } else {
            for b in buffers.iter() {
                let lit = b.to_literal_sync()?;
                // a 1-tuple root still needs unwrapping
                if self.n_outputs == 1 && matches!(lit.shape(), Ok(xla::Shape::Tuple(_))) {
                    literals.extend(lit.to_tuple()?);
                } else {
                    literals.push(lit);
                }
            }
        }
        anyhow::ensure!(
            literals.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            literals.len()
        );
        Ok(literals)
    }
}

/// Variants the native fallback may serve when no artifact exists.  The
/// tile programs implement the `nt` application semantics; `baseline`
/// computes the same mathematical function, so serving it natively is
/// sound; `ref` goes to the reference oracle.  Anything else (a typo, a
/// future variant) is rejected at admission instead of silently served.
pub const NATIVE_VARIANTS: &[&str] = &["nt", "baseline", "native", "ref"];

/// Decide how a (kernel, variant) with no artifact is served — the single
/// classifier both router admission and [`Registry::resolve`] consult, so
/// the two can never drift apart.
pub fn native_fallback_kind(name: &str, variant: &str) -> Result<BackendKind> {
    if !NATIVE_VARIANTS.contains(&variant) {
        anyhow::bail!(
            "the native fallback serves only variants {NATIVE_VARIANTS:?}, not {variant:?}"
        );
    }
    if variant == "ref" && crate::exec::reference::supports(name) {
        return Ok(BackendKind::Reference);
    }
    match crate::kernel::lookup(name) {
        Some(def) if def.executable() => Ok(BackendKind::Native),
        Some(def) => anyhow::bail!(
            "kernel {name} is registered but its arrangement cannot be lowered natively: {}",
            def.probe_error().unwrap_or("unknown probe failure")
        ),
        None => anyhow::bail!("kernel {name} has no native tile program or reference oracle"),
    }
}

/// Which execution path a resolved backend uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// compiled AOT artifact via PJRT
    Artifact,
    /// native tile-program execution (`crate::exec`)
    Native,
    /// straightforward reference implementation (`crate::exec::reference`)
    Reference,
}

impl BackendKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Artifact => "artifact",
            BackendKind::Native => "native",
            BackendKind::Reference => "reference",
        }
    }
}

/// The reusable execution handle [`Backend::prepare`] resolves shapes to
/// — the uniform compile-once/execute-many lifecycle across all backends.
/// For the native path it is the plan-cached [`crate::exec::CompiledProgram`];
/// artifacts are compiled ahead of time, so their handle is the
/// executable itself; reference oracles need no preparation at all.
pub enum Prepared {
    /// an AOT artifact (already shape-specialized at compile time)
    Artifact(Arc<Executable>),
    /// a native compiled program out of the plan cache
    Native(Arc<crate::exec::CompiledProgram>),
    /// reference oracles are interpreted directly
    Reference,
}

/// Something that can execute one kernel: an AOT artifact or a native
/// tile program.  Not `Send` — artifact executables hold `Rc`-based PJRT
/// handles, so each coordinator worker owns its own registry, exactly as
/// before (the plan cache *is* shared across workers).
///
/// The lifecycle is split in two so callers can amortize the expensive
/// half: [`Backend::prepare`] resolves input *shapes* to a reusable
/// [`Prepared`] handle (cache hit on the native path when the shape was
/// seen before), and [`Backend::execute`] runs the handle over concrete
/// tensors.  [`Backend::run`] is the one-shot convenience composition.
pub trait Backend {
    fn name(&self) -> &str;
    fn kind(&self) -> BackendKind;
    /// Resolve concrete input shapes to a reusable execution handle.
    fn prepare(&self, shapes: &[&[usize]]) -> Result<Prepared>;
    /// [`Backend::prepare`] plus plan-cache attribution: `Some(true)` when
    /// the handle came from a cache hit, `Some(false)` when it compiled
    /// fresh, `None` when the backend has no plan cache (artifact /
    /// reference paths).  The coordinator's tracer records this per
    /// request.
    fn prepare_traced(&self, shapes: &[&[usize]]) -> Result<(Prepared, Option<bool>)> {
        Ok((self.prepare(shapes)?, None))
    }
    /// Execute a prepared handle over concrete inputs.
    fn execute(&self, prepared: &Prepared, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
    /// prepare + execute in one step.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        let prepared = self.prepare(&shapes)?;
        self.execute(&prepared, inputs)
    }
}

/// [`Backend`] over a compiled AOT artifact.
pub struct ArtifactBackend {
    pub exe: Arc<Executable>,
}

impl Backend for ArtifactBackend {
    fn name(&self) -> &str {
        &self.exe.name
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Artifact
    }

    fn prepare(&self, _shapes: &[&[usize]]) -> Result<Prepared> {
        // artifacts are compiled ahead of time for fixed shapes; shape
        // agreement is enforced at admission and by PJRT itself
        Ok(Prepared::Artifact(self.exe.clone()))
    }

    fn execute(&self, prepared: &Prepared, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match prepared {
            Prepared::Artifact(exe) => exe.run(inputs),
            _ => anyhow::bail!("artifact backend {} handed a non-artifact handle", self.exe.name),
        }
    }
}

/// [`Backend`] over a native tile program: `prepare` consults the shared
/// plan cache (specializing + lowering only on a miss), `execute` launches
/// the cached program over the persistent pool.
pub struct NativeBackend {
    kernel: Arc<crate::kernel::KernelDef>,
    variant: String,
    scheduler: crate::exec::GridScheduler,
    plans: Arc<crate::exec::PlanCache>,
    label: String,
}

impl NativeBackend {
    pub fn new(
        kernel: Arc<crate::kernel::KernelDef>,
        variant: &str,
        threads: usize,
        plans: Arc<crate::exec::PlanCache>,
    ) -> NativeBackend {
        let label = format!("{}.native", kernel.name);
        NativeBackend {
            kernel,
            variant: variant.to_string(),
            scheduler: crate::exec::GridScheduler::pooled(threads),
            plans,
            label,
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn prepare(&self, shapes: &[&[usize]]) -> Result<Prepared> {
        Ok(Prepared::Native(self.plans.prepare(&self.kernel, &self.variant, shapes)?))
    }

    fn prepare_traced(&self, shapes: &[&[usize]]) -> Result<(Prepared, Option<bool>)> {
        let (compiled, hit) =
            self.plans.prepare_with_outcome(&self.kernel, &self.variant, shapes)?;
        Ok((Prepared::Native(compiled), Some(hit)))
    }

    fn execute(&self, prepared: &Prepared, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match prepared {
            Prepared::Native(compiled) => compiled.execute(inputs, &self.scheduler),
            _ => anyhow::bail!("native backend {} handed a non-native handle", self.label),
        }
    }
}

/// [`Backend`] over the reference oracles (the `ref` variant when no
/// artifact exists).
pub struct RefBackend {
    kernel: String,
    label: String,
}

impl RefBackend {
    pub fn new(kernel: &str) -> RefBackend {
        RefBackend { kernel: kernel.to_string(), label: format!("{kernel}.ref-native") }
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &str {
        &self.label
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Reference
    }

    fn prepare(&self, _shapes: &[&[usize]]) -> Result<Prepared> {
        Ok(Prepared::Reference)
    }

    fn execute(&self, prepared: &Prepared, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match prepared {
            Prepared::Reference => crate::exec::reference::run(&self.kernel, inputs),
            _ => anyhow::bail!("reference backend {} handed a non-reference handle", self.label),
        }
    }
}

/// The PJRT client plus compile cache — shared by coordinator and harness.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load_artifact(&self, path: &Path, name: &str, n_outputs: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { name: name.to_string(), exe, n_outputs })
    }
}
