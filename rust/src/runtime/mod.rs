//! Runtime: PJRT client wrapper + AOT artifact loading (L3 <-> L2 bridge).
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.
//! HLO *text* is the interchange format (see python/compile/aot.py).

mod host;
mod manifest;
mod registry;

pub use host::HostTensor;
pub use manifest::{GoldenCase, KernelArtifact, Manifest, ModelInfo, WeightEntry};
pub use registry::{ExecKey, Registry};

use std::path::Path;

use anyhow::{Context, Result};

/// A compiled, loaded executable plus its output arity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub n_outputs: usize,
}

impl Executable {
    /// Execute with host tensors, returning host tensors.
    ///
    /// Handles both root conventions jax's HLO dialect produces: a plain
    /// array root for single-output functions and a tuple root for
    /// multi-output functions.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.run_literals(&literals)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute with pre-built literals (hot-path variant: callers keep
    /// reusable input literals — weights are passed by reference so the
    /// decode loop never re-serializes them).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let buffers = &result[0];
        let mut literals = Vec::with_capacity(self.n_outputs);
        if buffers.len() == 1 && self.n_outputs > 1 {
            // tuple root: one buffer holding the whole tuple
            let lit = buffers[0].to_literal_sync()?;
            literals.extend(lit.to_tuple()?);
        } else {
            for b in buffers.iter() {
                let lit = b.to_literal_sync()?;
                // a 1-tuple root still needs unwrapping
                if self.n_outputs == 1 && matches!(lit.shape(), Ok(xla::Shape::Tuple(_))) {
                    literals.extend(lit.to_tuple()?);
                } else {
                    literals.push(lit);
                }
            }
        }
        anyhow::ensure!(
            literals.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            literals.len()
        );
        Ok(literals)
    }
}

/// The PJRT client plus compile cache — shared by coordinator and harness.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load_artifact(&self, path: &Path, name: &str, n_outputs: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { name: name.to_string(), exe, n_outputs })
    }
}
