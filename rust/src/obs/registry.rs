//! The per-kernel/per-shape metrics registry.
//!
//! A [`MetricsRegistry`] maps `(kernel, shape signature)` to the same
//! lock-free atomic [`Metrics`](crate::coordinator::Metrics) struct the
//! coordinator uses globally.  Handles are `Arc`s: the hot path takes a
//! read lock once per request to fetch (or, first time, a write lock to
//! create) the handle, then records with plain relaxed atomics exactly
//! like the global struct.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::coordinator::{Metrics, MetricsSnapshot};

/// One registry row, snapshotted.
///
/// `metrics.plan_hits`/`plan_misses` are zero here — plan-cache
/// attribution is per-kernel (not per-shape) and is joined in from
/// [`crate::exec::PlanCache::kernel_counters`] by
/// [`ObsSnapshot`](crate::obs::ObsSnapshot).
#[derive(Debug, Clone)]
pub struct KernelShapeSnapshot {
    pub kernel: String,
    pub shapes: String,
    pub metrics: MetricsSnapshot,
}

/// Concurrent map of per-(kernel, shape) [`Metrics`].
///
/// ```
/// use std::sync::atomic::Ordering;
/// use ninetoothed_repro::obs::MetricsRegistry;
///
/// let reg = MetricsRegistry::new();
/// let m = reg.handle("softmax", "64x256");
/// m.submitted.fetch_add(1, Ordering::Relaxed);
/// m.completed.fetch_add(1, Ordering::Relaxed);
/// m.observe_latency_us(120);
///
/// let rows = reg.snapshot();
/// assert_eq!(rows.len(), 1);
/// assert_eq!(rows[0].kernel, "softmax");
/// assert_eq!(rows[0].metrics.completed, 1);
/// assert_eq!(reg.merged().submitted, 1);
/// ```
pub struct MetricsRegistry {
    inner: RwLock<HashMap<(String, String), Arc<Metrics>>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { inner: RwLock::new(HashMap::new()) }
    }

    /// Fetch the metrics handle for `(kernel, shapes)`, creating it on
    /// first use.  Read-lock fast path; the write lock is only taken the
    /// first time a (kernel, shape) pair is seen.
    pub fn handle(&self, kernel: &str, shapes: &str) -> Arc<Metrics> {
        if let Some(m) = self
            .inner
            .read()
            .unwrap()
            .get(&(kernel.to_string(), shapes.to_string()))
        {
            return m.clone();
        }
        self.inner
            .write()
            .unwrap()
            .entry((kernel.to_string(), shapes.to_string()))
            .or_default()
            .clone()
    }

    /// Snapshot every row, sorted by kernel then shape signature.
    pub fn snapshot(&self) -> Vec<KernelShapeSnapshot> {
        let mut rows: Vec<KernelShapeSnapshot> = self
            .inner
            .read()
            .unwrap()
            .iter()
            .map(|((kernel, shapes), m)| KernelShapeSnapshot {
                kernel: kernel.clone(),
                shapes: shapes.clone(),
                metrics: m.snapshot(0, 0),
            })
            .collect();
        rows.sort_by(|a, b| (&a.kernel, &a.shapes).cmp(&(&b.kernel, &b.shapes)));
        rows
    }

    /// Sum of every row — equals the coordinator's bare global snapshot
    /// when both were recorded from the same requests.
    pub fn merged(&self) -> MetricsSnapshot {
        let mut total = MetricsSnapshot::empty();
        for row in self.snapshot() {
            total.merge(&row.metrics);
        }
        total
    }

    /// Number of distinct (kernel, shape) rows.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.read().unwrap().is_empty()
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::Ordering;

    use super::*;

    #[test]
    fn handle_returns_same_struct_for_same_key() {
        let reg = MetricsRegistry::new();
        let a = reg.handle("mm", "8x8|8x8");
        let b = reg.handle("mm", "8x8|8x8");
        assert!(Arc::ptr_eq(&a, &b));
        a.submitted.fetch_add(2, Ordering::Relaxed);
        assert_eq!(reg.snapshot()[0].metrics.submitted, 2);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn same_kernel_different_shapes_get_distinct_rows() {
        let reg = MetricsRegistry::new();
        reg.handle("softmax", "4x16").completed.fetch_add(1, Ordering::Relaxed);
        reg.handle("softmax", "4x32").completed.fetch_add(3, Ordering::Relaxed);
        let rows = reg.snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].shapes.as_str(), rows[0].metrics.completed), ("4x16", 1));
        assert_eq!((rows[1].shapes.as_str(), rows[1].metrics.completed), ("4x32", 3));
        assert_eq!(reg.merged().completed, 4);
    }
}
