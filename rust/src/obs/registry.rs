//! The per-kernel/per-shape/per-client metrics registry.
//!
//! A [`MetricsRegistry`] maps `(kernel, shape signature, client id)` to
//! the same lock-free atomic [`Metrics`](crate::coordinator::Metrics)
//! struct the coordinator uses globally.  Handles are `Arc`s: the hot
//! path takes a read lock once per request to fetch (or, first time, a
//! write lock to create) the handle, then records with plain relaxed
//! atomics exactly like the global struct.
//!
//! The client dimension is optional (`""` = unattributed, the
//! in-process / anonymous-wire default) and **cardinality-bounded**: at
//! most [`MAX_CLIENT_ROWS`] distinct client ids get their own rows;
//! later ids are folded into the [`OVERFLOW_CLIENT`] row so a client
//! that invents ids per request cannot grow the registry (or the
//! Prometheus exposition) without bound.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, RwLock};

use crate::coordinator::{Metrics, MetricsSnapshot};

/// Most distinct client ids that get dedicated rows; the rest fold into
/// [`OVERFLOW_CLIENT`].
pub const MAX_CLIENT_ROWS: usize = 64;

/// The shared row for clients beyond the cardinality cap.
pub const OVERFLOW_CLIENT: &str = "other";

/// One registry row, snapshotted.
///
/// `metrics.plan_hits`/`plan_misses` are zero here — plan-cache
/// attribution is per-kernel (not per-shape) and is joined in from
/// [`crate::exec::PlanCache::kernel_counters`] by
/// [`ObsSnapshot`](crate::obs::ObsSnapshot).
#[derive(Debug, Clone)]
pub struct KernelShapeSnapshot {
    pub kernel: String,
    pub shapes: String,
    /// client id the row is attributed to; `""` = unattributed,
    /// [`OVERFLOW_CLIENT`] = beyond the cardinality cap
    pub client: String,
    pub metrics: MetricsSnapshot,
}

struct Inner {
    rows: HashMap<(String, String, String), Arc<Metrics>>,
    /// distinct non-empty client ids holding dedicated rows
    clients: HashSet<String>,
}

/// Concurrent map of per-(kernel, shape, client) [`Metrics`].
///
/// ```
/// use std::sync::atomic::Ordering;
/// use ninetoothed_repro::obs::MetricsRegistry;
///
/// let reg = MetricsRegistry::new();
/// let m = reg.handle("softmax", "64x256");
/// m.submitted.fetch_add(1, Ordering::Relaxed);
/// m.completed.fetch_add(1, Ordering::Relaxed);
/// m.observe_latency_us(120);
///
/// let rows = reg.snapshot();
/// assert_eq!(rows.len(), 1);
/// assert_eq!(rows[0].kernel, "softmax");
/// assert_eq!(rows[0].client, "");
/// assert_eq!(rows[0].metrics.completed, 1);
/// assert_eq!(reg.merged().submitted, 1);
/// ```
pub struct MetricsRegistry {
    inner: RwLock<Inner>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            inner: RwLock::new(Inner { rows: HashMap::new(), clients: HashSet::new() }),
        }
    }

    /// Fetch the unattributed metrics handle for `(kernel, shapes)` —
    /// [`MetricsRegistry::handle_for`] without a client id.
    pub fn handle(&self, kernel: &str, shapes: &str) -> Arc<Metrics> {
        self.handle_for(kernel, shapes, None)
    }

    /// Fetch the metrics handle for `(kernel, shapes, client)`, creating
    /// it on first use.  Read-lock fast path; the write lock is only
    /// taken the first time a key is seen.  A new client id past
    /// [`MAX_CLIENT_ROWS`] resolves to the [`OVERFLOW_CLIENT`] row.
    pub fn handle_for(&self, kernel: &str, shapes: &str, client: Option<&str>) -> Arc<Metrics> {
        let client = client.unwrap_or("");
        {
            let inner = self.inner.read().unwrap();
            let eff = effective_client(&inner, client);
            let key = (kernel.to_string(), shapes.to_string(), eff.to_string());
            if let Some(m) = inner.rows.get(&key) {
                return m.clone();
            }
        }
        let mut inner = self.inner.write().unwrap();
        let eff = if client.is_empty() || inner.clients.contains(client) {
            client.to_string()
        } else if inner.clients.len() >= MAX_CLIENT_ROWS {
            OVERFLOW_CLIENT.to_string()
        } else {
            inner.clients.insert(client.to_string());
            client.to_string()
        };
        inner
            .rows
            .entry((kernel.to_string(), shapes.to_string(), eff))
            .or_default()
            .clone()
    }

    /// Snapshot every row, sorted by kernel, shape signature, client.
    pub fn snapshot(&self) -> Vec<KernelShapeSnapshot> {
        let mut rows: Vec<KernelShapeSnapshot> = self
            .inner
            .read()
            .unwrap()
            .rows
            .iter()
            .map(|((kernel, shapes, client), m)| KernelShapeSnapshot {
                kernel: kernel.clone(),
                shapes: shapes.clone(),
                client: client.clone(),
                metrics: m.snapshot(0, 0),
            })
            .collect();
        rows.sort_by(|a, b| {
            (&a.kernel, &a.shapes, &a.client).cmp(&(&b.kernel, &b.shapes, &b.client))
        });
        rows
    }

    /// Sum of every row — equals the coordinator's bare global snapshot
    /// when both were recorded from the same requests.
    pub fn merged(&self) -> MetricsSnapshot {
        let mut total = MetricsSnapshot::empty();
        for row in self.snapshot() {
            total.merge(&row.metrics);
        }
        total
    }

    /// Number of distinct (kernel, shape, client) rows.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.read().unwrap().rows.is_empty()
    }

    /// Distinct client ids currently holding dedicated rows (excludes
    /// `""` and [`OVERFLOW_CLIENT`]).
    pub fn distinct_clients(&self) -> usize {
        self.inner.read().unwrap().clients.len()
    }
}

/// Resolve the row a client id lands in without mutating: known and
/// unattributed ids map to themselves; an unknown id maps to itself
/// while dedicated slots remain, otherwise to the overflow row.
fn effective_client<'a>(inner: &Inner, client: &'a str) -> &'a str {
    if client.is_empty() || inner.clients.contains(client) {
        client
    } else if inner.clients.len() >= MAX_CLIENT_ROWS {
        OVERFLOW_CLIENT
    } else {
        client
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::atomic::Ordering;

    use super::*;

    #[test]
    fn handle_returns_same_struct_for_same_key() {
        let reg = MetricsRegistry::new();
        let a = reg.handle("mm", "8x8|8x8");
        let b = reg.handle("mm", "8x8|8x8");
        assert!(Arc::ptr_eq(&a, &b));
        a.submitted.fetch_add(2, Ordering::Relaxed);
        assert_eq!(reg.snapshot()[0].metrics.submitted, 2);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn same_kernel_different_shapes_get_distinct_rows() {
        let reg = MetricsRegistry::new();
        reg.handle("softmax", "4x16").completed.fetch_add(1, Ordering::Relaxed);
        reg.handle("softmax", "4x32").completed.fetch_add(3, Ordering::Relaxed);
        let rows = reg.snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].shapes.as_str(), rows[0].metrics.completed), ("4x16", 1));
        assert_eq!((rows[1].shapes.as_str(), rows[1].metrics.completed), ("4x32", 3));
        assert_eq!(reg.merged().completed, 4);
    }

    #[test]
    fn clients_get_distinct_rows_sorted_after_unattributed() {
        let reg = MetricsRegistry::new();
        reg.handle_for("mm", "8x8|8x8", Some("acme")).completed.fetch_add(1, Ordering::Relaxed);
        reg.handle("mm", "8x8|8x8").completed.fetch_add(2, Ordering::Relaxed);
        let a = reg.handle_for("mm", "8x8|8x8", Some("acme"));
        let b = reg.handle_for("mm", "8x8|8x8", Some("acme"));
        assert!(Arc::ptr_eq(&a, &b));
        let rows = reg.snapshot();
        assert_eq!(rows.len(), 2);
        assert_eq!((rows[0].client.as_str(), rows[0].metrics.completed), ("", 2));
        assert_eq!((rows[1].client.as_str(), rows[1].metrics.completed), ("acme", 1));
        assert_eq!(reg.merged().completed, 3);
        assert_eq!(reg.distinct_clients(), 1);
    }

    #[test]
    fn client_cardinality_overflows_into_other() {
        let reg = MetricsRegistry::new();
        for i in 0..MAX_CLIENT_ROWS + 8 {
            reg.handle_for("mm", "8x8|8x8", Some(&format!("client_{i:03}")))
                .completed
                .fetch_add(1, Ordering::Relaxed);
        }
        assert_eq!(reg.distinct_clients(), MAX_CLIENT_ROWS);
        // MAX dedicated rows + one shared overflow row
        assert_eq!(reg.len(), MAX_CLIENT_ROWS + 1);
        let rows = reg.snapshot();
        let other = rows.iter().find(|r| r.client == OVERFLOW_CLIENT).unwrap();
        assert_eq!(other.metrics.completed, 8);
        // an already-capped id keeps resolving to its dedicated row
        reg.handle_for("mm", "8x8|8x8", Some("client_000"))
            .completed
            .fetch_add(1, Ordering::Relaxed);
        assert_eq!(reg.len(), MAX_CLIENT_ROWS + 1);
        let rows = reg.snapshot();
        let first = rows.iter().find(|r| r.client == "client_000").unwrap();
        assert_eq!(first.metrics.completed, 2);
        assert_eq!(reg.merged().completed, (MAX_CLIENT_ROWS + 9) as u64);
    }
}
