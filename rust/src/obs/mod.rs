//! Observability: the measurement substrate for the serving stack.
//!
//! Three std-only layers, all exported through one [`ObsSnapshot`]:
//!
//! 1. **Per-kernel/per-shape metrics** — [`MetricsRegistry`], a concurrent
//!    map of the coordinator's lock-free atomic
//!    [`Metrics`](crate::coordinator::Metrics) keyed by kernel name and
//!    shape signature, recorded at the same submit/complete/reject/coalesce
//!    points as the global struct, with per-kernel plan-cache hit/miss
//!    attribution joined in from [`crate::exec::PlanCache`].
//! 2. **Request tracing** — [`TraceRecorder`], a sampled ring buffer of
//!    per-request span timelines (queued → batch → plan → execute →
//!    reply), with an ASCII [`render_waterfall`] for the slowest recent
//!    requests.  Sampling knob: `NT_TRACE_SAMPLE=k` keeps every k-th
//!    request.
//! 3. **Execution profiling** — [`ProfileReport`], opt-in (`NT_PROFILE=1`)
//!    wall-time attribution per IR instruction kind and per grid cell,
//!    attached to each compiled plan, plus worker-pool [`PoolGauges`].
//! 4. **Latency SLOs** — [`SloEngine`], per-kernel / per-client
//!    objectives (`NT_SLO`) evaluated over rolling windows against the
//!    registry's histograms; a burning error budget feeds back into
//!    admission (the coordinator halves its shed watermark).
//! 5. **The flight recorder** — [`EventLog`], a bounded NDJSON event
//!    log (`NT_EVENT_LOG`) of admissions, sheds, plan compiles, tune
//!    decisions, SLO breaches and slow-request traces (`NT_SLOW_US`).
//!
//! Snapshots render three ways: a human table ([`ObsSnapshot::render_table`],
//! the `repro stats` subcommand), Prometheus text exposition
//! ([`ObsSnapshot::render_prometheus`], ready for a future TCP `/metrics`
//! endpoint), and JSON ([`ObsSnapshot::to_json`]).

pub mod events;
pub mod profile;
pub mod registry;
pub mod slo;
pub mod trace;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::MetricsSnapshot;
use crate::json::Json;
pub use events::EventLog;
pub use profile::{InstrStat, PoolGauges, ProfileReport, ProfileSnapshot, INSTR_KINDS};
pub use registry::{KernelShapeSnapshot, MetricsRegistry, MAX_CLIENT_ROWS, OVERFLOW_CLIENT};
pub use slo::{parse_slo_spec, SloEngine, SloObjective, SloStatus};
pub use trace::{render_waterfall, Span, SpanKind, Trace, TraceRecorder};

/// How many slowest traces an [`ObsSnapshot`] retains and renders.
pub const TRACE_TOP_N: usize = 5;

/// Canonical shape signature: dims joined with `x`, tensors joined with
/// `|` — `[[70,50],[50,90]]` → `"70x50|50x90"`.  Rank-0 tensors render as
/// `scalar`, an empty input list as `-`.
pub fn shape_sig(shapes: &[&[usize]]) -> String {
    if shapes.is_empty() {
        return "-".to_string();
    }
    shapes
        .iter()
        .map(|dims| {
            if dims.is_empty() {
                "scalar".to_string()
            } else {
                dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
            }
        })
        .collect::<Vec<_>>()
        .join("|")
}

/// The live recording half of the layer: one per coordinator, shared by
/// every worker.  (Profiles live on compiled plans; pool gauges live on
/// the global pool — both are pulled in at snapshot time.)
pub struct Obs {
    pub per_kernel: MetricsRegistry,
    pub traces: TraceRecorder,
    pub slo: SloEngine,
    pub events: EventLog,
}

impl Obs {
    /// Build with knobs from the environment (`NT_TRACE_SAMPLE`); garbage
    /// values fail loudly, matching the pool knobs.  The SLO engine and
    /// flight recorder start disabled — their knobs (`NT_SLO`,
    /// `NT_EVENT_LOG`, …) are coordinator configuration, installed by
    /// `Coordinator::start` from `CoordinatorConfig`.
    pub fn from_env() -> Result<Obs> {
        Ok(Obs {
            per_kernel: MetricsRegistry::new(),
            traces: TraceRecorder::from_env()?,
            slo: SloEngine::disabled(),
            events: EventLog::disabled(),
        })
    }

    /// Evaluate the SLO window if one is due (cheap no-op otherwise) and
    /// log breach transitions to the flight recorder.
    pub fn tick_slo(&self) {
        for breached in self.slo.maybe_evaluate(&self.per_kernel) {
            self.events.slo_breach(&breached);
        }
    }

    /// Account a finished request's trace: offer it to slow-request
    /// capture, then ring it if the request was sampled.  The coordinator
    /// calls this for in-process completions, the wire front door after
    /// the reply write (so the trace carries the `net_write` span).
    pub fn note_request_done(&self, sampled: bool, trace: Trace) {
        self.events.maybe_slow_request(&trace);
        if sampled {
            self.traces.record(trace);
        }
    }
}

/// A point-in-time copy of everything the layer knows, ready to render.
pub struct ObsSnapshot {
    /// the coordinator's global counters, plan h/m included
    pub global: MetricsSnapshot,
    /// per-(kernel, shape, client) rows, sorted; plan h/m zero (see
    /// `plan_kernels`)
    pub kernels: Vec<KernelShapeSnapshot>,
    /// per-kernel plan-cache (hits, misses) from [`crate::exec::PlanCache`]
    pub plan_kernels: Vec<(String, u64, u64)>,
    /// per-objective SLO verdicts for the last evaluated window (empty
    /// when no `NT_SLO` is configured)
    pub slo: Vec<SloStatus>,
    /// the `TRACE_TOP_N` slowest retained traces, slowest first
    pub traces: Vec<Trace>,
    /// per-plan profiles (non-empty only under `NT_PROFILE=1`)
    pub profiles: Vec<ProfileSnapshot>,
    pub pool: PoolGauges,
}

impl ObsSnapshot {
    fn plan_for(&self, kernel: &str) -> (u64, u64) {
        self.plan_kernels
            .iter()
            .find(|(k, _, _)| k == kernel)
            .map(|(_, h, m)| (*h, *m))
            .unwrap_or((0, 0))
    }

    /// The human-readable stats table `repro stats` prints.
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.global.render());
        out.push_str("\nper-kernel/per-shape (plan h/m is kernel-level):\n");
        out.push_str(&format!(
            "  {:<10} {:<24} {:<10} {:>6} {:>8} {:>8} {:>9} {:>9} {:>11} {:>5} {:>8}\n",
            "kernel", "shapes", "client", "count", "p50_us", "p99_us", "coalesced", "batched",
            "plan h/m", "tuned", "tune_ms"
        ));
        for row in &self.kernels {
            let m = &row.metrics;
            let (hits, misses) = self.plan_for(&row.kernel);
            let client = if row.client.is_empty() { "-" } else { row.client.as_str() };
            out.push_str(&format!(
                "  {:<10} {:<24} {:<10} {:>6} {:>8} {:>8} {:>9} {:>9} {:>11} {:>5} {:>8.1}\n",
                row.kernel,
                row.shapes,
                client,
                m.completed,
                m.latency_quantile_us(0.5),
                m.latency_quantile_us(0.99),
                m.coalesced,
                m.batched,
                format!("{hits}/{misses}"),
                m.tuned_plans,
                m.tune_us_total as f64 / 1000.0,
            ));
        }
        if !self.slo.is_empty() {
            out.push_str("slo objectives (burn = violation rate / error budget):\n");
            for s in &self.slo {
                out.push_str(&format!(
                    "  {:<28} window n={:<6} viol={:<6} burn={:<8.2} {}\n",
                    s.objective,
                    s.window_total,
                    s.window_violations,
                    s.burn_rate,
                    if s.burning { "BURNING" } else { "ok" }
                ));
            }
        }
        out.push_str(&self.pool.render());
        out.push('\n');
        if !self.traces.is_empty() {
            out.push_str(&format!("slowest {} traced requests:\n", self.traces.len()));
            out.push_str(&render_waterfall(&self.traces));
        }
        for p in &self.profiles {
            out.push_str(&p.render());
            out.push('\n');
        }
        out
    }

    /// Prometheus text exposition (version 0.0.4): `# HELP`/`# TYPE`
    /// preambles, cumulative `le` buckets for the latency histogram, and
    /// escaped label values.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let g = &self.global;

        out.push_str("# HELP nt_requests_total Requests by lifecycle event.\n");
        out.push_str("# TYPE nt_requests_total counter\n");
        for (event, v) in [
            ("submitted", g.submitted),
            ("completed", g.completed),
            ("rejected", g.rejected),
            ("shed", g.shed),
            ("batched", g.batched),
            ("coalesced", g.coalesced),
        ] {
            out.push_str(&format!("nt_requests_total{{event=\"{event}\"}} {v}\n"));
        }
        out.push_str("# HELP nt_net_timeouts_total Wire connections closed on read/write timeout.\n");
        out.push_str("# TYPE nt_net_timeouts_total counter\n");
        out.push_str(&format!("nt_net_timeouts_total {}\n", g.net_timeouts));
        out.push_str("# HELP nt_executions_total Backend launches (batches count once).\n");
        out.push_str("# TYPE nt_executions_total counter\n");
        out.push_str(&format!("nt_executions_total {}\n", g.executions));
        out.push_str("# HELP nt_exec_us_total Wall microseconds spent executing backends.\n");
        out.push_str("# TYPE nt_exec_us_total counter\n");
        out.push_str(&format!("nt_exec_us_total {}\n", g.exec_us_total));
        out.push_str("# HELP nt_queue_us_total Microseconds requests spent queued.\n");
        out.push_str("# TYPE nt_queue_us_total counter\n");
        out.push_str(&format!("nt_queue_us_total {}\n", g.queue_us_total));

        out.push_str("# HELP nt_tuned_plans_total Autotune searches that installed a winner.\n");
        out.push_str("# TYPE nt_tuned_plans_total counter\n");
        out.push_str(&format!("nt_tuned_plans_total {}\n", g.tuned_plans));
        out.push_str("# HELP nt_tune_us_total Wall microseconds spent in autotune searches.\n");
        out.push_str("# TYPE nt_tune_us_total counter\n");
        out.push_str(&format!("nt_tune_us_total {}\n", g.tune_us_total));
        out.push_str(
            "# HELP nt_tune_measurements_total Timed candidate executions performed by \
             autotune searches (0 after a warm restart against a tuning table).\n",
        );
        out.push_str("# TYPE nt_tune_measurements_total counter\n");
        out.push_str(&format!("nt_tune_measurements_total {}\n", g.tune_measurements));

        out.push_str("# HELP nt_plan_cache_total Compiled-plan cache lookups by result.\n");
        out.push_str("# TYPE nt_plan_cache_total counter\n");
        out.push_str(&format!("nt_plan_cache_total{{result=\"hit\"}} {}\n", g.plan_hits));
        out.push_str(&format!("nt_plan_cache_total{{result=\"miss\"}} {}\n", g.plan_misses));

        out.push_str("# HELP nt_request_latency_us Submit-to-reply latency histogram.\n");
        out.push_str("# TYPE nt_request_latency_us histogram\n");
        let mut cumulative = 0u64;
        for (i, count) in g.latency_hist.iter().enumerate() {
            cumulative += count;
            let le = (1u64 << (i + 1)) - 1;
            out.push_str(&format!(
                "nt_request_latency_us_bucket{{le=\"{le}\"}} {cumulative}\n"
            ));
        }
        out.push_str(&format!(
            "nt_request_latency_us_bucket{{le=\"+Inf\"}} {cumulative}\n"
        ));
        out.push_str(&format!("nt_request_latency_us_sum {}\n", g.latency_us_sum));
        out.push_str(&format!("nt_request_latency_us_count {cumulative}\n"));

        out.push_str("# HELP nt_kernel_requests_total Per-kernel/per-shape requests by event.\n");
        out.push_str("# TYPE nt_kernel_requests_total counter\n");
        for row in &self.kernels {
            let labels = row_labels(row);
            let m = &row.metrics;
            for (event, v) in [
                ("submitted", m.submitted),
                ("completed", m.completed),
                ("rejected", m.rejected),
                ("shed", m.shed),
                ("batched", m.batched),
                ("coalesced", m.coalesced),
                ("tuned", m.tuned_plans),
            ] {
                out.push_str(&format!(
                    "nt_kernel_requests_total{{{labels},event=\"{event}\"}} {v}\n"
                ));
            }
        }
        out.push_str("# HELP nt_kernel_latency_us Per-kernel/per-shape latency quantiles.\n");
        out.push_str("# TYPE nt_kernel_latency_us gauge\n");
        for row in &self.kernels {
            let labels = row_labels(row);
            for (q, label) in [(0.5, "0.5"), (0.99, "0.99")] {
                out.push_str(&format!(
                    "nt_kernel_latency_us{{{labels},quantile=\"{label}\"}} {}\n",
                    row.metrics.latency_quantile_us(q)
                ));
            }
        }
        if !self.slo.is_empty() {
            out.push_str(
                "# HELP nt_slo_burn_rate Error-budget burn rate per objective \
                 over the last window (>1 = burning).\n",
            );
            out.push_str("# TYPE nt_slo_burn_rate gauge\n");
            for s in &self.slo {
                out.push_str(&format!(
                    "nt_slo_burn_rate{{objective=\"{}\"}} {:.4}\n",
                    escape_label(&s.objective),
                    s.burn_rate
                ));
            }
            out.push_str(
                "# HELP nt_slo_burning Whether the objective is burning \
                 (admission sheds early).\n",
            );
            out.push_str("# TYPE nt_slo_burning gauge\n");
            for s in &self.slo {
                out.push_str(&format!(
                    "nt_slo_burning{{objective=\"{}\"}} {}\n",
                    escape_label(&s.objective),
                    u64::from(s.burning)
                ));
            }
            out.push_str(
                "# HELP nt_slo_window_total Completions in the objective's last window.\n",
            );
            out.push_str("# TYPE nt_slo_window_total gauge\n");
            for s in &self.slo {
                out.push_str(&format!(
                    "nt_slo_window_total{{objective=\"{}\"}} {}\n",
                    escape_label(&s.objective),
                    s.window_total
                ));
            }
            out.push_str(
                "# HELP nt_slo_window_violations Estimated over-threshold completions \
                 in the objective's last window.\n",
            );
            out.push_str("# TYPE nt_slo_window_violations gauge\n");
            for s in &self.slo {
                out.push_str(&format!(
                    "nt_slo_window_violations{{objective=\"{}\"}} {}\n",
                    escape_label(&s.objective),
                    s.window_violations
                ));
            }
        }
        out.push_str("# HELP nt_kernel_plan_total Per-kernel plan-cache lookups by result.\n");
        out.push_str("# TYPE nt_kernel_plan_total counter\n");
        for (kernel, hits, misses) in &self.plan_kernels {
            let kernel = escape_label(kernel);
            out.push_str(&format!(
                "nt_kernel_plan_total{{kernel=\"{kernel}\",result=\"hit\"}} {hits}\n"
            ));
            out.push_str(&format!(
                "nt_kernel_plan_total{{kernel=\"{kernel}\",result=\"miss\"}} {misses}\n"
            ));
        }

        out.push_str("# HELP nt_pool_workers Persistent worker-pool threads.\n");
        out.push_str("# TYPE nt_pool_workers gauge\n");
        out.push_str(&format!("nt_pool_workers {}\n", self.pool.workers));
        out.push_str("# HELP nt_pool_queue_depth Jobs waiting in the pool's injector queue.\n");
        out.push_str("# TYPE nt_pool_queue_depth gauge\n");
        out.push_str(&format!("nt_pool_queue_depth {}\n", self.pool.queue_depth));
        out.push_str("# HELP nt_pool_busy_workers Workers currently executing a job.\n");
        out.push_str("# TYPE nt_pool_busy_workers gauge\n");
        out.push_str(&format!("nt_pool_busy_workers {}\n", self.pool.busy_workers));
        out.push_str("# HELP nt_pool_jobs_total Jobs executed by pool workers since start.\n");
        out.push_str("# TYPE nt_pool_jobs_total counter\n");
        out.push_str(&format!("nt_pool_jobs_total {}\n", self.pool.jobs_executed));
        out
    }

    /// The whole snapshot as a [`Json`] tree (serialize with `to_string`).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("global".to_string(), metrics_json(&self.global));
        root.insert(
            "kernels".to_string(),
            Json::Arr(
                self.kernels
                    .iter()
                    .map(|row| {
                        let (hits, misses) = self.plan_for(&row.kernel);
                        let mut o = BTreeMap::new();
                        o.insert("kernel".to_string(), Json::Str(row.kernel.clone()));
                        o.insert("shapes".to_string(), Json::Str(row.shapes.clone()));
                        o.insert("client".to_string(), Json::Str(row.client.clone()));
                        o.insert("metrics".to_string(), metrics_json(&row.metrics));
                        o.insert("plan_hits".to_string(), Json::Num(hits as f64));
                        o.insert("plan_misses".to_string(), Json::Num(misses as f64));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "traces".to_string(),
            Json::Arr(
                self.traces
                    .iter()
                    .map(|t| {
                        let mut o = BTreeMap::new();
                        o.insert("kernel".to_string(), Json::Str(t.kernel.clone()));
                        o.insert("shapes".to_string(), Json::Str(t.shapes.clone()));
                        o.insert("batch_size".to_string(), Json::Num(t.batch_size as f64));
                        o.insert("coalesced".to_string(), Json::Bool(t.coalesced));
                        o.insert(
                            "plan_hit".to_string(),
                            match t.plan_hit {
                                Some(b) => Json::Bool(b),
                                None => Json::Null,
                            },
                        );
                        o.insert("total_us".to_string(), Json::Num(t.total_us as f64));
                        o.insert(
                            "trace_id".to_string(),
                            match &t.trace_id {
                                Some(id) => Json::Str(id.clone()),
                                None => Json::Null,
                            },
                        );
                        o.insert(
                            "client_id".to_string(),
                            match &t.client_id {
                                Some(c) => Json::Str(c.clone()),
                                None => Json::Null,
                            },
                        );
                        o.insert(
                            "spans".to_string(),
                            Json::Arr(
                                t.spans
                                    .iter()
                                    .map(|s| {
                                        let mut so = BTreeMap::new();
                                        so.insert(
                                            "kind".to_string(),
                                            Json::Str(s.kind.name().to_string()),
                                        );
                                        so.insert(
                                            "start_us".to_string(),
                                            Json::Num(s.start_us as f64),
                                        );
                                        so.insert("end_us".to_string(), Json::Num(s.end_us as f64));
                                        Json::Obj(so)
                                    })
                                    .collect(),
                            ),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "profiles".to_string(),
            Json::Arr(
                self.profiles
                    .iter()
                    .map(|p| {
                        let mut o = BTreeMap::new();
                        o.insert("label".to_string(), Json::Str(p.label.clone()));
                        o.insert("cells".to_string(), Json::Num(p.cells as f64));
                        o.insert("cell_ns_total".to_string(), Json::Num(p.cell_ns_total as f64));
                        o.insert("cell_ns_max".to_string(), Json::Num(p.cell_ns_max as f64));
                        o.insert(
                            "instrs".to_string(),
                            Json::Arr(
                                p.instrs
                                    .iter()
                                    .map(|i| {
                                        let mut io = BTreeMap::new();
                                        io.insert(
                                            "kind".to_string(),
                                            Json::Str(i.kind.to_string()),
                                        );
                                        io.insert("count".to_string(), Json::Num(i.count as f64));
                                        io.insert(
                                            "total_ns".to_string(),
                                            Json::Num(i.total_ns as f64),
                                        );
                                        Json::Obj(io)
                                    })
                                    .collect(),
                            ),
                        );
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "slo".to_string(),
            Json::Arr(
                self.slo
                    .iter()
                    .map(|s| {
                        let mut o = BTreeMap::new();
                        o.insert("objective".to_string(), Json::Str(s.objective.clone()));
                        o.insert("quantile".to_string(), Json::Num(s.quantile));
                        o.insert("threshold_us".to_string(), Json::Num(s.threshold_us as f64));
                        o.insert("window_total".to_string(), Json::Num(s.window_total as f64));
                        o.insert(
                            "window_violations".to_string(),
                            Json::Num(s.window_violations as f64),
                        );
                        o.insert("burn_rate".to_string(), Json::Num(s.burn_rate));
                        o.insert("burning".to_string(), Json::Bool(s.burning));
                        Json::Obj(o)
                    })
                    .collect(),
            ),
        );
        let mut pool = BTreeMap::new();
        pool.insert("workers".to_string(), Json::Num(self.pool.workers as f64));
        pool.insert("queue_depth".to_string(), Json::Num(self.pool.queue_depth as f64));
        pool.insert("busy_workers".to_string(), Json::Num(self.pool.busy_workers as f64));
        pool.insert("jobs_executed".to_string(), Json::Num(self.pool.jobs_executed as f64));
        root.insert("pool".to_string(), Json::Obj(pool));
        Json::Obj(root)
    }
}

fn metrics_json(m: &MetricsSnapshot) -> Json {
    let mut o = BTreeMap::new();
    for (k, v) in [
        ("submitted", m.submitted),
        ("completed", m.completed),
        ("rejected", m.rejected),
        ("shed", m.shed),
        ("net_timeouts", m.net_timeouts),
        ("batched", m.batched),
        ("coalesced", m.coalesced),
        ("executions", m.executions),
        ("exec_us_total", m.exec_us_total),
        ("queue_us_total", m.queue_us_total),
        ("tuned_plans", m.tuned_plans),
        ("tune_us_total", m.tune_us_total),
        ("tune_measurements", m.tune_measurements),
        ("plan_hits", m.plan_hits),
        ("plan_misses", m.plan_misses),
        ("latency_us_sum", m.latency_us_sum),
        ("latency_p50_us", m.latency_quantile_us(0.5)),
        ("latency_p99_us", m.latency_quantile_us(0.99)),
    ] {
        o.insert(k.to_string(), Json::Num(v as f64));
    }
    Json::Obj(o)
}

/// The Prometheus label set for one registry row; the `client` label is
/// only present on attributed rows, so unattributed series keep their
/// pre-tenancy identity.
fn row_labels(row: &KernelShapeSnapshot) -> String {
    let mut labels = format!(
        "kernel=\"{}\",shapes=\"{}\"",
        escape_label(&row.kernel),
        escape_label(&row.shapes)
    );
    if !row.client.is_empty() {
        labels.push_str(&format!(",client=\"{}\"", escape_label(&row.client)));
    }
    labels
}

fn escape_label(value: &str) -> String {
    value
        .replace('\\', "\\\\")
        .replace('"', "\\\"")
        .replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_sig_formats() {
        assert_eq!(shape_sig(&[&[70, 50], &[50, 90]]), "70x50|50x90");
        assert_eq!(shape_sig(&[&[7, 301]]), "7x301");
        assert_eq!(shape_sig(&[&[]]), "scalar");
        assert_eq!(shape_sig(&[]), "-");
    }

    #[test]
    fn escape_label_handles_specials() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
