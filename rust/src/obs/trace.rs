//! Request tracing: a sampled, lock-cheap ring buffer of per-request
//! span timelines.
//!
//! The coordinator records one [`Trace`] per sampled request, built from
//! monotonic offsets against the request's submit instant: queued →
//! coalesced/batched → plan lookup or compile → grid execute → reply.
//! Sampling (`NT_TRACE_SAMPLE=k` keeps every k-th request, default 1 =
//! all) is decided with a single relaxed atomic increment at submit time,
//! so unsampled requests never touch the ring's mutex.  The ring holds
//! the most recent `capacity` traces; [`render_waterfall`] draws the
//! classic per-span timeline for the slowest of them.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

/// The phases a request passes through, in lifecycle order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// wire ingress: frame read + request decode (wire requests only;
    /// its duration shifts every later span right, so offsets count
    /// from the frame's arrival, not the coordinator submit)
    NetRead,
    /// first-use autotune search on the submitting thread (only present
    /// on the request that triggered it)
    Tune,
    /// submit → drained from the queue by a worker
    Queued,
    /// drained → batch assembled (pack/coalesce decision made)
    Batch,
    /// plan-cache lookup, compiling on a miss
    Plan,
    /// grid execution of the compiled plan
    Execute,
    /// unpack/unstack and reply delivery
    Reply,
    /// wire egress: the reply frame's write, appended by the front door
    /// after the write completes (wire requests only)
    NetWrite,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::NetRead => "net_read",
            SpanKind::Tune => "tune",
            SpanKind::Queued => "queued",
            SpanKind::Batch => "batch",
            SpanKind::Plan => "plan",
            SpanKind::Execute => "execute",
            SpanKind::Reply => "reply",
            SpanKind::NetWrite => "net_write",
        }
    }
}

/// One phase of one request, as microsecond offsets from submit.
#[derive(Debug, Clone)]
pub struct Span {
    pub kind: SpanKind,
    pub start_us: u64,
    pub end_us: u64,
}

impl Span {
    pub fn dur_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// A sampled request's full timeline.
#[derive(Debug, Clone)]
pub struct Trace {
    pub kernel: String,
    /// shape signature, e.g. `"7x301"` or `"70x50|50x90"`
    pub shapes: String,
    pub batch_size: usize,
    pub coalesced: bool,
    /// `Some(true)` plan-cache hit, `Some(false)` compile, `None` when the
    /// backend has no plan cache (artifact / reference paths)
    pub plan_hit: Option<bool>,
    pub total_us: u64,
    /// client-supplied wire correlation id, echoed in the reply breakdown
    pub trace_id: Option<String>,
    /// tenant identity the request was attributed to
    pub client_id: Option<String>,
    pub spans: Vec<Span>,
}

/// Sampling ring buffer of recent [`Trace`]s.
pub struct TraceRecorder {
    sample: u64,
    counter: AtomicU64,
    capacity: usize,
    ring: Mutex<VecDeque<Trace>>,
}

impl TraceRecorder {
    pub const DEFAULT_CAPACITY: usize = 256;

    /// Keep every `sample`-th request (1 = all), retaining the most recent
    /// `capacity` traces.
    pub fn new(sample: u64, capacity: usize) -> TraceRecorder {
        TraceRecorder {
            sample: sample.max(1),
            counter: AtomicU64::new(0),
            capacity: capacity.max(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// Sampling interval from `NT_TRACE_SAMPLE` (default 1: trace every
    /// request).  Garbage values fail loudly, like the pool knobs.
    pub fn from_env() -> Result<TraceRecorder> {
        let sample = crate::exec::pool::parse_env_usize("NT_TRACE_SAMPLE")?.unwrap_or(1);
        Ok(TraceRecorder::new(sample as u64, TraceRecorder::DEFAULT_CAPACITY))
    }

    /// Decide at submit time whether this request is traced.  One relaxed
    /// atomic increment; every k-th caller (starting with the first) gets
    /// `true`.
    pub fn should_sample(&self) -> bool {
        self.counter.fetch_add(1, Ordering::Relaxed) % self.sample == 0
    }

    /// The configured sampling interval.
    pub fn sample_interval(&self) -> u64 {
        self.sample
    }

    pub fn record(&self, trace: Trace) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(trace);
    }

    /// All retained traces, oldest first.
    pub fn recent(&self) -> Vec<Trace> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// The `n` slowest retained traces, slowest first.
    pub fn slowest(&self, n: usize) -> Vec<Trace> {
        let mut traces = self.recent();
        traces.sort_by(|a, b| b.total_us.cmp(&a.total_us));
        traces.truncate(n);
        traces
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.lock().unwrap().is_empty()
    }
}

/// Render an ASCII waterfall, one block of rows per trace, each span a
/// `#`-bar positioned on a common per-trace time axis.
pub fn render_waterfall(traces: &[Trace]) -> String {
    const WIDTH: usize = 32;
    let mut out = String::new();
    for t in traces {
        let hit = match t.plan_hit {
            Some(true) => "plan=hit",
            Some(false) => "plan=compile",
            None => "plan=-",
        };
        let mut head = format!(
            "{} [{}] total={}us batch={} coalesced={} {}",
            t.kernel, t.shapes, t.total_us, t.batch_size, t.coalesced, hit
        );
        if let Some(c) = &t.client_id {
            head.push_str(&format!(" client={c}"));
        }
        if let Some(id) = &t.trace_id {
            head.push_str(&format!(" trace={id}"));
        }
        head.push('\n');
        out.push_str(&head);
        let total = t.total_us.max(1);
        for span in &t.spans {
            let start_col = (span.start_us as usize * WIDTH / total as usize).min(WIDTH);
            let end_col = (span.end_us as usize * WIDTH / total as usize).clamp(start_col, WIDTH);
            let bar = format!(
                "{}{}",
                " ".repeat(start_col),
                "#".repeat((end_col - start_col).max(1))
            );
            out.push_str(&format!(
                "  {:<8}|{:<w$}| {:>6}us\n",
                span.kind.name(),
                bar,
                span.dur_us(),
                w = WIDTH + 1
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(kernel: &str, total_us: u64) -> Trace {
        Trace {
            kernel: kernel.to_string(),
            shapes: "4x4".to_string(),
            batch_size: 1,
            coalesced: false,
            plan_hit: Some(true),
            total_us,
            trace_id: None,
            client_id: None,
            spans: vec![
                Span { kind: SpanKind::Queued, start_us: 0, end_us: total_us / 2 },
                Span { kind: SpanKind::Execute, start_us: total_us / 2, end_us: total_us },
            ],
        }
    }

    #[test]
    fn sampling_keeps_every_kth_request() {
        let rec = TraceRecorder::new(3, 8);
        let sampled: Vec<bool> = (0..9).map(|_| rec.should_sample()).collect();
        assert_eq!(sampled.iter().filter(|s| **s).count(), 3);
        assert!(sampled[0] && sampled[3] && sampled[6]);
    }

    #[test]
    fn ring_caps_retention_and_slowest_sorts() {
        let rec = TraceRecorder::new(1, 4);
        for i in 0..10u64 {
            rec.record(trace("softmax", i * 100));
        }
        assert_eq!(rec.len(), 4);
        let slow = rec.slowest(2);
        assert_eq!(slow.len(), 2);
        assert_eq!(slow[0].total_us, 900);
        assert_eq!(slow[1].total_us, 800);
    }

    #[test]
    fn waterfall_renders_each_span() {
        let out = render_waterfall(&[trace("mm", 200)]);
        assert!(out.contains("mm [4x4] total=200us"));
        assert!(out.contains("queued"));
        assert!(out.contains("execute"));
        assert!(out.contains('#'));
    }

    #[test]
    fn waterfall_handles_zero_total() {
        let mut t = trace("add", 0);
        t.spans = vec![Span { kind: SpanKind::Reply, start_us: 0, end_us: 0 }];
        let out = render_waterfall(&[t]);
        assert!(out.contains("reply"));
    }
}
