//! Latency SLOs: per-kernel and per-client objectives evaluated over
//! rolling windows against the per-row log2 histograms in
//! [`MetricsRegistry`].
//!
//! An objective is declared in the `NT_SLO` spec string — a
//! semicolon-separated list of `[scope:]pQ<duration` clauses:
//!
//! * `p99<2ms` — every request, any kernel, any client;
//! * `mm:p99<5ms` — scoped to one kernel;
//! * `client=acme:p95<10ms` — scoped to one tenant.
//!
//! Durations take `us`, `ms` or `s` units.  A malformed spec is a clean
//! startup error (`CoordinatorConfig::validate`), matching every other
//! `NT_*` knob.
//!
//! Evaluation is windowed and cheap: [`SloEngine::maybe_evaluate`] runs
//! on the submit path but no more than once per window (`try_lock` + an
//! elapsed check — between windows it is a single mutex probe).  Each
//! window the engine diffs the current filtered histograms against the
//! previous boundary, estimates the fraction of completions at or above
//! the threshold (log-linear interpolation inside the boundary bucket),
//! and derives the **error-budget burn rate**: the observed violation
//! fraction over the allowed fraction `1 - q`.  A burn rate above 1.0
//! marks the objective *burning*, which admission reads through
//! [`SloEngine::burning_objective`] to shed earlier (the coordinator
//! halves its effective watermark).  An idle window (zero completions)
//! keeps the previous verdict — no traffic is no evidence of recovery.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::registry::MetricsRegistry;

/// One parsed `NT_SLO` clause.
#[derive(Debug, Clone, PartialEq)]
pub struct SloObjective {
    /// kernel filter (`None` = every kernel)
    pub kernel: Option<String>,
    /// client filter (`None` = every client, attributed or not)
    pub client: Option<String>,
    /// quantile in (0, 1), e.g. `0.99` for `p99`
    pub quantile: f64,
    /// latency threshold in microseconds
    pub threshold_us: u64,
    /// the original clause text, the stable `objective` label
    pub spec: String,
}

/// One objective's verdict for the most recent evaluated window.
#[derive(Debug, Clone)]
pub struct SloStatus {
    /// the clause text, e.g. `"mm:p99<5ms"`
    pub objective: String,
    pub quantile: f64,
    pub threshold_us: u64,
    /// completions observed in the window
    pub window_total: u64,
    /// estimated completions at or above the threshold in the window
    pub window_violations: u64,
    /// violation fraction / allowed fraction (`1 - q`); > 1.0 = burning
    pub burn_rate: f64,
    pub burning: bool,
}

/// Parse a full `NT_SLO` spec string into its objectives.
pub fn parse_slo_spec(spec: &str) -> Result<Vec<SloObjective>> {
    let mut objectives = Vec::new();
    for clause in spec.split(';') {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        objectives.push(parse_objective(clause)?);
    }
    if objectives.is_empty() {
        bail!("SLO spec {spec:?} contains no objectives");
    }
    Ok(objectives)
}

fn parse_objective(text: &str) -> Result<SloObjective> {
    let (scope, body) = match text.split_once(':') {
        Some((scope, body)) => (Some(scope.trim()), body.trim()),
        None => (None, text.trim()),
    };
    let (kernel, client) = match scope {
        None => (None, None),
        Some(scope) => match scope.strip_prefix("client=") {
            Some("") => bail!("SLO objective {text:?}: empty client name"),
            Some(client) => (None, Some(client.to_string())),
            None if scope.is_empty() => bail!("SLO objective {text:?}: empty kernel scope"),
            None => (Some(scope.to_string()), None),
        },
    };
    let body = body.strip_prefix('p').ok_or_else(|| {
        anyhow!("SLO objective {text:?}: expected pQ<duration (e.g. p99<2ms)")
    })?;
    let (q_text, dur_text) = body.split_once('<').ok_or_else(|| {
        anyhow!("SLO objective {text:?}: expected pQ<duration (e.g. p99<2ms)")
    })?;
    let q: f64 = q_text
        .trim()
        .parse()
        .map_err(|_| anyhow!("SLO objective {text:?}: bad quantile {q_text:?}"))?;
    if !(q > 0.0 && q < 100.0) {
        bail!("SLO objective {text:?}: quantile must be in (0, 100)");
    }
    let threshold_us =
        parse_duration_us(dur_text.trim()).with_context(|| format!("SLO objective {text:?}"))?;
    Ok(SloObjective {
        kernel,
        client,
        quantile: q / 100.0,
        threshold_us,
        spec: text.to_string(),
    })
}

fn parse_duration_us(text: &str) -> Result<u64> {
    let (value, scale) = if let Some(v) = text.strip_suffix("us") {
        (v, 1.0)
    } else if let Some(v) = text.strip_suffix("ms") {
        (v, 1_000.0)
    } else if let Some(v) = text.strip_suffix('s') {
        (v, 1_000_000.0)
    } else {
        bail!("duration {text:?} needs a unit (us, ms or s)");
    };
    let value: f64 = value
        .trim()
        .parse()
        .map_err(|_| anyhow!("bad duration value {text:?}"))?;
    let us = (value * scale).round();
    if !(1.0..9e15).contains(&us) {
        bail!("duration {text:?} must be at least 1us");
    }
    Ok(us as u64)
}

struct SloState {
    last_eval: Option<Instant>,
    /// cumulative per-objective filtered histograms at the last window
    /// boundary — the subtrahend that makes the window rolling
    baselines: Vec<Vec<u64>>,
    statuses: Vec<SloStatus>,
}

/// The windowed evaluator.  One per coordinator, shared by every
/// submitter; disabled (no objectives) it is a single branch.
pub struct SloEngine {
    objectives: Vec<SloObjective>,
    window: Duration,
    /// mirror of "any status is burning", readable without the lock on
    /// the admission fast path
    any_burning: AtomicBool,
    state: Mutex<SloState>,
}

impl SloEngine {
    pub fn new(objectives: Vec<SloObjective>, window: Duration) -> SloEngine {
        let statuses = objectives
            .iter()
            .map(|o| SloStatus {
                objective: o.spec.clone(),
                quantile: o.quantile,
                threshold_us: o.threshold_us,
                window_total: 0,
                window_violations: 0,
                burn_rate: 0.0,
                burning: false,
            })
            .collect();
        let baselines = objectives.iter().map(|_| Vec::new()).collect();
        SloEngine {
            objectives,
            window: window.max(Duration::from_millis(1)),
            any_burning: AtomicBool::new(false),
            state: Mutex::new(SloState { last_eval: None, baselines, statuses }),
        }
    }

    /// No objectives: every entry point is a cheap no-op.
    pub fn disabled() -> SloEngine {
        SloEngine::new(Vec::new(), Duration::from_secs(1))
    }

    pub fn is_enabled(&self) -> bool {
        !self.objectives.is_empty()
    }

    pub fn objectives(&self) -> &[SloObjective] {
        &self.objectives
    }

    pub fn window(&self) -> Duration {
        self.window
    }

    /// Evaluate the window if one has elapsed; returns the objectives
    /// that *transitioned into* burning (for the flight recorder).
    /// Contention-free: a submitter that loses the `try_lock` race just
    /// skips — someone else is evaluating.
    pub fn maybe_evaluate(&self, registry: &MetricsRegistry) -> Vec<SloStatus> {
        if self.objectives.is_empty() {
            return Vec::new();
        }
        let Ok(mut state) = self.state.try_lock() else {
            return Vec::new();
        };
        if let Some(last) = state.last_eval {
            if last.elapsed() < self.window {
                return Vec::new();
            }
        }
        self.evaluate_locked(&mut state, registry)
    }

    /// Force a window evaluation now — tests and diagnostics;
    /// [`SloEngine::maybe_evaluate`] is the rate-limited serving entry.
    pub fn evaluate_now(&self, registry: &MetricsRegistry) -> Vec<SloStatus> {
        if self.objectives.is_empty() {
            return Vec::new();
        }
        let mut state = self.state.lock().unwrap();
        self.evaluate_locked(&mut state, registry)
    }

    fn evaluate_locked(
        &self,
        state: &mut SloState,
        registry: &MetricsRegistry,
    ) -> Vec<SloStatus> {
        state.last_eval = Some(Instant::now());
        let rows = registry.snapshot();
        let mut newly_burning = Vec::new();
        for (i, obj) in self.objectives.iter().enumerate() {
            let mut cur: Vec<u64> = Vec::new();
            for row in &rows {
                if obj.kernel.as_deref().is_some_and(|k| k != row.kernel) {
                    continue;
                }
                if obj.client.as_deref().is_some_and(|c| c != row.client) {
                    continue;
                }
                if cur.len() < row.metrics.latency_hist.len() {
                    cur.resize(row.metrics.latency_hist.len(), 0);
                }
                for (acc, v) in cur.iter_mut().zip(&row.metrics.latency_hist) {
                    *acc += v;
                }
            }
            let baseline = &mut state.baselines[i];
            if baseline.len() < cur.len() {
                baseline.resize(cur.len(), 0);
            }
            let delta: Vec<u64> = cur
                .iter()
                .zip(baseline.iter())
                .map(|(c, b)| c.saturating_sub(*b))
                .collect();
            baseline.clone_from(&cur);
            let total: u64 = delta.iter().sum();
            if total == 0 {
                // an idle window is no evidence either way: keep the
                // previous verdict until traffic returns
                continue;
            }
            let violations = violations_at_or_above(&delta, obj.threshold_us);
            let burn = (violations / total as f64) / (1.0 - obj.quantile);
            let status = &mut state.statuses[i];
            let was_burning = status.burning;
            status.window_total = total;
            status.window_violations = violations.round() as u64;
            status.burn_rate = burn;
            status.burning = burn > 1.0;
            if status.burning && !was_burning {
                newly_burning.push(status.clone());
            }
        }
        self.any_burning
            .store(state.statuses.iter().any(|s| s.burning), Ordering::Relaxed);
        newly_burning
    }

    /// Whether any objective's error budget is burning right now — one
    /// relaxed load, the admission fast path.
    pub fn is_burning(&self) -> bool {
        self.any_burning.load(Ordering::Relaxed)
    }

    /// The first burning objective's spec, for the structured shed
    /// reason.  Takes the state lock only while actually burning.
    pub fn burning_objective(&self) -> Option<String> {
        if !self.is_burning() {
            return None;
        }
        self.state
            .lock()
            .unwrap()
            .statuses
            .iter()
            .find(|s| s.burning)
            .map(|s| s.objective.clone())
    }

    /// Every objective's latest verdict (initialized at construction, so
    /// the `nt_slo_*` series exist before the first window completes).
    pub fn statuses(&self) -> Vec<SloStatus> {
        self.state.lock().unwrap().statuses.clone()
    }
}

/// Estimated completions at or above `threshold_us` in a log2 histogram
/// delta (bucket `i` spans `[2^i, 2^(i+1))` µs): whole buckets above the
/// threshold count fully, the boundary bucket contributes its
/// interpolated fraction.
fn violations_at_or_above(hist: &[u64], threshold_us: u64) -> f64 {
    let mut violations = 0.0;
    for (i, &count) in hist.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let lo = 1u64 << i;
        let hi = 1u64 << (i + 1);
        if threshold_us <= lo {
            violations += count as f64;
        } else if threshold_us < hi {
            let frac = (hi - threshold_us) as f64 / (hi - lo) as f64;
            violations += count as f64 * frac;
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_scopes_and_units() {
        let objs = parse_slo_spec("p99<2ms; mm:p99<5ms; client=acme:p95<10ms").unwrap();
        assert_eq!(objs.len(), 3);
        assert_eq!(
            (objs[0].kernel.as_deref(), objs[0].client.as_deref()),
            (None, None)
        );
        assert!((objs[0].quantile - 0.99).abs() < 1e-12);
        assert_eq!(objs[0].threshold_us, 2_000);
        assert_eq!(objs[1].kernel.as_deref(), Some("mm"));
        assert_eq!(objs[1].threshold_us, 5_000);
        assert_eq!(objs[2].client.as_deref(), Some("acme"));
        assert!((objs[2].quantile - 0.95).abs() < 1e-12);
        assert_eq!(objs[2].threshold_us, 10_000);
        assert_eq!(parse_slo_spec("p50<500us").unwrap()[0].threshold_us, 500);
        assert_eq!(parse_slo_spec("p50<1s").unwrap()[0].threshold_us, 1_000_000);
        assert_eq!(parse_slo_spec("p99.9<1ms").unwrap()[0].quantile, 0.999);
    }

    #[test]
    fn spec_rejects_garbage() {
        for bad in [
            "",
            "p99",
            "p99<",
            "p99<2",       // no unit
            "p99<0us",     // sub-1us threshold
            "p0<2ms",      // quantile 0
            "p100<2ms",    // quantile 100
            "q99<2ms",     // no leading p
            "client=:p99<2ms",
            ":p99<2ms",
            "mm:client=acme:p99<2ms",
        ] {
            assert!(parse_slo_spec(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn violation_interpolation() {
        // 10 samples in bucket 6 ([64, 128) µs)
        let mut hist = vec![0u64; 28];
        hist[6] = 10;
        assert_eq!(violations_at_or_above(&hist, 64) as u64, 10); // all
        assert_eq!(violations_at_or_above(&hist, 128) as u64, 0); // none
        let half = violations_at_or_above(&hist, 96); // midpoint
        assert!((half - 5.0).abs() < 1e-9, "{half}");
    }

    #[test]
    fn burn_trips_recovers_and_holds_through_idle_windows() {
        let reg = MetricsRegistry::new();
        let eng = SloEngine::new(
            parse_slo_spec("p50<100us").unwrap(),
            Duration::from_millis(1),
        );
        assert!(!eng.is_burning());
        assert_eq!(eng.statuses().len(), 1, "statuses exist before any window");

        let m = reg.handle("mm", "8x8|8x8");
        for _ in 0..10 {
            m.observe_latency_us(1000); // all violate 100us
        }
        let newly = eng.evaluate_now(&reg);
        assert_eq!(newly.len(), 1);
        assert!(eng.is_burning());
        assert_eq!(eng.burning_objective().as_deref(), Some("p50<100us"));
        let s = &eng.statuses()[0];
        assert_eq!((s.window_total, s.window_violations), (10, 10));
        assert!(s.burn_rate > 1.0, "burn={}", s.burn_rate);

        // an idle window keeps the verdict: no traffic, still burning
        assert!(eng.evaluate_now(&reg).is_empty());
        assert!(eng.is_burning());

        // a healthy window recovers (and is not a "newly burning" event)
        for _ in 0..10 {
            m.observe_latency_us(10);
        }
        assert!(eng.evaluate_now(&reg).is_empty());
        assert!(!eng.is_burning());
        assert!(eng.burning_objective().is_none());
    }

    #[test]
    fn scoped_objectives_filter_rows() {
        let reg = MetricsRegistry::new();
        // mm is slow, softmax is fast; acme's requests are slow
        for _ in 0..10 {
            reg.handle("mm", "8x8|8x8").observe_latency_us(5000);
            reg.handle("softmax", "4x16").observe_latency_us(10);
            reg.handle_for("softmax", "4x16", Some("acme")).observe_latency_us(5000);
        }
        let eng = SloEngine::new(
            parse_slo_spec("softmax:p50<100us;client=acme:p50<100us").unwrap(),
            Duration::from_millis(1),
        );
        eng.evaluate_now(&reg);
        let statuses = eng.statuses();
        // the softmax objective sees both the fast anonymous rows and
        // acme's slow ones: 10 of 20 violate = exactly the p50 budget
        assert_eq!(statuses[0].window_total, 20);
        assert_eq!(statuses[0].window_violations, 10);
        // the client objective sees only acme's slow rows and burns
        assert_eq!(statuses[1].window_total, 10);
        assert!(statuses[1].burning);
        assert_eq!(eng.burning_objective().as_deref(), Some("client=acme:p50<100us"));
    }
}
