//! The execution profiler: opt-in wall-time attribution per instruction
//! kind and per grid cell, plus the worker-pool gauges.
//!
//! A [`ProfileReport`] is attached to every compiled plan
//! ([`crate::exec::CompiledProgram`]); when profiling is enabled
//! (`NT_PROFILE=1` at compile time of the plan, or an explicitly
//! [`ProfileReport::enabled`] report passed to
//! `CompiledProgram::execute_profiled`), the IR interpreter and the grid
//! scheduler record into it on every launch.  Disabled reports cost one
//! branch per instruction — the hot path stays untimed.
//!
//! All counters are relaxed atomics, so many grid workers record into one
//! report concurrently without locks.

use std::sync::atomic::{AtomicU64, Ordering};

/// Display names for [`crate::exec::Instr`] kinds, indexed by
/// `Instr::kind_index`.
pub const INSTR_KINDS: &[&str] = &[
    "load",
    "zeros",
    "const",
    "unary",
    "binary",
    "reduce",
    "dot",
    "dot_acc",
    "broadcast",
    "transpose",
    "pad_mask",
    "block_dim",
    "split_half",
    "concat",
    "assign",
    "loop",
    "store",
];

/// Accumulated execution profile for one compiled plan: wall time and
/// execution count per instruction kind, plus per-grid-cell timing.
pub struct ProfileReport {
    enabled: bool,
    instr_ns: Vec<AtomicU64>,
    instr_count: Vec<AtomicU64>,
    cells: AtomicU64,
    cell_ns_total: AtomicU64,
    cell_ns_max: AtomicU64,
}

impl ProfileReport {
    fn with_enabled(enabled: bool) -> ProfileReport {
        ProfileReport {
            enabled,
            instr_ns: (0..INSTR_KINDS.len()).map(|_| AtomicU64::new(0)).collect(),
            instr_count: (0..INSTR_KINDS.len()).map(|_| AtomicU64::new(0)).collect(),
            cells: AtomicU64::new(0),
            cell_ns_total: AtomicU64::new(0),
            cell_ns_max: AtomicU64::new(0),
        }
    }

    /// Enabled iff `NT_PROFILE=1` — the report every compiled plan carries.
    pub fn from_env() -> ProfileReport {
        ProfileReport::with_enabled(std::env::var("NT_PROFILE").is_ok_and(|v| v == "1"))
    }

    /// A report that records nothing (one branch per instruction).
    pub fn disabled() -> ProfileReport {
        ProfileReport::with_enabled(false)
    }

    /// An always-recording report, independent of `NT_PROFILE` (tests,
    /// benches, explicit `execute_profiled` callers).
    pub fn enabled() -> ProfileReport {
        ProfileReport::with_enabled(true)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Record one executed instruction of `kind` (an
    /// `Instr::kind_index`) taking `ns` wall nanoseconds.
    pub fn record_instr(&self, kind: usize, ns: u64) {
        if let (Some(t), Some(c)) = (self.instr_ns.get(kind), self.instr_count.get(kind)) {
            t.fetch_add(ns, Ordering::Relaxed);
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one executed grid cell taking `ns` wall nanoseconds.
    pub fn record_cell(&self, ns: u64) {
        self.cells.fetch_add(1, Ordering::Relaxed);
        self.cell_ns_total.fetch_add(ns, Ordering::Relaxed);
        self.cell_ns_max.fetch_max(ns, Ordering::Relaxed);
    }

    /// Copy the counters out (instruction kinds that never executed are
    /// omitted).
    pub fn snapshot(&self, label: &str) -> ProfileSnapshot {
        let instrs = INSTR_KINDS
            .iter()
            .enumerate()
            .filter_map(|(i, kind)| {
                let count = self.instr_count[i].load(Ordering::Relaxed);
                (count > 0).then(|| InstrStat {
                    kind,
                    count,
                    total_ns: self.instr_ns[i].load(Ordering::Relaxed),
                })
            })
            .collect();
        ProfileSnapshot {
            label: label.to_string(),
            instrs,
            cells: self.cells.load(Ordering::Relaxed),
            cell_ns_total: self.cell_ns_total.load(Ordering::Relaxed),
            cell_ns_max: self.cell_ns_max.load(Ordering::Relaxed),
        }
    }
}

/// One instruction kind's accumulated profile.
#[derive(Debug, Clone)]
pub struct InstrStat {
    pub kind: &'static str,
    pub count: u64,
    pub total_ns: u64,
}

/// A point-in-time copy of a [`ProfileReport`], labeled with the plan it
/// came from (kernel + shape signature).
#[derive(Debug, Clone)]
pub struct ProfileSnapshot {
    pub label: String,
    /// per-instruction-kind stats, in `INSTR_KINDS` order, zeros omitted
    pub instrs: Vec<InstrStat>,
    pub cells: u64,
    pub cell_ns_total: u64,
    pub cell_ns_max: u64,
}

impl ProfileSnapshot {
    /// Human table: instruction kinds sorted by total time, then the
    /// per-cell summary line.
    pub fn render(&self) -> String {
        let mut rows = self.instrs.clone();
        rows.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
        let mut out = format!("profile {}:\n", self.label);
        for r in &rows {
            let mean_ns = if r.count == 0 { 0 } else { r.total_ns / r.count };
            out.push_str(&format!(
                "  {:<11} count={:<8} total={:>9.3}ms mean={:>7}ns\n",
                r.kind,
                r.count,
                r.total_ns as f64 / 1e6,
                mean_ns,
            ));
        }
        let mean_cell = if self.cells == 0 { 0 } else { self.cell_ns_total / self.cells };
        out.push_str(&format!(
            "  cells={} mean={}ns max={}ns",
            self.cells, mean_cell, self.cell_ns_max
        ));
        out
    }
}

/// Point-in-time gauges of the shared worker pool
/// (`crate::exec::pool`): how wide it is, how deep its injector queue
/// currently is, how many workers are executing a job right now, and how
/// many queued jobs it has executed since start.
#[derive(Debug, Clone, Default)]
pub struct PoolGauges {
    pub workers: usize,
    pub queue_depth: usize,
    pub busy_workers: usize,
    pub jobs_executed: u64,
}

impl PoolGauges {
    pub fn render(&self) -> String {
        format!(
            "pool: workers={} queue_depth={} busy={} jobs_executed={}",
            self.workers, self.queue_depth, self.busy_workers, self.jobs_executed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_skips_untouched_kinds_and_tracks_cells() {
        let p = ProfileReport::enabled();
        assert!(p.is_enabled());
        p.record_instr(0, 100);
        p.record_instr(0, 50);
        p.record_instr(16, 25);
        p.record_cell(10);
        p.record_cell(30);
        let s = p.snapshot("test");
        assert_eq!(s.instrs.len(), 2);
        assert_eq!(s.instrs[0].kind, "load");
        assert_eq!((s.instrs[0].count, s.instrs[0].total_ns), (2, 150));
        assert_eq!(s.instrs[1].kind, "store");
        assert_eq!((s.cells, s.cell_ns_total, s.cell_ns_max), (2, 40, 30));
        assert!(s.render().contains("store"));
    }

    #[test]
    fn disabled_report_still_accepts_records() {
        // recording is gated by the *caller* checking is_enabled; the
        // report itself never panics either way
        let p = ProfileReport::disabled();
        assert!(!p.is_enabled());
        p.record_instr(999, 1); // out of range: ignored
        assert!(p.snapshot("x").instrs.is_empty());
    }
}
