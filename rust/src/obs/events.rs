//! The flight recorder: a bounded, low-overhead NDJSON event log for
//! the serving path.
//!
//! Every line is one self-contained JSON object with at least `"event"`
//! (the kind) and `"ts_ms"` (wall clock, Unix millis).  The recorder
//! captures the decisions that matter when debugging an incident after
//! the fact: admissions, sheds (with the effective watermark and the
//! structured reason), plan compiles, autotune decisions, SLO breach
//! transitions, and — when `NT_SLOW_US` is set — the full span trace of
//! any request at least that slow.
//!
//! Durability discipline:
//!
//! * **one `write_all` per line** — a line is never split across
//!   syscalls, so concurrent emitters cannot tear each other's records
//!   (the line is formatted outside the sink lock, written under it);
//! * **size-bounded rotation** — when appending a line would push the
//!   file past the cap, the current file is atomically renamed to
//!   `<path>.1` (replacing any previous rotation) and a fresh file is
//!   started, all under the sink lock: at most two files ever exist and
//!   every line lands whole in exactly one of them;
//! * **fail-open** — an I/O error disables the sink with one warning to
//!   stderr; the serving path never blocks or errors on the recorder.
//!
//! Disabled (the default — no `NT_EVENT_LOG`), every emitter returns
//! after one branch.  `repro events` tails and filters the log.

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use super::slo::SloStatus;
use super::trace::{Span, Trace};
use crate::json::Json;

/// Default rotation cap (`NT_EVENT_LOG_MAX_KB`), in KiB.
pub const DEFAULT_MAX_KB: usize = 4096;

/// The event log handle; cheap to probe when disabled.
pub struct EventLog {
    sink: Option<Sink>,
    slow_us: Option<u64>,
}

struct Sink {
    path: PathBuf,
    max_bytes: u64,
    /// set on the first I/O error; further writes are skipped
    failed: AtomicBool,
    state: Mutex<SinkState>,
}

struct SinkState {
    file: File,
    written: u64,
}

impl EventLog {
    /// No sink: every emitter is a no-op after one branch.
    pub fn disabled() -> EventLog {
        EventLog { sink: None, slow_us: None }
    }

    /// Open (append) an NDJSON sink rotating at `max_bytes` (clamped to
    /// ≥ 1 KiB).  `slow_us` arms slow-request trace capture.
    pub fn to_file(path: PathBuf, max_bytes: u64, slow_us: Option<u64>) -> Result<EventLog> {
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening event log {}", path.display()))?;
        let written = file.metadata().map(|m| m.len()).unwrap_or(0);
        Ok(EventLog {
            sink: Some(Sink {
                path,
                max_bytes: max_bytes.max(1024),
                failed: AtomicBool::new(false),
                state: Mutex::new(SinkState { file, written }),
            }),
            slow_us,
        })
    }

    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    pub fn path(&self) -> Option<&Path> {
        self.sink.as_ref().map(|s| s.path.as_path())
    }

    pub fn slow_us(&self) -> Option<u64> {
        self.slow_us
    }

    /// Whether completed-request traces should be offered to
    /// [`EventLog::maybe_slow_request`] — i.e. whether building a trace
    /// purely for slow-capture is worth it.
    pub fn wants_slow(&self) -> bool {
        self.sink.is_some() && self.slow_us.is_some()
    }

    /// Emit one event line: `{"event": kind, "ts_ms": now, ...fields}`.
    pub fn emit(&self, kind: &str, fields: Vec<(&str, Json)>) {
        let Some(sink) = &self.sink else { return };
        let mut o = BTreeMap::new();
        o.insert("event".to_string(), Json::Str(kind.to_string()));
        o.insert("ts_ms".to_string(), Json::Num(now_ms() as f64));
        for (k, v) in fields {
            o.insert(k.to_string(), v);
        }
        let mut line = Json::Obj(o).to_string();
        line.push('\n');
        sink.write_line(&line);
    }

    pub fn admit(&self, kernel: &str, shapes: &str, client: Option<&str>) {
        if !self.enabled() {
            return;
        }
        let mut fields = vec![
            ("kernel", Json::Str(kernel.to_string())),
            ("shapes", Json::Str(shapes.to_string())),
        ];
        push_client(&mut fields, client);
        self.emit("admit", fields);
    }

    /// `objective` is the burning SLO clause when the shed happened at a
    /// lowered watermark (`reason: "slo_burn"` vs `"queue_full"`).
    pub fn shed(
        &self,
        kernel: &str,
        shapes: &str,
        client: Option<&str>,
        depth: usize,
        watermark: usize,
        objective: Option<&str>,
    ) {
        if !self.enabled() {
            return;
        }
        let reason = if objective.is_some() { "slo_burn" } else { "queue_full" };
        let mut fields = vec![
            ("kernel", Json::Str(kernel.to_string())),
            ("shapes", Json::Str(shapes.to_string())),
            ("depth", Json::Num(depth as f64)),
            ("watermark", Json::Num(watermark as f64)),
            ("reason", Json::Str(reason.to_string())),
        ];
        if let Some(obj) = objective {
            fields.push(("objective", Json::Str(obj.to_string())));
        }
        push_client(&mut fields, client);
        self.emit("shed", fields);
    }

    pub fn plan_compile(&self, kernel: &str, shapes: &str) {
        if !self.enabled() {
            return;
        }
        self.emit(
            "plan_compile",
            vec![
                ("kernel", Json::Str(kernel.to_string())),
                ("shapes", Json::Str(shapes.to_string())),
            ],
        );
    }

    pub fn tune(&self, kernel: &str, shapes: &str, tune_us: u64, measurements: u64) {
        if !self.enabled() {
            return;
        }
        self.emit(
            "tune",
            vec![
                ("kernel", Json::Str(kernel.to_string())),
                ("shapes", Json::Str(shapes.to_string())),
                ("tune_us", Json::Num(tune_us as f64)),
                ("measurements", Json::Num(measurements as f64)),
            ],
        );
    }

    pub fn slo_breach(&self, status: &SloStatus) {
        if !self.enabled() {
            return;
        }
        self.emit(
            "slo_breach",
            vec![
                ("objective", Json::Str(status.objective.clone())),
                ("burn_rate", Json::Num(status.burn_rate)),
                ("window_total", Json::Num(status.window_total as f64)),
                ("window_violations", Json::Num(status.window_violations as f64)),
            ],
        );
    }

    /// Record the full span trace of a completed request if it is at
    /// least `NT_SLOW_US` µs end to end.
    pub fn maybe_slow_request(&self, trace: &Trace) {
        let Some(limit) = self.slow_us else { return };
        if self.sink.is_none() || trace.total_us < limit {
            return;
        }
        let mut fields = vec![
            ("kernel", Json::Str(trace.kernel.clone())),
            ("shapes", Json::Str(trace.shapes.clone())),
            ("batch_size", Json::Num(trace.batch_size as f64)),
            ("coalesced", Json::Bool(trace.coalesced)),
            ("total_us", Json::Num(trace.total_us as f64)),
            ("spans", Json::Arr(trace.spans.iter().map(span_json).collect())),
        ];
        if let Some(c) = &trace.client_id {
            fields.push(("client_id", Json::Str(c.clone())));
        }
        if let Some(t) = &trace.trace_id {
            fields.push(("trace_id", Json::Str(t.clone())));
        }
        self.emit("slow_request", fields);
    }
}

fn push_client(fields: &mut Vec<(&str, Json)>, client: Option<&str>) {
    if let Some(c) = client {
        fields.push(("client_id", Json::Str(c.to_string())));
    }
}

fn span_json(s: &Span) -> Json {
    let mut o = BTreeMap::new();
    o.insert("kind".to_string(), Json::Str(s.kind.name().to_string()));
    o.insert("start_us".to_string(), Json::Num(s.start_us as f64));
    o.insert("end_us".to_string(), Json::Num(s.end_us as f64));
    Json::Obj(o)
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// `<path>.1`, the single rotation slot.
pub fn rotated_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".1");
    PathBuf::from(os)
}

impl Sink {
    fn write_line(&self, line: &str) {
        if self.failed.load(Ordering::Relaxed) {
            return;
        }
        let mut state = self.state.lock().unwrap();
        if state.written > 0 && state.written + line.len() as u64 > self.max_bytes {
            if let Err(e) = self.rotate(&mut state) {
                self.fail(&format!("rotate: {e}"));
                return;
            }
        }
        if let Err(e) = state.file.write_all(line.as_bytes()) {
            self.fail(&format!("write: {e}"));
            return;
        }
        state.written += line.len() as u64;
    }

    fn rotate(&self, state: &mut SinkState) -> std::io::Result<()> {
        state.file.flush()?;
        std::fs::rename(&self.path, rotated_path(&self.path))?;
        state.file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        state.written = 0;
        Ok(())
    }

    fn fail(&self, why: &str) {
        if !self.failed.swap(true, Ordering::Relaxed) {
            eprintln!(
                "nt-events: disabling event log {}: {why}",
                self.path.display()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("nt_events_{}_{name}.ndjson", std::process::id()))
    }

    fn cleanup(path: &Path) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(rotated_path(path));
    }

    fn lines(path: &Path) -> Vec<String> {
        match std::fs::read_to_string(path) {
            Ok(s) => s.lines().map(str::to_string).collect(),
            Err(_) => Vec::new(),
        }
    }

    #[test]
    fn disabled_log_is_inert() {
        let log = EventLog::disabled();
        assert!(!log.enabled());
        assert!(!log.wants_slow());
        log.emit("admit", vec![("kernel", Json::Str("mm".into()))]);
        log.admit("mm", "8x8", None);
    }

    #[test]
    fn events_land_as_parseable_ndjson() {
        let path = temp("basic");
        cleanup(&path);
        let log = EventLog::to_file(path.clone(), 1 << 20, None).unwrap();
        log.admit("mm", "8x8|8x8", Some("acme"));
        log.shed("mm", "8x8|8x8", None, 9, 4, Some("p99<1ms"));
        log.plan_compile("softmax", "4x16");
        log.tune("mm", "8x8|8x8", 1234, 21);
        let all = lines(&path);
        assert_eq!(all.len(), 4);
        for line in &all {
            let v = crate::json::parse(line).expect("line parses");
            assert!(v.get("event").is_some() && v.get("ts_ms").is_some(), "{line}");
        }
        let shed = crate::json::parse(&all[1]).unwrap();
        assert_eq!(shed.str("reason").unwrap(), "slo_burn");
        assert_eq!(shed.str("objective").unwrap(), "p99<1ms");
        assert_eq!(shed.usize("watermark").unwrap(), 4);
        cleanup(&path);
    }

    #[test]
    fn rotation_keeps_whole_lines_in_two_files() {
        let path = temp("rotate");
        cleanup(&path);
        // cap clamps to 1024 bytes; ~100-byte lines force several rotations
        let log = EventLog::to_file(path.clone(), 1, None).unwrap();
        for i in 0..64 {
            log.admit("softmax", &format!("row_{i:04}_padpadpadpadpadpadpadpad"), Some("hammer"));
        }
        let rotated = rotated_path(&path);
        assert!(rotated.exists(), "rotation happened");
        for file in [&rotated, &path] {
            let all = lines(file);
            assert!(!all.is_empty());
            assert!(std::fs::metadata(file).unwrap().len() <= 2048);
            for line in &all {
                crate::json::parse(line).expect("rotated line parses");
            }
        }
        cleanup(&path);
    }

    #[test]
    fn slow_capture_respects_threshold() {
        use crate::obs::{SpanKind, Trace};
        let path = temp("slow");
        cleanup(&path);
        let log = EventLog::to_file(path.clone(), 1 << 20, Some(100)).unwrap();
        assert!(log.wants_slow());
        let mut t = Trace {
            kernel: "mm".into(),
            shapes: "8x8|8x8".into(),
            batch_size: 1,
            coalesced: false,
            plan_hit: Some(true),
            total_us: 99,
            trace_id: Some("req-1".into()),
            client_id: Some("acme".into()),
            spans: vec![Span { kind: SpanKind::Execute, start_us: 0, end_us: 99 }],
        };
        log.maybe_slow_request(&t); // under threshold: dropped
        t.total_us = 100;
        log.maybe_slow_request(&t); // at threshold: recorded
        let all = lines(&path);
        assert_eq!(all.len(), 1);
        let v = crate::json::parse(&all[0]).unwrap();
        assert_eq!(v.str("event").unwrap(), "slow_request");
        assert_eq!(v.str("trace_id").unwrap(), "req-1");
        assert_eq!(v.str("client_id").unwrap(), "acme");
        assert_eq!(v.arr("spans").unwrap().len(), 1);
        cleanup(&path);
    }
}
