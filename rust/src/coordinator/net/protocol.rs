//! The wire protocol: JSON payloads carried inside length-prefixed
//! frames (see [`super::frame`]).
//!
//! Every request is a JSON object with an `"op"` field and an optional
//! numeric `"id"` the server echoes back; every reply carries `"ok"`
//! (boolean) plus either the op's result fields or an `"error"` object
//! with a stable machine-readable `code`.  The full grammar — every
//! endpoint, every error code, worked examples the protocol tests replay
//! verbatim — is documented in `docs/wire-protocol.md`.
//!
//! Tensors travel as `{"shape": [...], "data": [...], "dtype": "f32"}`.
//! f32 values are serialized through f64 shortest-roundtrip formatting,
//! which is exact in both directions — results received over the wire
//! are **bit-identical** to in-process execution (a test pins this).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::json::Json;
use crate::runtime::{HostData, HostTensor};

/// Protocol version, reported by the `health` endpoint.
pub const PROTOCOL_VERSION: u64 = 1;

/// Stable machine-readable error codes (`error.code` in error replies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// the framing itself was violated (oversized declared length,
    /// truncated frame, non-UTF-8 payload); the reply is best-effort and
    /// the connection closes, since the stream cannot be resynchronized
    BadFrame,
    /// the frame's payload was not parseable JSON, or not a JSON object
    BadRequest,
    /// the `"op"` field is missing or names no endpoint
    UnknownOp,
    /// a well-formed request the router refused (unknown kernel, bad
    /// arity, bad shapes, malformed tensor encoding)
    InvalidArgument,
    /// admission control shed the request; retry after `retry_after_ms`
    Overloaded,
    /// the server is draining; no new submits are accepted
    ShuttingDown,
    /// the request was admitted but execution failed
    Internal,
}

impl ErrorCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownOp => "unknown_op",
            ErrorCode::InvalidArgument => "invalid_argument",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::Internal => "internal",
        }
    }
}

/// Serialize a tensor for the wire.
pub fn tensor_to_json(t: &HostTensor) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "shape".to_string(),
        Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
    );
    match &t.data {
        HostData::F32(v) => {
            o.insert("dtype".to_string(), Json::Str("f32".to_string()));
            o.insert(
                "data".to_string(),
                Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
            );
        }
        HostData::I32(v) => {
            o.insert("dtype".to_string(), Json::Str("i32".to_string()));
            o.insert(
                "data".to_string(),
                Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect()),
            );
        }
    }
    Json::Obj(o)
}

/// Decode a wire tensor; rejects shape/data disagreements cleanly.
pub fn tensor_from_json(v: &Json) -> Result<HostTensor> {
    let shape = v.usize_vec("shape")?;
    let data = v.arr("data")?;
    let dtype = match v.get("dtype") {
        None => "f32",
        Some(d) => d.as_str().ok_or_else(|| anyhow!("tensor dtype must be a string"))?,
    };
    match dtype {
        "f32" => {
            let values: Vec<f32> = data
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(|f| f as f32)
                        .ok_or_else(|| anyhow!("non-numeric value in tensor data"))
                })
                .collect::<Result<_>>()?;
            HostTensor::f32(shape, values)
        }
        "i32" => {
            let values: Vec<i32> = data
                .iter()
                .map(|x| {
                    x.as_i64()
                        .and_then(|i| i32::try_from(i).ok())
                        .ok_or_else(|| anyhow!("non-i32 value in tensor data"))
                })
                .collect::<Result<_>>()?;
            HostTensor::i32(shape, values)
        }
        other => bail!("unsupported tensor dtype {other:?} (expected \"f32\" or \"i32\")"),
    }
}

/// A decoded request envelope: the op name, the echo id, the optional
/// trace-context fields, and the raw object for op-specific fields.
#[derive(Debug)]
pub struct WireRequest {
    pub op: String,
    pub id: Option<u64>,
    /// client-supplied trace correlation id (`"trace_id"`), echoed in the
    /// submit reply's span breakdown and recorded on the server trace
    pub trace_id: Option<String>,
    /// tenant identity (`"client_id"`) — the per-client metrics and SLO
    /// dimension
    pub client_id: Option<String>,
    pub body: Json,
}

/// Decode a frame payload into a request envelope.  The error string is
/// ready for [`error_reply`] with the paired code.
pub fn decode_request(payload: &str) -> Result<WireRequest, (ErrorCode, String)> {
    let body = Json::parse(payload)
        .map_err(|e| (ErrorCode::BadRequest, format!("invalid JSON: {e}")))?;
    if !matches!(body, Json::Obj(_)) {
        return Err((ErrorCode::BadRequest, "request must be a JSON object".to_string()));
    }
    let id = body.get("id").and_then(Json::as_i64).and_then(|v| u64::try_from(v).ok());
    let op = match body.get("op").and_then(Json::as_str) {
        Some(op) => op.to_string(),
        None => {
            return Err((ErrorCode::UnknownOp, "request has no \"op\" field".to_string()))
        }
    };
    let trace_id = opt_context_str(&body, "trace_id")?;
    let client_id = opt_context_str(&body, "client_id")?;
    Ok(WireRequest { op, id, trace_id, client_id, body })
}

/// Extract an optional trace-context string field (`trace_id` /
/// `client_id`): absent is fine, present must be a non-empty string of
/// at most 128 characters — ids are labels in metrics and logs, so
/// unbounded client-controlled values are rejected at the door.
fn opt_context_str(body: &Json, key: &str) -> Result<Option<String>, (ErrorCode, String)> {
    match body.get(key) {
        None => Ok(None),
        Some(Json::Str(s)) if !s.is_empty() && s.chars().count() <= 128 => {
            Ok(Some(s.clone()))
        }
        Some(_) => Err((
            ErrorCode::InvalidArgument,
            format!("\"{key}\" must be a non-empty string of at most 128 characters"),
        )),
    }
}

fn base_reply(id: Option<u64>, ok: bool) -> BTreeMap<String, Json> {
    let mut o = BTreeMap::new();
    if let Some(id) = id {
        o.insert("id".to_string(), Json::Num(id as f64));
    }
    o.insert("ok".to_string(), Json::Bool(ok));
    o
}

/// Build a success reply: the base envelope plus the op's result fields.
pub fn ok_reply(id: Option<u64>, fields: Vec<(&str, Json)>) -> String {
    let mut o = base_reply(id, true);
    for (k, v) in fields {
        o.insert(k.to_string(), v);
    }
    Json::Obj(o).to_string()
}

/// Build an error reply with a stable code, a human message, and an
/// optional retry hint (set for [`ErrorCode::Overloaded`]).
pub fn error_reply(
    id: Option<u64>,
    code: ErrorCode,
    message: &str,
    retry_after_ms: Option<u64>,
) -> String {
    error_reply_fields(id, code, message, retry_after_ms, Vec::new())
}

/// [`error_reply`] with extra structured fields inside the error object —
/// the overloaded reply uses it to attach a machine-readable shed
/// `reason` (and the burning SLO `objective` when admission was
/// tightened by it).
pub fn error_reply_fields(
    id: Option<u64>,
    code: ErrorCode,
    message: &str,
    retry_after_ms: Option<u64>,
    extra: Vec<(&str, Json)>,
) -> String {
    let mut err = BTreeMap::new();
    err.insert("code".to_string(), Json::Str(code.as_str().to_string()));
    err.insert("message".to_string(), Json::Str(message.to_string()));
    if let Some(ms) = retry_after_ms {
        err.insert("retry_after_ms".to_string(), Json::Num(ms as f64));
    }
    for (k, v) in extra {
        err.insert(k.to_string(), v);
    }
    let mut o = base_reply(id, false);
    o.insert("error".to_string(), Json::Obj(err));
    Json::Obj(o).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    #[test]
    fn tensor_roundtrip_is_bit_identical() {
        let mut rng = SplitMix64::new(11);
        let t = HostTensor::randn(vec![3, 17], &mut rng);
        let wire = tensor_to_json(&t).to_string();
        let back = tensor_from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.shape, t.shape);
        let (a, b) = (t.as_f32().unwrap(), back.as_f32().unwrap());
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "f32 data must survive the wire bit-exactly"
        );
    }

    #[test]
    fn i32_tensor_roundtrip() {
        let t = HostTensor::scalar_i32(-7);
        let wire = tensor_to_json(&t).to_string();
        let back = tensor_from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[-7]);
        assert!(back.shape.is_empty());
    }

    #[test]
    fn tensor_decode_rejects_garbage() {
        for bad in [
            r#"{"shape":[2],"data":[1]}"#,                     // length mismatch
            r#"{"shape":[1],"data":["x"]}"#,                   // non-numeric
            r#"{"shape":[1],"data":[1],"dtype":"f64"}"#,       // unknown dtype
            r#"{"data":[1]}"#,                                 // no shape
        ] {
            assert!(tensor_from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn request_envelope_decodes() {
        let req = decode_request(r#"{"id":4,"op":"health"}"#).unwrap();
        assert_eq!((req.op.as_str(), req.id), ("health", Some(4)));
        assert_eq!((req.trace_id, req.client_id), (None, None));
        assert_eq!(decode_request("nonsense").unwrap_err().0, ErrorCode::BadRequest);
        assert_eq!(decode_request("[1,2]").unwrap_err().0, ErrorCode::BadRequest);
        assert_eq!(decode_request(r#"{"id":1}"#).unwrap_err().0, ErrorCode::UnknownOp);
    }

    #[test]
    fn trace_context_fields_decode_and_validate() {
        let req = decode_request(
            r#"{"client_id":"acme","id":7,"op":"submit","trace_id":"req-0042"}"#,
        )
        .unwrap();
        assert_eq!(req.trace_id.as_deref(), Some("req-0042"));
        assert_eq!(req.client_id.as_deref(), Some("acme"));
        for bad in [
            r#"{"op":"submit","trace_id":""}"#,         // empty
            r#"{"op":"submit","trace_id":7}"#,          // not a string
            r#"{"client_id":[1],"op":"submit"}"#,       // not a string
        ] {
            assert_eq!(decode_request(bad).unwrap_err().0, ErrorCode::InvalidArgument, "{bad}");
        }
        let long = format!(r#"{{"op":"submit","trace_id":"{}"}}"#, "x".repeat(129));
        assert_eq!(decode_request(&long).unwrap_err().0, ErrorCode::InvalidArgument);
        let max = format!(r#"{{"op":"submit","trace_id":"{}"}}"#, "x".repeat(128));
        assert_eq!(decode_request(&max).unwrap().trace_id.unwrap().len(), 128);
    }

    #[test]
    fn replies_are_canonical_json() {
        assert_eq!(
            ok_reply(Some(1), vec![("status", Json::Str("ok".into()))]),
            r#"{"id":1,"ok":true,"status":"ok"}"#
        );
        assert_eq!(
            error_reply(None, ErrorCode::Overloaded, "queue full", Some(3)),
            r#"{"error":{"code":"overloaded","message":"queue full","retry_after_ms":3},"ok":false}"#
        );
    }

    #[test]
    fn error_reply_extra_fields_render_inside_error_object() {
        assert_eq!(
            error_reply_fields(
                Some(2),
                ErrorCode::Overloaded,
                "queue depth 4 >= shed watermark 4",
                Some(5),
                vec![("reason", Json::Str("queue_full".into()))],
            ),
            r#"{"error":{"code":"overloaded","message":"queue depth 4 >= shed watermark 4","reason":"queue_full","retry_after_ms":5},"ok":false}"#
        );
    }
}
