//! The wire-protocol serving front door: a std-only TCP server over the
//! in-process [`Coordinator`].
//!
//! Transport is deliberately minimal — length-prefixed JSON frames
//! ([`frame`]) over `std::net::TcpListener`, reusing the crate's own
//! [`crate::json`] codec; no new dependencies.  The protocol layer
//! ([`protocol`]) exposes four endpoints (`submit`, `kernels`, `stats`,
//! `health`) plus a `shutdown` op, each documented with replayable
//! examples in `docs/wire-protocol.md`.
//!
//! Robustness semantics, in one place:
//!
//! * **Admission control** — submits pass through
//!   [`Coordinator::submit_admit`]: the bounded queue sheds load at the
//!   configured watermark and the client receives a structured
//!   `overloaded` error with a `retry_after_ms` hint instead of a hang
//!   or a dropped connection.  Shed counts surface in the serving
//!   metrics (`repro stats`).
//! * **Per-connection timeouts** — reads and writes carry socket
//!   timeouts ([`NetConfig`]); a connection idle past the read timeout
//!   is closed and counted (`net_timeouts`).
//! * **Frame hygiene** — garbage JSON in a well-formed frame gets a
//!   clean `bad_request` reply and the connection survives; an
//!   unparseable frame (oversized length, truncation) gets a best-effort
//!   `bad_frame` reply and the connection closes, since the byte stream
//!   can no longer be resynchronized.
//! * **Graceful drain** — [`Server::shutdown`] stops accepting, lets
//!   in-flight requests finish and their replies flush, then returns;
//!   the caller drains the coordinator afterwards
//!   ([`Coordinator::drain`]), which flushes any still-queued batches.
//!
//! ```
//! use std::sync::Arc;
//! use ninetoothed_repro::coordinator::net::{Client, NetConfig, Server};
//! use ninetoothed_repro::coordinator::{Coordinator, CoordinatorConfig};
//! use ninetoothed_repro::runtime::{HostTensor, Manifest};
//!
//! let coordinator = Arc::new(
//!     Coordinator::start(Arc::new(Manifest::builtin()), CoordinatorConfig::default()).unwrap(),
//! );
//! // port 0: the OS picks a free port, `local_addr` reports it
//! let server = Server::start(coordinator.clone(), NetConfig::default()).unwrap();
//!
//! let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
//! let health = client.health().unwrap();
//! assert_eq!(health.str("status").unwrap(), "ok");
//!
//! let x = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
//! let y = HostTensor::f32(vec![2], vec![3.0, 4.0]).unwrap();
//! let reply = client.submit("add", "nt", &[x, y]).unwrap();
//! assert_eq!(reply.outputs[0].as_f32().unwrap(), &[4.0, 6.0]);
//!
//! server.shutdown();
//! coordinator.drain();
//! ```

pub mod frame;
pub mod protocol;

use std::collections::BTreeMap;
use std::io::{self, BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::server::{Coordinator, SubmitError, SubmitOpts};
use crate::exec::pool;
use crate::json::Json;
use crate::runtime::HostTensor;
use self::frame::{read_frame, write_frame, FrameError};
use self::protocol::{
    decode_request, error_reply, error_reply_fields, ok_reply, tensor_from_json, tensor_to_json,
    ErrorCode, WireRequest, PROTOCOL_VERSION,
};

/// Wire-transport knobs, startup-validated like every other `NT_*` knob.
///
/// ```
/// use ninetoothed_repro::coordinator::net::NetConfig;
///
/// let config = NetConfig::default();
/// assert_eq!(config.addr, "127.0.0.1:0"); // OS-assigned port
/// assert!(config.validate().is_ok());
/// ```
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// listen address, `host:port` (`port 0` = OS-assigned)
    pub addr: String,
    /// close a connection idle longer than this (counted in metrics)
    pub read_timeout: Duration,
    /// give up on a reply write blocked longer than this
    pub write_timeout: Duration,
    /// reject frames whose declared payload exceeds this
    pub max_frame_bytes: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            max_frame_bytes: frame::MAX_FRAME_BYTES,
        }
    }
}

impl NetConfig {
    /// Apply environment overrides: `NT_NET_READ_TIMEOUT_MS`,
    /// `NT_NET_WRITE_TIMEOUT_MS`, `NT_NET_MAX_FRAME_MB` (all validated
    /// positive integers — garbage fails startup, never defaults).
    pub fn from_env(mut self) -> Result<NetConfig> {
        if let Some(ms) = pool::parse_env_usize("NT_NET_READ_TIMEOUT_MS")? {
            self.read_timeout = Duration::from_millis(ms as u64);
        }
        if let Some(ms) = pool::parse_env_usize("NT_NET_WRITE_TIMEOUT_MS")? {
            self.write_timeout = Duration::from_millis(ms as u64);
        }
        if let Some(mb) = pool::parse_env_usize("NT_NET_MAX_FRAME_MB")? {
            self.max_frame_bytes = mb << 20;
        }
        self.validate()?;
        Ok(self)
    }

    /// Startup validation: non-zero timeouts, a frame cap big enough for
    /// any control-plane reply.
    pub fn validate(&self) -> Result<()> {
        if self.read_timeout.is_zero() || self.write_timeout.is_zero() {
            bail!("net config: read/write timeouts must be non-zero");
        }
        if self.max_frame_bytes < 1024 {
            bail!("net config: max_frame_bytes must be at least 1024");
        }
        Ok(())
    }
}

struct ServerShared {
    coordinator: Arc<Coordinator>,
    config: NetConfig,
    /// set by [`Server::shutdown`]: stop accepting, refuse new submits
    draining: AtomicBool,
    /// set when a wire `shutdown` op arrives ([`Server::wait`] watches it)
    shutdown_requested: AtomicBool,
    /// live connections: a stream handle (so drain can unblock readers)
    /// plus the serving thread
    conns: Mutex<Vec<(TcpStream, JoinHandle<()>)>>,
}

/// The TCP front door.  One OS thread accepts; one OS thread per
/// connection serves frames sequentially (replies preserve request
/// order within a connection).  Blocking threads — not an async
/// reactor — match the rest of the stack: execution itself is blocking
/// and CPU-bound.
pub struct Server {
    shared: Arc<ServerShared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `config.addr` and start accepting.  The coordinator is
    /// shared — in-process submitters keep working alongside the wire.
    pub fn start(coordinator: Arc<Coordinator>, config: NetConfig) -> Result<Server> {
        config.validate()?;
        let listener = TcpListener::bind(&config.addr)
            .with_context(|| format!("binding {}", config.addr))?;
        let addr = listener.local_addr()?;
        let shared = Arc::new(ServerShared {
            coordinator,
            config,
            draining: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("nt-net-accept".to_string())
                .spawn(move || accept_loop(shared, listener))
                .expect("spawn acceptor")
        };
        Ok(Server { shared, addr, accept: Some(accept) })
    }

    /// The actual bound address (resolves `:0` to the assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until a wire `shutdown` op arrives, then drain gracefully.
    /// `repro serve --addr` sits here.
    pub fn wait(self) {
        while !self.shared.shutdown_requested.load(Ordering::Acquire) {
            std::thread::sleep(Duration::from_millis(25));
        }
        self.shutdown();
    }

    /// Graceful drain: stop accepting, unblock idle readers, let
    /// in-flight requests finish and their replies flush, join every
    /// connection thread.  The coordinator itself keeps running — call
    /// [`Coordinator::drain`] afterwards to flush queued batches and
    /// stop the workers.
    pub fn shutdown(mut self) {
        self.shared.draining.store(true, Ordering::Release);
        // wake the blocking accept() so it observes the flag
        let _ = TcpStream::connect(self.addr);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        let conns: Vec<(TcpStream, JoinHandle<()>)> =
            self.shared.conns.lock().unwrap().drain(..).collect();
        for (stream, _) in &conns {
            // unblock readers parked in read_frame; the write side stays
            // open so in-flight replies still deliver
            let _ = stream.shutdown(Shutdown::Read);
        }
        for (_, handle) in conns {
            let _ = handle.join();
        }
    }
}

fn accept_loop(shared: Arc<ServerShared>, listener: TcpListener) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) => {
                if shared.draining.load(Ordering::Acquire) {
                    return;
                }
                continue;
            }
        };
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
        let handle_stream = match stream.try_clone() {
            Ok(clone) => clone,
            Err(_) => continue,
        };
        let conn_shared = shared.clone();
        let handle = std::thread::Builder::new()
            .name("nt-net-conn".to_string())
            .spawn(move || serve_connection(conn_shared, stream))
            .expect("spawn connection thread");
        let mut conns = shared.conns.lock().unwrap();
        // reap finished connections so the registry doesn't grow forever
        conns.retain(|(_, h)| !h.is_finished());
        conns.push((handle_stream, handle));
    }
}

fn serve_connection(shared: Arc<ServerShared>, stream: TcpStream) {
    let config = &shared.config;
    let _ = stream.set_read_timeout(Some(config.read_timeout));
    let _ = stream.set_write_timeout(Some(config.write_timeout));
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut writer = BufWriter::new(write_half);
    loop {
        match read_frame(&mut reader, config.max_frame_bytes) {
            Ok(payload) => {
                // the instant the full request frame was received: decode
                // and dispatch from here to submit is the net_read span
                let received = Instant::now();
                let (reply, trace) = handle_frame(&shared, &payload, received);
                let write_start = Instant::now();
                if let Err(e) = write_frame(&mut writer, &reply) {
                    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) {
                        shared.coordinator.note_net_timeout();
                    }
                    return;
                }
                if let Some((mut trace, sampled)) = trace {
                    // the reply frame is on the wire: append the
                    // net_write span, then hand the finished trace to
                    // the obs layer (trace ring + flight recorder)
                    let write_us = write_start.elapsed().as_micros() as u64;
                    let start = trace.total_us;
                    trace.spans.push(crate::obs::Span {
                        kind: crate::obs::SpanKind::NetWrite,
                        start_us: start,
                        end_us: start + write_us,
                    });
                    trace.total_us += write_us;
                    shared.coordinator.obs().note_request_done(sampled, trace);
                }
            }
            Err(FrameError::Closed) => return,
            Err(FrameError::TimedOut) => {
                shared.coordinator.note_net_timeout();
                return;
            }
            Err(FrameError::Malformed(msg)) => {
                // best effort: tell the peer why, then close — after a
                // framing violation the stream cannot be resynchronized
                let _ = write_frame(
                    &mut writer,
                    &error_reply(None, ErrorCode::BadFrame, &msg, None),
                );
                return;
            }
            Err(FrameError::Io(_)) => return,
        }
        if shared.draining.load(Ordering::Acquire) {
            return;
        }
    }
}

/// Decode one frame payload and execute its op.  Always returns a reply
/// frame — every failure mode maps to a structured error.  Successful
/// submits also return the request's trace (and its sampled flag) so the
/// connection loop can append the `net_write` span after the reply frame
/// is actually written.
fn handle_frame(
    shared: &ServerShared,
    payload: &str,
    received: Instant,
) -> (String, Option<(crate::obs::Trace, bool)>) {
    let req = match decode_request(payload) {
        Ok(req) => req,
        Err((code, msg)) => return (error_reply(None, code, &msg, None), None),
    };
    match req.op.as_str() {
        "health" => (handle_health(shared, req.id), None),
        "kernels" => (handle_kernels(req.id), None),
        "stats" => (handle_stats(shared, req.id, &req.body), None),
        "submit" => handle_submit(shared, &req, received),
        "shutdown" => {
            shared.shutdown_requested.store(true, Ordering::Release);
            (ok_reply(req.id, vec![("draining", Json::Bool(true))]), None)
        }
        other => (
            error_reply(
                req.id,
                ErrorCode::UnknownOp,
                &format!(
                    "unknown op {other:?} (expected submit, kernels, stats, health, shutdown)"
                ),
                None,
            ),
            None,
        ),
    }
}

fn handle_health(shared: &ServerShared, id: Option<u64>) -> String {
    let config = shared.coordinator.config();
    ok_reply(
        id,
        vec![
            ("draining", Json::Bool(shared.draining.load(Ordering::Acquire))),
            ("kernels", Json::Num(crate::kernel::kernels().len() as f64)),
            ("protocol", Json::Num(PROTOCOL_VERSION as f64)),
            ("queue_capacity", Json::Num(config.queue_capacity as f64)),
            ("queue_depth", Json::Num(shared.coordinator.queue_depth() as f64)),
            ("shed_watermark", Json::Num(config.effective_shed_watermark() as f64)),
            ("status", Json::Str("ok".to_string())),
            ("workers", Json::Num(config.workers as f64)),
        ],
    )
}

fn handle_kernels(id: Option<u64>) -> String {
    let mut defs = crate::kernel::kernels();
    defs.sort_by(|a, b| a.name.cmp(&b.name));
    let rows = defs
        .iter()
        .map(|def| {
            let mut o = BTreeMap::new();
            o.insert("arity".to_string(), Json::Num(def.arity as f64));
            o.insert("coalesce".to_string(), Json::Bool(def.coalesce));
            o.insert("executable".to_string(), Json::Bool(def.executable()));
            o.insert(
                "loop_carries".to_string(),
                match def.loop_carries() {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            );
            o.insert("name".to_string(), Json::Str(def.name.clone()));
            Json::Obj(o)
        })
        .collect();
    ok_reply(id, vec![("kernels", Json::Arr(rows))])
}

fn handle_stats(shared: &ServerShared, id: Option<u64>, body: &Json) -> String {
    let snapshot = shared.coordinator.obs_snapshot();
    match body.get("format").and_then(Json::as_str).unwrap_or("json") {
        "json" => ok_reply(id, vec![("stats", snapshot.to_json())]),
        "prometheus" => ok_reply(id, vec![("prometheus", Json::Str(snapshot.render_prometheus()))]),
        "table" => ok_reply(id, vec![("table", Json::Str(snapshot.render_table()))]),
        other => error_reply(
            id,
            ErrorCode::InvalidArgument,
            &format!("unknown stats format {other:?} (expected json, prometheus, table)"),
            None,
        ),
    }
}

fn handle_submit(
    shared: &ServerShared,
    req: &WireRequest,
    received: Instant,
) -> (String, Option<(crate::obs::Trace, bool)>) {
    let id = req.id;
    let body = &req.body;
    if shared.draining.load(Ordering::Acquire) {
        return (error_reply(id, ErrorCode::ShuttingDown, "server is draining", None), None);
    }
    let kernel = match body.str("kernel") {
        Ok(k) => k,
        Err(e) => {
            return (error_reply(id, ErrorCode::InvalidArgument, &format!("{e:#}"), None), None)
        }
    };
    let variant = body.get("variant").and_then(Json::as_str).unwrap_or("nt");
    let inputs: Vec<HostTensor> = match body
        .arr("inputs")
        .map_err(|e| anyhow!("{e:#}"))
        .and_then(|arr| arr.iter().map(tensor_from_json).collect())
    {
        Ok(inputs) => inputs,
        Err(e) => {
            return (error_reply(id, ErrorCode::InvalidArgument, &format!("{e:#}"), None), None)
        }
    };
    let opts = SubmitOpts {
        client_id: req.client_id.clone(),
        trace_id: req.trace_id.clone(),
        net_read_us: Some(received.elapsed().as_micros() as u64),
    };
    let rx = match shared.coordinator.submit_with(kernel, variant, inputs, opts) {
        Ok(rx) => rx,
        Err(SubmitError::Invalid(e)) => {
            return (error_reply(id, ErrorCode::InvalidArgument, &format!("{e:#}"), None), None)
        }
        Err(SubmitError::Overloaded { depth, watermark, retry_after_ms, slo_objective }) => {
            // a machine-readable shed reason: plain backpressure, or the
            // SLO feedback loop tightening admission while a budget burns
            let reason = if slo_objective.is_some() { "slo_burn" } else { "queue_full" };
            let msg = match &slo_objective {
                Some(obj) => format!(
                    "queue depth {depth} >= shed watermark {watermark} \
                     (lowered while SLO {obj} burns)"
                ),
                None => format!("queue depth {depth} >= shed watermark {watermark}"),
            };
            let mut extra = vec![("reason", Json::Str(reason.to_string()))];
            if let Some(obj) = slo_objective {
                extra.push(("objective", Json::Str(obj)));
            }
            return (
                error_reply_fields(id, ErrorCode::Overloaded, &msg, Some(retry_after_ms), extra),
                None,
            );
        }
    };
    match rx.recv() {
        Ok(Ok(resp)) => {
            let mut fields = vec![
                ("backend", Json::Str(resp.backend.to_string())),
                ("batch_size", Json::Num(resp.batch_size as f64)),
                ("exec_us", Json::Num(resp.exec_us as f64)),
                ("outputs", Json::Arr(resp.outputs.iter().map(tensor_to_json).collect())),
                ("queue_us", Json::Num(resp.queue_us as f64)),
            ];
            if let Some(trace) = &resp.trace {
                fields.push(("trace", breakdown_json(trace)));
            }
            (ok_reply(id, fields), resp.trace.map(|t| (t, resp.sampled)))
        }
        Ok(Err(e)) => (error_reply(id, ErrorCode::Internal, &format!("{e:#}"), None), None),
        Err(_) => (error_reply(id, ErrorCode::Internal, "worker dropped the reply", None), None),
    }
}

/// The per-span breakdown echoed inside a submit reply: span kinds and
/// durations (µs) in timeline order, the server-side total, and the
/// echoed trace id.  Built before the reply frame is written, so the
/// `net_write` span is never in it — only the server's own recorded
/// trace carries that.
fn breakdown_json(t: &crate::obs::Trace) -> Json {
    let spans = t
        .spans
        .iter()
        .map(|s| {
            let mut span = BTreeMap::new();
            span.insert("kind".to_string(), Json::Str(s.kind.name().to_string()));
            span.insert(
                "us".to_string(),
                Json::Num(s.end_us.saturating_sub(s.start_us) as f64),
            );
            Json::Obj(span)
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("spans".to_string(), Json::Arr(spans));
    o.insert("total_us".to_string(), Json::Num(t.total_us as f64));
    if let Some(trace_id) = &t.trace_id {
        o.insert("trace_id".to_string(), Json::Str(trace_id.clone()));
    }
    Json::Obj(o)
}

/// The server's span breakdown, decoded from a submit reply's `trace`
/// field: `(kind, duration µs)` pairs in timeline order plus the
/// server-side total and the echoed trace id.
#[derive(Debug, Clone)]
pub struct TraceBreakdown {
    pub spans: Vec<(String, u64)>,
    pub total_us: u64,
    pub trace_id: Option<String>,
}

/// A decoded `submit` success reply.
#[derive(Debug)]
pub struct SubmitReply {
    pub outputs: Vec<HostTensor>,
    pub queue_us: u64,
    pub exec_us: u64,
    pub batch_size: usize,
    pub backend: String,
    /// the server's per-span breakdown (wire submits always carry one)
    pub trace: Option<TraceBreakdown>,
}

/// The tiny client helper: one connection, sequential request/reply.
/// `examples/client.rs` and the protocol tests drive the server through
/// this; [`Client::call_raw`] is the escape hatch for hand-built frames.
pub struct Client {
    stream: TcpStream,
    max_frame_bytes: usize,
    next_id: u64,
    /// tenant identity attached to every submit (None = anonymous)
    client_id: Option<String>,
}

impl Client {
    /// Connect once (no retry); `addr` is `host:port`.
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            stream,
            max_frame_bytes: frame::MAX_FRAME_BYTES,
            next_id: 0,
            client_id: None,
        })
    }

    /// Attach a tenant identity: every later submit carries it as
    /// `client_id`, landing in the server's per-client metrics rows.
    pub fn set_client_id(&mut self, client_id: impl Into<String>) {
        self.client_id = Some(client_id.into());
    }

    /// Connect, retrying with backoff until `timeout` elapses — for
    /// racing a server that is still binding (the CI smoke step).
    pub fn connect_with_retry(addr: &str, timeout: Duration) -> Result<Client> {
        let deadline = Instant::now() + timeout;
        let mut wait = Duration::from_millis(20);
        loop {
            match Client::connect(addr) {
                Ok(client) => return Ok(client),
                Err(e) if Instant::now() + wait >= deadline => {
                    return Err(e.wrap(format!("no server at {addr} within {timeout:?}")))
                }
                Err(_) => {
                    std::thread::sleep(wait);
                    wait = (wait * 2).min(Duration::from_millis(500));
                }
            }
        }
    }

    /// Send one raw payload as a frame and read one reply frame.
    pub fn call_raw(&mut self, payload: &str) -> Result<String> {
        write_frame(&mut self.stream, payload)?;
        read_frame(&mut self.stream, self.max_frame_bytes).map_err(|e| anyhow!("{e}"))
    }

    /// Send an op and parse the reply object (which may be `ok:false` —
    /// use [`Client::expect_ok`] to turn errors into `Err`).
    pub fn call(&mut self, mut fields: BTreeMap<String, Json>) -> Result<Json> {
        self.next_id += 1;
        fields.insert("id".to_string(), Json::Num(self.next_id as f64));
        let reply = self.call_raw(&Json::Obj(fields).to_string())?;
        Json::parse(&reply).map_err(|e| anyhow!("unparseable reply: {e}"))
    }

    /// Convert an `ok:false` reply into an error carrying the protocol
    /// code and message.
    pub fn expect_ok(reply: Json) -> Result<Json> {
        if reply.get("ok").and_then(Json::as_bool) == Some(true) {
            return Ok(reply);
        }
        let code = reply
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(Json::as_str)
            .unwrap_or("unknown");
        let msg = reply
            .get("error")
            .and_then(|e| e.get("message"))
            .and_then(Json::as_str)
            .unwrap_or("");
        bail!("server error [{code}]: {msg}")
    }

    fn op(name: &str) -> BTreeMap<String, Json> {
        let mut o = BTreeMap::new();
        o.insert("op".to_string(), Json::Str(name.to_string()));
        o
    }

    /// `health` — server liveness + queue state.
    pub fn health(&mut self) -> Result<Json> {
        Self::expect_ok(self.call(Self::op("health"))?)
    }

    /// `kernels` — the registry as the server exposes it.
    pub fn kernels(&mut self) -> Result<Json> {
        Self::expect_ok(self.call(Self::op("kernels"))?)
    }

    /// `stats` with `format:"json"` — the full [`crate::obs::ObsSnapshot`].
    pub fn stats_json(&mut self) -> Result<Json> {
        let mut o = Self::op("stats");
        o.insert("format".to_string(), Json::Str("json".to_string()));
        Ok(Self::expect_ok(self.call(o)?)?.req("stats")?.clone())
    }

    /// `stats` with `format:"prometheus"` — the text exposition.
    pub fn stats_prometheus(&mut self) -> Result<String> {
        let mut o = Self::op("stats");
        o.insert("format".to_string(), Json::Str("prometheus".to_string()));
        let reply = Self::expect_ok(self.call(o)?)?;
        Ok(reply.str("prometheus")?.to_string())
    }

    /// `submit`, returning the parsed reply object verbatim (ok **or**
    /// error) — the overload tests inspect shed replies through this.
    pub fn submit_raw(
        &mut self,
        kernel: &str,
        variant: &str,
        inputs: &[HostTensor],
    ) -> Result<Json> {
        self.submit_raw_traced(kernel, variant, inputs, None)
    }

    /// [`Client::submit_raw`] with a trace correlation id; the client's
    /// `client_id` (if set) rides along on both.
    pub fn submit_raw_traced(
        &mut self,
        kernel: &str,
        variant: &str,
        inputs: &[HostTensor],
        trace_id: Option<&str>,
    ) -> Result<Json> {
        let mut o = Self::op("submit");
        o.insert("kernel".to_string(), Json::Str(kernel.to_string()));
        o.insert("variant".to_string(), Json::Str(variant.to_string()));
        o.insert("inputs".to_string(), Json::Arr(inputs.iter().map(tensor_to_json).collect()));
        if let Some(trace_id) = trace_id {
            o.insert("trace_id".to_string(), Json::Str(trace_id.to_string()));
        }
        if let Some(client_id) = &self.client_id {
            o.insert("client_id".to_string(), Json::Str(client_id.clone()));
        }
        self.call(o)
    }

    /// `submit`, decoded: outputs + timing, or the server's error.
    pub fn submit(
        &mut self,
        kernel: &str,
        variant: &str,
        inputs: &[HostTensor],
    ) -> Result<SubmitReply> {
        self.submit_traced(kernel, variant, inputs, None)
    }

    /// [`Client::submit`] with a trace correlation id: the decoded reply
    /// includes the server's span breakdown with the id echoed back.
    pub fn submit_traced(
        &mut self,
        kernel: &str,
        variant: &str,
        inputs: &[HostTensor],
        trace_id: Option<&str>,
    ) -> Result<SubmitReply> {
        let reply = Self::expect_ok(self.submit_raw_traced(kernel, variant, inputs, trace_id)?)?;
        let outputs = reply
            .arr("outputs")?
            .iter()
            .map(tensor_from_json)
            .collect::<Result<Vec<_>>>()?;
        let trace = match reply.get("trace") {
            Some(t) => Some(parse_breakdown(t)?),
            None => None,
        };
        Ok(SubmitReply {
            outputs,
            queue_us: reply.usize("queue_us")? as u64,
            exec_us: reply.usize("exec_us")? as u64,
            batch_size: reply.usize("batch_size")?,
            backend: reply.str("backend")?.to_string(),
            trace,
        })
    }

    /// Ask the server to drain and exit (`repro serve --addr` honors it).
    pub fn shutdown_server(&mut self) -> Result<()> {
        Self::expect_ok(self.call(Self::op("shutdown"))?)?;
        Ok(())
    }
}

/// Decode a submit reply's `trace` field.
fn parse_breakdown(v: &Json) -> Result<TraceBreakdown> {
    let spans = v
        .arr("spans")?
        .iter()
        .map(|s| Ok((s.str("kind")?.to_string(), s.usize("us")? as u64)))
        .collect::<Result<Vec<_>>>()?;
    Ok(TraceBreakdown {
        spans,
        total_us: v.usize("total_us")? as u64,
        trace_id: v.get("trace_id").and_then(Json::as_str).map(str::to_string),
    })
}
