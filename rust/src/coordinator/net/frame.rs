//! Length-prefixed frame codec: the wire unit of the serving protocol.
//!
//! A frame is a 4-byte big-endian `u32` payload length followed by that
//! many bytes of UTF-8 JSON.  The codec is transport-agnostic (`Read` /
//! `Write`), so the same functions back the TCP server, the client
//! helper and the in-memory codec tests.
//!
//! Failure taxonomy (what [`read_frame`] can return) drives the server's
//! connection policy:
//!
//! * [`FrameError::Closed`] — EOF *between* frames: the peer hung up
//!   cleanly; close quietly.
//! * [`FrameError::TimedOut`] — the read blocked past the socket's
//!   configured timeout: count a net timeout, close.
//! * [`FrameError::Malformed`] — oversized declared length, EOF in the
//!   middle of a frame, or a non-UTF-8 payload: reply with a `bad_frame`
//!   error (best effort) and close, because the stream can no longer be
//!   resynchronized.
//! * [`FrameError::Io`] — anything else the OS reports.
//!
//! Note that a well-formed frame carrying garbage *JSON* is not a frame
//! error: it decodes here, fails in the protocol layer, and the
//! connection survives.
//!
//! ```
//! use ninetoothed_repro::coordinator::net::frame::{read_frame, write_frame};
//!
//! let mut wire = Vec::new();
//! write_frame(&mut wire, r#"{"op":"health"}"#).unwrap();
//! assert_eq!(&wire[..4], &15u32.to_be_bytes());
//!
//! let mut reader = wire.as_slice();
//! assert_eq!(read_frame(&mut reader, 1024).unwrap(), r#"{"op":"health"}"#);
//! ```

use std::fmt;
use std::io::{self, Read, Write};

/// Default cap on a single frame's payload (server and client side).
/// Large enough for a coalescible batch of serialized f32 tensors,
/// small enough that a hostile length prefix cannot OOM the server.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum FrameError {
    /// clean EOF on a frame boundary (the peer closed the connection)
    Closed,
    /// the socket's read timeout elapsed with no (complete) frame
    TimedOut,
    /// protocol violation: oversized length, truncated frame, bad UTF-8.
    /// The stream cannot be resynchronized after this.
    Malformed(String),
    Io(io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::TimedOut => write!(f, "read timed out"),
            FrameError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            FrameError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn classify(e: io::Error) -> FrameError {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => FrameError::TimedOut,
        io::ErrorKind::UnexpectedEof => {
            FrameError::Malformed("connection closed mid-frame".to_string())
        }
        _ => FrameError::Io(e),
    }
}

/// Read one frame; `max_bytes` bounds the declared payload length.
///
/// EOF before the first length byte is [`FrameError::Closed`]; EOF (or a
/// timeout) anywhere later is a protocol violation, because a prefix of
/// a frame has already been consumed.
pub fn read_frame(r: &mut impl Read, max_bytes: usize) -> Result<String, FrameError> {
    let mut len_buf = [0u8; 4];
    // distinguish clean close (0 bytes) from a truncated prefix
    let mut got = 0;
    while got < len_buf.len() {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Err(FrameError::Closed),
            Ok(0) => {
                return Err(FrameError::Malformed(format!(
                    "connection closed after {got} of 4 length-prefix bytes"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if got == 0 => return Err(classify(e)),
            Err(e) => {
                return match classify(e) {
                    FrameError::TimedOut => Err(FrameError::Malformed(
                        "read timed out mid-length-prefix".to_string(),
                    )),
                    other => Err(other),
                }
            }
        }
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > max_bytes {
        return Err(FrameError::Malformed(format!(
            "declared frame length {len} exceeds the {max_bytes}-byte limit"
        )));
    }
    let mut payload = vec![0u8; len];
    if let Err(e) = r.read_exact(&mut payload) {
        return match classify(e) {
            FrameError::TimedOut => {
                Err(FrameError::Malformed("read timed out mid-frame".to_string()))
            }
            other => Err(other),
        };
    }
    String::from_utf8(payload)
        .map_err(|_| FrameError::Malformed("frame payload is not valid UTF-8".to_string()))
}

/// Write one frame (length prefix + payload) and flush.
pub fn write_frame(w: &mut impl Write, payload: &str) -> io::Result<()> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidInput, "frame payload exceeds u32 length")
    })?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(payload.as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips() {
        let mut wire = Vec::new();
        write_frame(&mut wire, "{}").unwrap();
        write_frame(&mut wire, r#"{"op":"health"}"#).unwrap();
        let mut r = wire.as_slice();
        assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).unwrap(), "{}");
        assert_eq!(read_frame(&mut r, MAX_FRAME_BYTES).unwrap(), r#"{"op":"health"}"#);
        assert!(matches!(read_frame(&mut r, MAX_FRAME_BYTES), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_length_is_malformed() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&u32::MAX.to_be_bytes());
        let err = read_frame(&mut wire.as_slice(), 1024).unwrap_err();
        assert!(matches!(&err, FrameError::Malformed(m) if m.contains("exceeds")), "{err}");
    }

    #[test]
    fn truncated_frame_is_malformed_not_closed() {
        // length says 10 bytes, body carries 3
        let mut wire = Vec::new();
        wire.extend_from_slice(&10u32.to_be_bytes());
        wire.extend_from_slice(b"abc");
        let err = read_frame(&mut wire.as_slice(), 1024).unwrap_err();
        assert!(matches!(err, FrameError::Malformed(_)), "{err}");
        // ...and so is a truncated length prefix
        let err = read_frame(&mut [0u8, 0].as_slice(), 1024).unwrap_err();
        assert!(matches!(&err, FrameError::Malformed(m) if m.contains("length-prefix")), "{err}");
    }

    #[test]
    fn non_utf8_payload_is_malformed() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&2u32.to_be_bytes());
        wire.extend_from_slice(&[0xff, 0xfe]);
        let err = read_frame(&mut wire.as_slice(), 1024).unwrap_err();
        assert!(matches!(&err, FrameError::Malformed(m) if m.contains("UTF-8")), "{err}");
    }
}
