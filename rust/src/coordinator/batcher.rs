//! Dynamic batching: slot packing under frozen AOT shapes, and native
//! request coalescing for shape-polymorphic routes.
//!
//! **Slot packing** ([`Packer`]): an element-wise artifact is compiled for
//! a fixed vector length (the "slot", e.g. 65536 for `add`).  Requests
//! carry arbitrary smaller lengths; the packer bin-packs consecutive
//! compatible requests into one slot, executes once, and scatters the
//! slices back to their owners.  Padding tail elements are zeros —
//! element-wise kernels map zeros to values the owners never see.
//!
//! **Native coalescing** ([`Coalescer`]): native routes have no frozen
//! slot, but same-kernel same-shape requests can share a launch anyway —
//! row-independent kernels (element-wise 1-D, rowwise 2-D) are stacked
//! along dim 0 into one tensor, executed as a single grid launch against
//! one cached compiled program, and split back on reply.  Because every
//! row/element is computed by the same per-tile math regardless of how
//! many rows the launch carries, coalesced execution is **bit-identical**
//! to per-request execution (asserted in `exec`'s tests).

use anyhow::{bail, Result};

use crate::runtime::HostTensor;

/// Where each packed request's data lives inside the slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackPlan {
    pub offsets: Vec<usize>,
    pub lengths: Vec<usize>,
    pub used: usize,
    pub slot: usize,
}

impl PackPlan {
    /// Fraction of the slot occupied by real request data (0.0–1.0);
    /// the observability layer reports it as a packing-efficiency gauge.
    pub fn utilization(&self) -> f64 {
        if self.slot == 0 {
            return 0.0;
        }
        self.used as f64 / self.slot as f64
    }
}

pub struct Packer {
    pub slot: usize,
    /// max requests fused into one execution
    pub max_fanin: usize,
}

impl Packer {
    pub fn new(slot: usize, max_fanin: usize) -> Packer {
        Packer { slot, max_fanin }
    }

    /// Greedy first-fit over the queue order: take requests while they fit.
    /// Returns how many of `lengths` were packed and the plan.
    ///
    /// An oversized *head* request (one that can never fit the slot) is a
    /// clean error, not a silent zero-item plan — admission already
    /// rejects these, so hitting this means a bug upstream, and the
    /// caller fails the request with this message instead of looping.
    pub fn plan(&self, lengths: &[usize]) -> Result<(usize, PackPlan)> {
        if let Some(&head) = lengths.first() {
            if head > self.slot {
                bail!(
                    "request of {head} elements can never fit the {}-element artifact slot",
                    self.slot
                );
            }
        }
        let mut offsets = Vec::new();
        let mut taken_lengths = Vec::new();
        let mut used = 0;
        for &len in lengths.iter().take(self.max_fanin) {
            if used + len > self.slot {
                break;
            }
            offsets.push(used);
            taken_lengths.push(len);
            used += len;
        }
        let taken = offsets.len();
        Ok((taken, PackPlan { offsets, lengths: taken_lengths, used, slot: self.slot }))
    }

    /// Gather the per-request vectors into one slot-sized buffer per input.
    ///
    /// Inputs are guaranteed f32 by router admission (packable routes
    /// reject non-f32 and zero-length tensors before they reach a queue).
    pub fn pack(&self, plan: &PackPlan, inputs_per_request: &[Vec<&HostTensor>]) -> Vec<HostTensor> {
        let n_args = inputs_per_request[0].len();
        let mut out = Vec::with_capacity(n_args);
        for arg in 0..n_args {
            let mut buf = vec![0f32; self.slot];
            for (req_idx, req_inputs) in inputs_per_request.iter().enumerate() {
                let src = req_inputs[arg].as_f32().expect("packable inputs are f32");
                let off = plan.offsets[req_idx];
                buf[off..off + src.len()].copy_from_slice(src);
            }
            out.push(HostTensor::f32(vec![self.slot], buf).expect("slot shape"));
        }
        out
    }

    /// Split a slot-sized output back into per-request tensors.
    pub fn unpack(&self, plan: &PackPlan, output: &HostTensor) -> Vec<HostTensor> {
        let data = output.as_f32().expect("packable outputs are f32");
        plan.offsets
            .iter()
            .zip(&plan.lengths)
            .map(|(&off, &len)| {
                HostTensor::f32(vec![len], data[off..off + len].to_vec()).expect("slice")
            })
            .collect()
    }
}

/// Native request coalescing: stack same-shape requests along dim 0 into
/// one grid launch.  [`Coalescer::plan`] decides how many consecutive
/// queued requests share the head's shapes; `stack`/`unstack` are the
/// data movement.
pub struct Coalescer {
    /// max requests stacked into one launch
    pub max_fanin: usize,
}

impl Coalescer {
    pub fn new(max_fanin: usize) -> Coalescer {
        Coalescer { max_fanin: max_fanin.max(1) }
    }

    /// How many leading requests (each described by its full input-shape
    /// set) can coalesce with the head: consecutive, identical shape
    /// sets, bounded by the fan-in.
    pub fn plan(&self, shape_sets: &[Vec<&[usize]>]) -> usize {
        let Some(head) = shape_sets.first() else { return 0 };
        shape_sets.iter().take(self.max_fanin).take_while(|s| *s == head).count()
    }

    /// Concatenate per-request inputs along dim 0 (all requests carry
    /// identical shapes, so this is a flat append per argument).
    pub fn stack(per_request: &[Vec<&HostTensor>]) -> Result<Vec<HostTensor>> {
        let Some(head) = per_request.first() else {
            bail!("coalesce of zero requests");
        };
        let count = per_request.len();
        let mut out = Vec::with_capacity(head.len());
        for arg in 0..head.len() {
            let proto = &head[arg];
            let mut data = Vec::with_capacity(proto.len() * count);
            for req in per_request {
                if req.len() != head.len() || req[arg].shape != proto.shape {
                    bail!(
                        "coalesced requests disagree: {:?} vs {:?} for argument {arg}",
                        req.get(arg).map(|t| &t.shape),
                        proto.shape
                    );
                }
                data.extend_from_slice(req[arg].as_f32()?);
            }
            let mut shape = proto.shape.clone();
            shape[0] *= count;
            out.push(HostTensor::f32(shape, data)?);
        }
        Ok(out)
    }

    /// Split stacked outputs back into `count` per-request output sets.
    pub fn unstack(count: usize, outputs: Vec<HostTensor>) -> Result<Vec<Vec<HostTensor>>> {
        if count == 0 {
            bail!("unstack into zero requests");
        }
        let mut per_request: Vec<Vec<HostTensor>> = (0..count).map(|_| Vec::new()).collect();
        for output in outputs {
            if output.shape.is_empty() || output.shape[0] % count != 0 {
                bail!(
                    "coalesced output shape {:?} does not split into {count} requests",
                    output.shape
                );
            }
            let mut shape = output.shape.clone();
            shape[0] /= count;
            let chunk: usize = shape.iter().product();
            let data = output.as_f32()?;
            for (i, slot) in per_request.iter_mut().enumerate() {
                slot.push(HostTensor::f32(
                    shape.clone(),
                    data[i * chunk..(i + 1) * chunk].to_vec(),
                )?);
            }
        }
        Ok(per_request)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_respects_slot() {
        let p = Packer::new(100, 8);
        let (taken, plan) = p.plan(&[40, 40, 40]).unwrap();
        assert_eq!(taken, 2);
        assert_eq!(plan.offsets, vec![0, 40]);
        assert_eq!(plan.used, 80);
    }

    #[test]
    fn plan_respects_fanin() {
        let p = Packer::new(100, 2);
        let (taken, _) = p.plan(&[10, 10, 10]).unwrap();
        assert_eq!(taken, 2);
    }

    #[test]
    fn utilization_is_used_over_slot() {
        let p = Packer::new(100, 8);
        let (_, plan) = p.plan(&[40, 40]).unwrap();
        assert!((plan.utilization() - 0.8).abs() < 1e-12);
        let empty = PackPlan { offsets: vec![], lengths: vec![], used: 0, slot: 0 };
        assert_eq!(empty.utilization(), 0.0);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let p = Packer::new(10, 8);
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::f32(vec![4], vec![4.0, 5.0, 6.0, 7.0]).unwrap();
        let (taken, plan) = p.plan(&[3, 4]).unwrap();
        assert_eq!(taken, 2);
        let packed = p.pack(&plan, &[vec![&a], vec![&b]]);
        assert_eq!(packed[0].as_f32().unwrap()[..7], [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let outs = p.unpack(&plan, &packed[0]);
        assert_eq!(outs[0], a);
        assert_eq!(outs[1], b);
    }

    #[test]
    fn oversized_head_is_a_clean_error_not_an_empty_plan() {
        // regression: plan([11]) used to return taken = 0 silently, which
        // made the drain loop rely on a downstream max(1) hack
        let p = Packer::new(10, 8);
        let err = p.plan(&[11]).unwrap_err();
        assert!(format!("{err:#}").contains("can never fit"), "{err:#}");
    }

    #[test]
    fn oversized_later_request_just_ends_the_pack() {
        // only the head is terminal: a later oversized request stays
        // queued and errors once it becomes the head
        let p = Packer::new(10, 8);
        let (taken, plan) = p.plan(&[6, 11, 3]).unwrap();
        assert_eq!(taken, 1);
        assert_eq!(plan.used, 6);
    }

    #[test]
    fn coalescer_plans_consecutive_same_shape_runs() {
        let c = Coalescer::new(8);
        let s1: Vec<&[usize]> = vec![&[4, 8]];
        let s2: Vec<&[usize]> = vec![&[4, 9]];
        assert_eq!(c.plan(&[s1.clone(), s1.clone(), s2, s1.clone()]), 2);
        assert_eq!(Coalescer::new(2).plan(&[s1.clone(), s1.clone(), s1]), 2);
        assert_eq!(c.plan(&[]), 0);
    }

    #[test]
    fn coalescer_stack_unstack_roundtrip() {
        let a = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let b = HostTensor::f32(vec![2, 3], (6..12).map(|i| i as f32).collect()).unwrap();
        let stacked = Coalescer::stack(&[vec![&a], vec![&b]]).unwrap();
        assert_eq!(stacked[0].shape, vec![4, 3]);
        let split = Coalescer::unstack(2, stacked).unwrap();
        assert_eq!(split[0][0], a);
        assert_eq!(split[1][0], b);
    }

    #[test]
    fn coalescer_rejects_mismatched_shapes() {
        let a = HostTensor::f32(vec![2, 3], vec![0.0; 6]).unwrap();
        let b = HostTensor::f32(vec![3, 3], vec![0.0; 9]).unwrap();
        assert!(Coalescer::stack(&[vec![&a], vec![&b]]).is_err());
    }
}
