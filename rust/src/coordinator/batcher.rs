//! Slot packing: dynamic batching under frozen AOT shapes.
//!
//! An element-wise artifact is compiled for a fixed vector length (the
//! "slot", e.g. 65536 for `add`).  Requests carry arbitrary smaller
//! lengths; the packer bin-packs consecutive compatible requests into one
//! slot, executes once, and scatters the slices back to their owners.
//! Padding tail elements are zeros — element-wise kernels map zeros to
//! values the owners never see.

use crate::runtime::HostTensor;

/// Where each packed request's data lives inside the slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackPlan {
    pub offsets: Vec<usize>,
    pub lengths: Vec<usize>,
    pub used: usize,
    pub slot: usize,
}

pub struct Packer {
    pub slot: usize,
    /// max requests fused into one execution
    pub max_fanin: usize,
}

impl Packer {
    pub fn new(slot: usize, max_fanin: usize) -> Packer {
        Packer { slot, max_fanin }
    }

    /// Greedy first-fit over the queue order: take requests while they fit.
    /// Returns how many of `lengths` were packed and the plan.
    pub fn plan(&self, lengths: &[usize]) -> (usize, PackPlan) {
        let mut offsets = Vec::new();
        let mut taken_lengths = Vec::new();
        let mut used = 0;
        for &len in lengths.iter().take(self.max_fanin) {
            if used + len > self.slot {
                break;
            }
            offsets.push(used);
            taken_lengths.push(len);
            used += len;
        }
        let taken = offsets.len();
        (taken, PackPlan { offsets, lengths: taken_lengths, used, slot: self.slot })
    }

    /// Gather the per-request vectors into one slot-sized buffer per input.
    ///
    /// Inputs are guaranteed f32 by router admission (packable routes
    /// reject non-f32 and zero-length tensors before they reach a queue).
    pub fn pack(&self, plan: &PackPlan, inputs_per_request: &[Vec<&HostTensor>]) -> Vec<HostTensor> {
        let n_args = inputs_per_request[0].len();
        let mut out = Vec::with_capacity(n_args);
        for arg in 0..n_args {
            let mut buf = vec![0f32; self.slot];
            for (req_idx, req_inputs) in inputs_per_request.iter().enumerate() {
                let src = req_inputs[arg].as_f32().expect("packable inputs are f32");
                let off = plan.offsets[req_idx];
                buf[off..off + src.len()].copy_from_slice(src);
            }
            out.push(HostTensor::f32(vec![self.slot], buf).expect("slot shape"));
        }
        out
    }

    /// Split a slot-sized output back into per-request tensors.
    pub fn unpack(&self, plan: &PackPlan, output: &HostTensor) -> Vec<HostTensor> {
        let data = output.as_f32().expect("packable outputs are f32");
        plan.offsets
            .iter()
            .zip(&plan.lengths)
            .map(|(&off, &len)| {
                HostTensor::f32(vec![len], data[off..off + len].to_vec()).expect("slice")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_respects_slot() {
        let p = Packer::new(100, 8);
        let (taken, plan) = p.plan(&[40, 40, 40]);
        assert_eq!(taken, 2);
        assert_eq!(plan.offsets, vec![0, 40]);
        assert_eq!(plan.used, 80);
    }

    #[test]
    fn plan_respects_fanin() {
        let p = Packer::new(100, 2);
        let (taken, _) = p.plan(&[10, 10, 10]);
        assert_eq!(taken, 2);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let p = Packer::new(10, 8);
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::f32(vec![4], vec![4.0, 5.0, 6.0, 7.0]).unwrap();
        let (taken, plan) = p.plan(&[3, 4]);
        assert_eq!(taken, 2);
        let packed = p.pack(&plan, &[vec![&a], vec![&b]]);
        assert_eq!(packed[0].as_f32().unwrap()[..7], [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let outs = p.unpack(&plan, &packed[0]);
        assert_eq!(outs[0], a);
        assert_eq!(outs[1], b);
    }

    #[test]
    fn oversized_first_request_takes_zero() {
        let p = Packer::new(10, 8);
        let (taken, _) = p.plan(&[11]);
        assert_eq!(taken, 0);
    }
}
