//! Request admission and routing.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, Manifest};

/// One kernel invocation request.
#[derive(Debug)]
pub struct Request {
    pub kernel: String,
    pub variant: String,
    pub inputs: Vec<HostTensor>,
    pub submitted: Instant,
    /// where the response is delivered
    pub reply: mpsc::Sender<Result<Response>>,
}

#[derive(Debug)]
pub struct Response {
    pub outputs: Vec<HostTensor>,
    pub queue_us: u64,
    pub exec_us: u64,
    /// how many requests shared the execution (1 = unbatched)
    pub batch_size: usize,
}

/// Element-wise kernels whose single vector argument may be slot-packed.
pub const PACKABLE: &[&str] = &["add", "silu"];

/// Routing decision for an admitted request.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RouteKey {
    pub kernel: String,
    pub variant: String,
    /// packable requests share a queue per (kernel, variant)
    pub packable: bool,
}

pub struct Router {
    manifest: std::sync::Arc<Manifest>,
}

impl Router {
    pub fn new(manifest: std::sync::Arc<Manifest>) -> Router {
        Router { manifest }
    }

    /// Validate a request against the manifest; return its route.
    ///
    /// Packable element-wise requests may be *smaller* than the artifact
    /// slot (they are packed); all other requests must match the compiled
    /// shapes exactly — AOT artifacts are shape-specialized.
    pub fn admit(&self, req: &Request) -> Result<RouteKey> {
        let art = self.manifest.kernel(&req.kernel, &req.variant)?;
        let packable = PACKABLE.contains(&req.kernel.as_str());
        if req.inputs.len() != art.args.len() {
            bail!(
                "kernel {} expects {} inputs, got {}",
                req.kernel,
                art.args.len(),
                req.inputs.len()
            );
        }
        if packable {
            let slot = art.args[0].shape[0];
            for (i, (input, spec)) in req.inputs.iter().zip(&art.args).enumerate() {
                if input.shape.len() != spec.shape.len() {
                    bail!("input {i} rank mismatch for {}", req.kernel);
                }
                if input.len() > slot {
                    bail!(
                        "input {i} of {} elements exceeds the {}-element artifact slot",
                        input.len(),
                        slot
                    );
                }
            }
            // all vector inputs must agree in length
            let n = req.inputs[0].len();
            if req.inputs.iter().any(|t| t.len() != n) {
                bail!("packable request inputs must have equal length");
            }
        } else {
            for (i, (input, spec)) in req.inputs.iter().zip(&art.args).enumerate() {
                if input.shape != spec.shape {
                    bail!(
                        "input {i} shape {:?} != compiled shape {:?} for {}.{}",
                        input.shape,
                        spec.shape,
                        req.kernel,
                        req.variant
                    );
                }
            }
        }
        Ok(RouteKey { kernel: req.kernel.clone(), variant: req.variant.clone(), packable })
    }
}
