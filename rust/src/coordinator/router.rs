//! Request admission and routing.
//!
//! A request resolves against the manifest first (shape-specialized AOT
//! artifacts); when no artifact exists for the (kernel, variant), the
//! router validates against the native tile-program catalog instead and
//! marks the route native — the workers then execute it through the
//! `crate::exec` backend.  Malformed requests (wrong arity, rank-0 or
//! zero-length tensors, non-f32 data, incompatible shapes) are rejected
//! here with a clean error, never deeper in the pipeline.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, Manifest};

/// One kernel invocation request.
#[derive(Debug)]
pub struct Request {
    pub kernel: String,
    pub variant: String,
    pub inputs: Vec<HostTensor>,
    pub submitted: Instant,
    /// canonical input-shape signature ([`crate::obs::shape_sig`]) — the
    /// per-kernel metrics key, computed once at submit (rejections at
    /// admission are recorded against it too)
    pub shape_sig: String,
    /// whether the trace recorder sampled this request at submit
    pub sampled: bool,
    /// wall-clock of the first-use autotune search this submit triggered
    /// (`None` for the common no-tuning case) — traced as a `Tune` span
    pub tune_us: Option<u64>,
    /// tenant identity (wire `client_id`) — the per-client metrics and
    /// SLO dimension; `None` for anonymous / in-process submits
    pub client_id: Option<String>,
    /// client-supplied trace correlation id, carried into the recorded
    /// trace and echoed in the wire reply's breakdown
    pub trace_id: Option<String>,
    /// wire ingress time (frame read + decode) in µs; `Some` marks the
    /// request wire-originated — its trace gains a leading `net_read`
    /// span and its [`Response`] always carries the built trace
    pub net_read_us: Option<u64>,
    /// where the response is delivered
    pub reply: mpsc::Sender<Result<Response>>,
}

#[derive(Debug)]
pub struct Response {
    pub outputs: Vec<HostTensor>,
    pub queue_us: u64,
    pub exec_us: u64,
    /// how many requests shared the execution (1 = unbatched)
    pub batch_size: usize,
    /// which backend served the request ("artifact", "native", "reference")
    pub backend: &'static str,
    /// span timeline, present only for wire-originated requests: the
    /// front door echoes a per-span breakdown in the reply, then appends
    /// the `net_write` span and hands the trace to the obs layer
    pub trace: Option<crate::obs::Trace>,
    /// whether the trace recorder sampled this request (the front door
    /// rings only sampled wire traces)
    pub sampled: bool,
}

/// Element-wise kernels whose single vector argument may be slot-packed.
pub const PACKABLE: &[&str] = &["add", "silu"];

/// Routing decision for an admitted request.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RouteKey {
    pub kernel: String,
    pub variant: String,
    /// packable requests share a queue per (kernel, variant)
    pub packable: bool,
    /// no artifact exists: execute through the native tile backend
    pub native: bool,
    /// native route whose kernel is row-independent: the worker may stack
    /// consecutive same-shape requests into one grid launch (the native
    /// analogue of slot packing, bit-identical to per-request execution)
    pub coalescible: bool,
}

pub struct Router {
    manifest: std::sync::Arc<Manifest>,
}

impl Router {
    pub fn new(manifest: std::sync::Arc<Manifest>) -> Router {
        Router { manifest }
    }

    /// Validate a request; return its route.
    ///
    /// Artifact routes: packable element-wise requests may be *smaller*
    /// than the artifact slot (they are packed); all other requests must
    /// match the compiled shapes exactly — AOT artifacts are
    /// shape-specialized.  Native routes are shape-polymorphic: admission
    /// checks arity and computes a launch plan, which rejects anything
    /// the arrangement cannot tile.
    pub fn admit(&self, req: &Request) -> Result<RouteKey> {
        if req.inputs.is_empty() {
            bail!("request for {} carries no input tensors", req.kernel);
        }
        for (i, input) in req.inputs.iter().enumerate() {
            // rank-0 scalars are legal for artifact kernels that declare
            // them (addmm's alpha/beta); zero-length data never is
            if input.shape.iter().any(|&d| d == 0) {
                bail!(
                    "input {i} of {} has a zero-length dimension (shape {:?})",
                    req.kernel,
                    input.shape
                );
            }
        }
        match self.manifest.kernel(&req.kernel, &req.variant) {
            Ok(art) => {
                let packable = PACKABLE.contains(&req.kernel.as_str());
                if req.inputs.len() != art.args.len() {
                    bail!(
                        "kernel {} expects {} inputs, got {}",
                        req.kernel,
                        art.args.len(),
                        req.inputs.len()
                    );
                }
                if packable {
                    for (i, input) in req.inputs.iter().enumerate() {
                        if input.as_f32().is_err() {
                            bail!("input {i} of packable kernel {} must be f32", req.kernel);
                        }
                    }
                    let slot = art.args[0].shape[0];
                    for (i, (input, spec)) in req.inputs.iter().zip(&art.args).enumerate() {
                        if input.shape.len() != spec.shape.len() {
                            bail!("input {i} rank mismatch for {}", req.kernel);
                        }
                        if input.len() > slot {
                            bail!(
                                "input {i} of {} elements exceeds the {}-element artifact slot",
                                input.len(),
                                slot
                            );
                        }
                    }
                    // all vector inputs must agree in length
                    let n = req.inputs[0].len();
                    if req.inputs.iter().any(|t| t.len() != n) {
                        bail!("packable request inputs must have equal length");
                    }
                } else {
                    for (i, (input, spec)) in req.inputs.iter().zip(&art.args).enumerate() {
                        if input.shape != spec.shape {
                            bail!(
                                "input {i} shape {:?} != compiled shape {:?} for {}.{}",
                                input.shape,
                                spec.shape,
                                req.kernel,
                                req.variant
                            );
                        }
                    }
                }
                Ok(RouteKey {
                    kernel: req.kernel.clone(),
                    variant: req.variant.clone(),
                    packable,
                    native: false,
                    coalescible: false,
                })
            }
            Err(no_artifact) => {
                // native fallback: eligibility is decided by the same
                // classifier Registry::resolve uses, then the inputs must
                // pass the kernel's cheap shape checks
                let kind = match crate::runtime::native_fallback_kind(&req.kernel, &req.variant)
                {
                    Ok(kind) => kind,
                    Err(e) => bail!(
                        "kernel {}.{}: no AOT artifact ({no_artifact:#}); {e:#}",
                        req.kernel,
                        req.variant
                    ),
                };
                let def = crate::kernel::lookup(&req.kernel);
                if let Some(kernel) = &def {
                    kernel.check(&req.inputs)?;
                }
                // (a ref-only kernel with no definition validates at run)
                // coalescing's bit-identity contract is proven against the
                // *tile programs*, so only routes that will resolve to the
                // native backend coalesce — a `ref`-variant route executes
                // through the reference oracle and stays per-request.  The
                // flag itself is derived from the arrangement by
                // `kernel::make` (row-independence), never set by hand.
                let coalescible = kind == crate::runtime::BackendKind::Native
                    && def.map(|k| k.coalesce).unwrap_or(false);
                Ok(RouteKey {
                    kernel: req.kernel.clone(),
                    variant: req.variant.clone(),
                    packable: false,
                    native: true,
                    coalescible,
                })
            }
        }
    }
}
