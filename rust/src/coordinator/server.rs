//! The coordinator event loop: bounded injector queue, per-route pending
//! queues, a worker-thread pool draining them with slot packing and native
//! coalescing, and graceful shutdown.  (The PJRT execute call is blocking,
//! so OS threads — not an async reactor — are the right concurrency
//! primitive here.)
//!
//! The `xla` crate's handles are `Rc`-based (not `Send`), so executables
//! cannot be shared across threads: **each worker owns its own PJRT client
//! and executable cache**, built lazily from the shared manifest.  The
//! native **plan cache is shared** across all workers (compiled programs
//! are `Send + Sync`): a shape compiled by any worker is a cache hit for
//! every other, and the hit/miss counters surface in [`Coordinator::metrics`].

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::{Coalescer, Packer};
use super::metrics::Metrics;
use super::router::{Request, Response, RouteKey, Router};
use crate::exec::{pool, GridScheduler, PlanCache, TuneMode, Tuner};
use crate::runtime::{Backend, HostTensor, Manifest, Registry};

/// Startup-validated serving knobs.
///
/// Every field has an environment override applied by
/// [`CoordinatorConfig::from_env`]; garbage values are a clean startup
/// error, never a silent default.
///
/// ```
/// use ninetoothed_repro::coordinator::CoordinatorConfig;
///
/// let config = CoordinatorConfig { queue_capacity: 8, ..Default::default() };
/// assert!(config.validate().is_ok());
/// assert_eq!(config.effective_shed_watermark(), 8); // defaults to capacity
///
/// let bad = CoordinatorConfig { shed_watermark: Some(9), ..config };
/// assert!(bad.validate().is_err()); // watermark must not exceed capacity
/// ```
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    /// injector queue capacity; submits beyond this are shed (backpressure)
    pub queue_capacity: usize,
    /// load-shedding watermark: submits at or beyond this queue depth are
    /// refused with a retry hint.  `None` means "at capacity" — shedding
    /// only when the queue is actually full.  Must be `<= queue_capacity`.
    pub shed_watermark: Option<usize>,
    /// max requests fused into one slot-packed execution (artifact routes)
    pub max_fanin: usize,
    /// max same-shape requests stacked into one native launch
    pub coalesce_fanin: usize,
    /// compiled plans kept in the shared cache (LRU beyond this)
    pub plan_cache_capacity: usize,
    /// block-size autotuning policy (`NT_TUNE`); `Off` is byte-for-byte
    /// the pre-tuner coordinator
    pub tune_mode: TuneMode,
    /// on-disk tuning table (`NT_TUNE_TABLE`): consulted at startup to
    /// restore winners, rewritten atomically after each search
    pub tune_table: Option<std::path::PathBuf>,
    /// latency-SLO objectives (`NT_SLO` spec string, e.g.
    /// `p99<2ms;mm:p99<5ms;client=acme:p95<10ms`), parsed and validated
    /// at startup.  While an objective's error budget is burning,
    /// admission sheds at half the configured watermark.
    pub slo: Option<String>,
    /// SLO evaluation window in milliseconds (`NT_SLO_WINDOW_MS`)
    pub slo_window_ms: usize,
    /// flight-recorder NDJSON path (`NT_EVENT_LOG`); `None` disables it
    pub event_log: Option<std::path::PathBuf>,
    /// rotate the event log before it would exceed this many KiB
    /// (`NT_EVENT_LOG_MAX_KB`)
    pub event_log_max_kb: usize,
    /// record the full trace of any request at least this slow (µs) into
    /// the event log (`NT_SLOW_US`); inert without `event_log`
    pub slow_us: Option<u64>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            queue_capacity: 1024,
            shed_watermark: None,
            max_fanin: 16,
            coalesce_fanin: 16,
            plan_cache_capacity: 256,
            tune_mode: TuneMode::Off,
            tune_table: None,
            slo: None,
            slo_window_ms: 1000,
            event_log: None,
            event_log_max_kb: crate::obs::events::DEFAULT_MAX_KB,
            slow_us: None,
        }
    }
}

impl CoordinatorConfig {
    /// Apply environment overrides: `NT_QUEUE_CAP`, `NT_SHED_WATERMARK`,
    /// `NT_COALESCE_FANIN`, `NT_PLAN_CACHE_CAP`, `NT_TUNE`,
    /// `NT_TUNE_TABLE`, `NT_SLO`, `NT_SLO_WINDOW_MS`, `NT_EVENT_LOG`,
    /// `NT_EVENT_LOG_MAX_KB`, `NT_SLOW_US` (all validated — garbage is a
    /// clean error, not a silent default).  `NT_POOL_THREADS` is read by
    /// the shared pool itself; [`Coordinator::start`] validates it too.
    pub fn from_env(mut self) -> Result<CoordinatorConfig> {
        if let Some(v) = pool::parse_env_usize("NT_QUEUE_CAP")? {
            self.queue_capacity = v;
        }
        if let Some(v) = pool::parse_env_usize("NT_SHED_WATERMARK")? {
            self.shed_watermark = Some(v);
        }
        if let Some(v) = pool::parse_env_usize("NT_COALESCE_FANIN")? {
            self.coalesce_fanin = v;
        }
        if let Some(v) = pool::parse_env_usize("NT_PLAN_CACHE_CAP")? {
            self.plan_cache_capacity = v;
        }
        self.tune_mode = TuneMode::from_env()?;
        if let Ok(path) = std::env::var("NT_TUNE_TABLE") {
            self.tune_table = Some(std::path::PathBuf::from(path));
        }
        if let Ok(spec) = std::env::var("NT_SLO") {
            self.slo = Some(spec);
        }
        if let Some(v) = pool::parse_env_usize("NT_SLO_WINDOW_MS")? {
            self.slo_window_ms = v;
        }
        if let Ok(path) = std::env::var("NT_EVENT_LOG") {
            self.event_log = Some(std::path::PathBuf::from(path));
        }
        if let Some(v) = pool::parse_env_usize("NT_EVENT_LOG_MAX_KB")? {
            self.event_log_max_kb = v;
        }
        if let Some(v) = pool::parse_env_usize("NT_SLOW_US")? {
            self.slow_us = Some(v as u64);
        }
        self.validate()?;
        Ok(self)
    }

    /// The queue depth at which admission starts shedding: the configured
    /// watermark, or the full queue capacity when none was set.
    pub fn effective_shed_watermark(&self) -> usize {
        self.shed_watermark.unwrap_or(self.queue_capacity)
    }

    /// Startup validation: every knob must be a positive integer, and the
    /// shed watermark must not exceed the queue capacity.
    pub fn validate(&self) -> Result<()> {
        for (name, value) in [
            ("workers", self.workers),
            ("queue_capacity", self.queue_capacity),
            ("shed_watermark", self.effective_shed_watermark()),
            ("max_fanin", self.max_fanin),
            ("coalesce_fanin", self.coalesce_fanin),
            ("plan_cache_capacity", self.plan_cache_capacity),
            ("slo_window_ms", self.slo_window_ms),
            ("event_log_max_kb", self.event_log_max_kb),
        ] {
            if value == 0 {
                bail!("coordinator config: {name} must be >= 1, got 0");
            }
        }
        if self.effective_shed_watermark() > self.queue_capacity {
            bail!(
                "coordinator config: shed_watermark ({}) must be <= queue_capacity ({})",
                self.effective_shed_watermark(),
                self.queue_capacity
            );
        }
        if let Some(spec) = &self.slo {
            crate::obs::parse_slo_spec(spec)
                .with_context(|| format!("coordinator config: invalid NT_SLO spec {spec:?}"))?;
        }
        Ok(())
    }
}

/// Why [`Coordinator::submit_admit`] refused a request.  The wire front
/// door maps the two variants to distinct protocol error codes
/// (`invalid_argument` vs `overloaded` + retry hint).
#[derive(Debug)]
pub enum SubmitError {
    /// the request itself is malformed (unknown kernel, bad arity/shapes);
    /// retrying the same request can never succeed
    Invalid(anyhow::Error),
    /// admission control shed the request: the queue depth reached the
    /// effective shed watermark.  The request was valid — retry after the
    /// hint.  `slo_objective` is `Some(spec)` when a burning SLO budget
    /// had lowered the watermark below its configured value.
    Overloaded {
        depth: usize,
        watermark: usize,
        retry_after_ms: u64,
        slo_objective: Option<String>,
    },
}

impl SubmitError {
    pub fn into_anyhow(self) -> anyhow::Error {
        match self {
            SubmitError::Invalid(e) => e,
            SubmitError::Overloaded { depth, watermark, retry_after_ms, slo_objective } => {
                let burn = slo_objective
                    .map(|o| format!(" [slo burn: {o}]"))
                    .unwrap_or_default();
                anyhow!(
                    "coordinator overloaded: queue depth {depth} >= shed watermark \
                     {watermark}{burn} (retry in ~{retry_after_ms}ms)"
                )
            }
        }
    }
}

/// Optional per-request context for [`Coordinator::submit_with`].  The
/// wire front door threads tenant identity and trace correlation through
/// it; in-process callers use [`Default`].
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// tenant identity for per-client metrics rows and SLO scoping
    pub client_id: Option<String>,
    /// client-supplied trace id, echoed in the reply's span breakdown
    pub trace_id: Option<String>,
    /// wire ingress time (frame read + decode) in µs.  `Some` marks the
    /// request wire-originated: its trace gains a leading `net_read`
    /// span (shifting every later span right) and its [`Response`]
    /// always carries the built trace, so the front door can echo a
    /// breakdown and append the `net_write` span after the reply write.
    pub net_read_us: Option<u64>,
}

struct Shared {
    queues: Mutex<State>,
    available: Condvar,
    metrics: Metrics,
    /// per-kernel/per-shape metrics + the sampled trace ring
    obs: crate::obs::Obs,
}

struct State {
    /// FIFO of routes with pending work (fairness across kernels)
    order: VecDeque<RouteKey>,
    pending: HashMap<RouteKey, VecDeque<Request>>,
    depth: usize,
    shutdown: bool,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    router: Arc<Router>,
    config: CoordinatorConfig,
    plan_cache: Arc<PlanCache>,
    /// the block-size autotuner; first-use searches run on the submitting
    /// thread (never inside the batcher drain path)
    tuner: Arc<Tuner>,
    /// parallelism budget for tuning measurements: the same per-worker
    /// budget serving executions get, so medians transfer
    tune_scheduler: GridScheduler,
    /// behind a mutex so [`Coordinator::drain`] can join through `&self`
    /// (the wire server holds the coordinator in an `Arc`)
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Coordinator {
    /// Validate the config (and the pool's env knobs) and start the
    /// worker threads.  Config errors surface here, before any thread
    /// spawns or requests are accepted.
    pub fn start(manifest: Arc<Manifest>, config: CoordinatorConfig) -> Result<Coordinator> {
        config.validate()?;
        // a malformed NT_POOL_THREADS should fail startup, not silently
        // fall back when the pool is first touched mid-request
        pool::configured_threads()?;
        let shared = Arc::new(Shared {
            queues: Mutex::new(State {
                order: VecDeque::new(),
                pending: HashMap::new(),
                depth: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
            metrics: Metrics::new(),
            // NT_TRACE_SAMPLE is validated here, with the other knobs;
            // the SLO engine and flight recorder are config-driven (their
            // env knobs flow through CoordinatorConfig::from_env), so
            // tests can inject them without touching process globals
            obs: {
                let mut obs = crate::obs::Obs::from_env()?;
                if let Some(spec) = &config.slo {
                    obs.slo = crate::obs::SloEngine::new(
                        crate::obs::parse_slo_spec(spec)?,
                        std::time::Duration::from_millis(config.slo_window_ms as u64),
                    );
                }
                if let Some(path) = &config.event_log {
                    obs.events = crate::obs::EventLog::to_file(
                        path.clone(),
                        (config.event_log_max_kb as u64) << 10,
                        config.slow_us,
                    )?;
                }
                obs
            },
        });
        let router = Arc::new(Router::new(manifest.clone()));
        let plan_cache = Arc::new(PlanCache::new(config.plan_cache_capacity));
        let tuner = Arc::new(Tuner::new(
            config.tune_mode,
            config.tune_table.clone(),
            plan_cache.clone(),
        ));
        let restored = tuner.restore();
        if restored > 0 {
            eprintln!("nt-tune: restored {restored} tuned plan(s) from the tuning table");
        }
        let mut workers = Vec::new();
        let worker_count = config.workers.max(1);
        for worker_id in 0..worker_count {
            let shared = shared.clone();
            let manifest = manifest.clone();
            let plan_cache = plan_cache.clone();
            let (max_fanin, coalesce_fanin) = (config.max_fanin, config.coalesce_fanin);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("nt-worker-{worker_id}"))
                    .spawn(move || {
                        // per-worker backend cache (PJRT handles are not
                        // Send) over the *shared* plan cache.  Native grid
                        // launches all share the persistent pool; the
                        // per-worker budget divides it so concurrent
                        // workers don't each fan out the whole machine.
                        let cores = std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1);
                        let registry = Registry::auto(manifest)
                            .with_native_threads((cores / worker_count).max(1))
                            .with_plan_cache(plan_cache);
                        worker_loop(shared, registry, max_fanin, coalesce_fanin)
                    })
                    .expect("spawn worker"),
            );
        }
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let tune_scheduler = GridScheduler::pooled((cores / worker_count).max(1));
        Ok(Coordinator {
            shared,
            router,
            config,
            plan_cache,
            tuner,
            tune_scheduler,
            workers: Mutex::new(workers),
        })
    }

    /// The autotuner (counters feed the obs snapshot; the `repro tune`
    /// harness drives searches through it directly).
    pub fn tuner(&self) -> &Arc<Tuner> {
        &self.tuner
    }

    /// Submit a request; the response arrives on the receiver.
    /// Fails fast on admission errors and on backpressure.
    pub fn submit(
        &self,
        kernel: &str,
        variant: &str,
        inputs: Vec<crate::runtime::HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        self.submit_admit(kernel, variant, inputs)
            .map_err(SubmitError::into_anyhow)
    }

    /// [`Coordinator::submit`] with a typed admission outcome: malformed
    /// requests come back as [`SubmitError::Invalid`], load-shed requests
    /// as [`SubmitError::Overloaded`] with a retry hint — the distinction
    /// the wire protocol's error codes are built on.
    pub fn submit_admit(
        &self,
        kernel: &str,
        variant: &str,
        inputs: Vec<crate::runtime::HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Response>>, SubmitError> {
        self.submit_with(kernel, variant, inputs, SubmitOpts::default())
    }

    /// [`Coordinator::submit_admit`] with per-request context: tenant
    /// identity (per-client metrics rows, SLO scoping), trace correlation
    /// and the wire ingress time — the wire front door's entry point.
    pub fn submit_with(
        &self,
        kernel: &str,
        variant: &str,
        inputs: Vec<crate::runtime::HostTensor>,
        opts: SubmitOpts,
    ) -> Result<mpsc::Receiver<Result<Response>>, SubmitError> {
        // due SLO windows evaluate on the submit path (a cheap no-op
        // between windows); breach transitions land in the event log
        self.shared.obs.tick_slo();
        let (tx, rx) = mpsc::channel();
        let shape_sig = {
            let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
            crate::obs::shape_sig(&shapes)
        };
        let mut req = Request {
            kernel: kernel.to_string(),
            variant: variant.to_string(),
            inputs,
            submitted: Instant::now(),
            shape_sig,
            sampled: self.shared.obs.traces.should_sample(),
            tune_us: None,
            client_id: opts.client_id,
            trace_id: opts.trace_id,
            net_read_us: opts.net_read_us,
            reply: tx,
        };
        // one registry lookup per submit; every admission outcome below
        // records against the same per-(kernel, shape, client) row
        let per_kernel = self.shared.obs.per_kernel.handle_for(
            &req.kernel,
            &req.shape_sig,
            req.client_id.as_deref(),
        );
        let route = match self.router.admit(&req) {
            Ok(route) => route,
            Err(e) => {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                per_kernel.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Invalid(e));
            }
        };
        // First-use autotuning runs HERE, on the submitting thread, after
        // admission validated the request and before it enters the launch
        // queue — never inside the batcher drain path.  A tuning failure
        // is logged and the request serves with the heuristic plan.
        if route.native && self.tuner.mode() != TuneMode::Off {
            if let Some(kernel_def) = crate::kernel::lookup(&req.kernel) {
                match self.tuner.maybe_tune(
                    &kernel_def,
                    &req.variant,
                    &req.inputs,
                    &self.tune_scheduler,
                ) {
                    Ok(Some(outcome)) => {
                        req.tune_us = Some(outcome.tune_us);
                        for m in [&self.shared.metrics, &*per_kernel] {
                            m.tuned_plans.fetch_add(1, Ordering::Relaxed);
                            m.tune_us_total.fetch_add(outcome.tune_us, Ordering::Relaxed);
                            m.tune_measurements.fetch_add(outcome.measurements, Ordering::Relaxed);
                        }
                        self.shared.obs.events.tune(
                            &req.kernel,
                            &req.shape_sig,
                            outcome.tune_us,
                            outcome.measurements,
                        );
                    }
                    Ok(None) => {}
                    Err(e) => eprintln!(
                        "nt-tune: {} {}: {e:#} (serving with the heuristic)",
                        req.kernel,
                        req.shape_sig
                    ),
                }
            }
        }
        let (watermark, slo_objective) = self.effective_watermark_now();
        // the admit event's fields, gathered before `req` moves into the
        // queue; emitted after the lock drops (never file I/O under it)
        let admit_event = if self.shared.obs.events.enabled() {
            Some((req.kernel.clone(), req.shape_sig.clone(), req.client_id.clone()))
        } else {
            None
        };
        {
            let mut state = self.shared.queues.lock().unwrap();
            if state.depth >= watermark {
                let depth = state.depth;
                drop(state);
                self.shared.metrics.shed.fetch_add(1, Ordering::Relaxed);
                per_kernel.shed.fetch_add(1, Ordering::Relaxed);
                if let Some((kernel, shapes, client)) = admit_event {
                    self.shared.obs.events.shed(
                        &kernel,
                        &shapes,
                        client.as_deref(),
                        depth,
                        watermark,
                        slo_objective.as_deref(),
                    );
                }
                return Err(SubmitError::Overloaded {
                    depth,
                    watermark,
                    retry_after_ms: self.retry_after_ms(depth),
                    slo_objective,
                });
            }
            if !state.pending.contains_key(&route) {
                state.order.push_back(route.clone());
            }
            state.pending.entry(route).or_default().push_back(req);
            state.depth += 1;
        }
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        per_kernel.submitted.fetch_add(1, Ordering::Relaxed);
        if let Some((kernel, shapes, client)) = admit_event {
            self.shared.obs.events.admit(&kernel, &shapes, client.as_deref());
        }
        self.shared.available.notify_one();
        Ok(rx)
    }

    /// The watermark admission enforces right now: the configured value,
    /// halved (min 1) while an SLO error budget is burning — the feedback
    /// loop that sheds load early to protect latency.  Returns the
    /// burning objective's spec alongside, for the structured shed reason.
    pub fn effective_watermark_now(&self) -> (usize, Option<String>) {
        let configured = self.config.effective_shed_watermark();
        match self.shared.obs.slo.burning_objective() {
            Some(objective) => ((configured / 2).max(1), Some(objective)),
            None => (configured, None),
        }
    }

    /// Estimate how long a shed client should wait before retrying:
    /// roughly the time the current backlog needs to drain (mean
    /// execution time x depth / workers), clamped to [1ms, 5s].  Before
    /// any execution completes, the floor (1ms) is the hint.
    fn retry_after_ms(&self, depth: usize) -> u64 {
        let execs = self.shared.metrics.executions.load(Ordering::Relaxed);
        let exec_us = self.shared.metrics.exec_us_total.load(Ordering::Relaxed);
        let mean_us = if execs == 0 { 0 } else { exec_us / execs };
        let workers = self.config.workers.max(1) as u64;
        (depth as u64 * mean_us / workers / 1000).clamp(1, 5_000)
    }

    /// The validated config this coordinator was started with (the wire
    /// `health` endpoint reports the admission knobs from it).
    pub fn config(&self) -> &CoordinatorConfig {
        &self.config
    }

    /// Requests currently queued (admitted, not yet drained by a worker).
    pub fn queue_depth(&self) -> usize {
        self.shared.queues.lock().unwrap().depth
    }

    /// Record a wire-connection read/write timeout into the serving
    /// metrics (the net front door has no kernel to attribute it to).
    pub fn note_net_timeout(&self) {
        self.shared.metrics.net_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Serving metrics, including the shared plan cache's hit/miss
    /// counters (cache-hit rate is how you observe that repeat shapes do
    /// zero specialization work).
    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.shared.metrics.snapshot(self.plan_cache.hits(), self.plan_cache.misses())
    }

    /// The live observability layer: the per-kernel/per-shape metrics
    /// registry and the sampled trace ring.
    pub fn obs(&self) -> &crate::obs::Obs {
        &self.shared.obs
    }

    /// One coherent snapshot of everything observable — global metrics,
    /// per-kernel/per-shape/per-client rows, per-kernel plan-cache
    /// attribution, SLO verdicts, the slowest sampled traces, per-plan
    /// profiles (under `NT_PROFILE=1`), and pool gauges.
    pub fn obs_snapshot(&self) -> crate::obs::ObsSnapshot {
        // scrapes also drive due SLO windows, so an idle-but-scraped
        // server still evaluates its objectives
        self.shared.obs.tick_slo();
        crate::obs::ObsSnapshot {
            global: self.metrics(),
            kernels: self.shared.obs.per_kernel.snapshot(),
            plan_kernels: self.plan_cache.kernel_counters(),
            slo: self.shared.obs.slo.statuses(),
            traces: self.shared.obs.traces.slowest(crate::obs::TRACE_TOP_N),
            profiles: self.plan_cache.profile_snapshots(),
            pool: pool::global_gauges(),
        }
    }

    pub fn shutdown(self) {
        self.drain();
    }

    /// Graceful drain through a shared reference: stop accepting nothing
    /// new here (submits still succeed until the flag is seen), set the
    /// shutdown flag, and join the workers — they exit only once every
    /// pending route queue is empty, so in-flight batches flush.
    /// Idempotent: a second call finds no workers to join.
    pub fn drain(&self) {
        {
            let mut state = self.shared.queues.lock().unwrap();
            state.shutdown = true;
        }
        self.shared.available.notify_all();
        let handles: Vec<JoinHandle<()>> = self.workers.lock().unwrap().drain(..).collect();
        for handle in handles {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, registry: Registry, max_fanin: usize, coalesce_fanin: usize) {
    loop {
        // take a batch of requests for one route
        let (route, batch) = {
            let mut state = shared.queues.lock().unwrap();
            loop {
                if let Some(route) = state.order.pop_front() {
                    let queue = state.pending.get_mut(&route).expect("queued route");
                    let batch = drain_batch(queue, &route, &registry, max_fanin, coalesce_fanin);
                    let remaining = !queue.is_empty();
                    if !remaining {
                        state.pending.remove(&route);
                    } else {
                        state.order.push_back(route.clone());
                    }
                    state.depth -= batch.len();
                    break (route, batch);
                }
                if state.shutdown {
                    return;
                }
                state = shared.available.wait(state).unwrap();
            }
        };
        execute_batch(&shared, &registry, &route, batch);
    }
}

/// Pull up to one execution's worth of requests off a route queue:
/// slot-packing fit for packable artifact routes, a consecutive
/// same-shape run for coalescible native routes, a single request
/// otherwise.
fn drain_batch(
    queue: &mut VecDeque<Request>,
    route: &RouteKey,
    registry: &Registry,
    max_fanin: usize,
    coalesce_fanin: usize,
) -> Vec<Request> {
    if route.packable {
        let slot = registry
            .manifest()
            .kernel(&route.kernel, &route.variant)
            .map(|a| a.args[0].shape[0])
            .unwrap_or(0);
        let packer = Packer::new(slot, max_fanin);
        // plan() takes at most max_fanin requests, so don't walk a deep
        // backlog under the shared queues lock
        let lengths: Vec<usize> =
            queue.iter().take(max_fanin).map(|r| r.inputs[0].len()).collect();
        let taken = match packer.plan(&lengths) {
            Ok((taken, _)) => taken.min(queue.len()).max(1),
            // oversized head (admission bug): take it alone so
            // execute_batch fails it with the packer's clean error
            Err(_) => 1,
        };
        return queue.drain(..taken).collect();
    }
    if route.coalescible && coalesce_fanin > 1 {
        let coalescer = Coalescer::new(coalesce_fanin);
        // only the first fan-in's worth of shapes can matter, so don't
        // materialize shape sets for a deep backlog (this runs under the
        // shared queues lock)
        let shape_sets: Vec<Vec<&[usize]>> = queue
            .iter()
            .take(coalesce_fanin)
            .map(|r| r.inputs.iter().map(|t| t.shape.as_slice()).collect())
            .collect();
        let taken = coalescer.plan(&shape_sets).min(queue.len()).max(1);
        return queue.drain(..taken).collect();
    }
    queue.pop_front().into_iter().collect()
}

fn execute_batch(shared: &Shared, registry: &Registry, route: &RouteKey, batch: Vec<Request>) {
    if batch.is_empty() {
        return;
    }
    let backend = match registry.resolve(&route.kernel, &route.variant) {
        Ok(backend) => backend,
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
    };
    let backend_name = backend.kind().as_str();

    // the instant this batch left the queue: the boundary between the
    // Queued and Batch spans of every request in it
    let drained = Instant::now();
    let queue_us: Vec<u64> = batch
        .iter()
        .map(|r| drained.saturating_duration_since(r.submitted).as_micros() as u64)
        .collect();
    // execution-level counters attribute to the head request's shape row
    // (coalesced batches share one shape; packed batches may not — the
    // head is the approximation there)
    let head_sig = batch[0].shape_sig.clone();
    let head_metrics = shared.obs.per_kernel.handle(&route.kernel, &head_sig);

    // slot dimension for packable (artifact) routes; native routes are
    // shape-polymorphic and coalesced instead of packed
    let slot = if route.packable {
        registry
            .manifest()
            .kernel(&route.kernel, &route.variant)
            .map(|a| a.args[0].shape[0])
            .expect("packable routes are artifact routes")
    } else {
        0
    };

    let t0 = Instant::now();
    let coalesced = !route.packable && route.coalescible && batch.len() > 1;
    // every branch funnels through `run`, which splits plan lookup
    // (prepare) from grid execution so the tracer can draw them as
    // separate spans and attribute the plan-cache outcome
    let mut plan_span: Option<(Instant, Instant)> = None;
    let mut plan_hit: Option<bool> = None;
    let mut run = |inputs: &[HostTensor]| -> Result<Vec<HostTensor>> {
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        let plan_start = Instant::now();
        let (prepared, hit) = backend.prepare_traced(&shapes)?;
        plan_span = Some((plan_start, Instant::now()));
        plan_hit = hit;
        backend.execute(&prepared, inputs)
    };
    let result: Result<Vec<Vec<HostTensor>>> = if route.packable
        && (batch.len() > 1 || batch[0].inputs[0].len() != slot)
    {
        // slot-packed execution
        let packer = Packer::new(slot, batch.len());
        let lengths: Vec<usize> = batch.iter().map(|r| r.inputs[0].len()).collect();
        match packer.plan(&lengths) {
            Ok((taken, plan)) if taken == batch.len() => {
                let per_request: Vec<Vec<&HostTensor>> =
                    batch.iter().map(|r| r.inputs.iter().collect()).collect();
                let packed = packer.pack(&plan, &per_request);
                run(&packed).map(|outs| {
                    packer
                        .unpack(&plan, &outs[0])
                        .into_iter()
                        .map(|t| vec![t])
                        .collect::<Vec<_>>()
                })
            }
            Ok(_) => Err(anyhow!("batch does not fit the {slot}-element slot")),
            Err(e) => Err(e),
        }
    } else if coalesced {
        // coalesced native execution: one stacked grid launch through the
        // plan cache, split back per request
        let per_request: Vec<Vec<&HostTensor>> =
            batch.iter().map(|r| r.inputs.iter().collect()).collect();
        Coalescer::stack(&per_request)
            .and_then(|stacked| run(&stacked))
            .and_then(|outs| Coalescer::unstack(batch.len(), outs))
    } else {
        run(&batch[0].inputs).map(|outs| vec![outs])
    };
    let exec_end = Instant::now();
    let exec_us = exec_end.duration_since(t0).as_micros() as u64;

    for m in [&shared.metrics, &*head_metrics] {
        m.executions.fetch_add(1, Ordering::Relaxed);
        m.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
        if batch.len() > 1 {
            m.batched.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
        if coalesced && result.is_ok() {
            m.coalesced.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
    }
    if plan_hit == Some(false) {
        shared.obs.events.plan_compile(&route.kernel, &head_sig);
    }

    match result {
        Ok(outputs_per_req) => {
            let n = batch.len();
            for ((req, outputs), q_us) in batch.into_iter().zip(outputs_per_req).zip(queue_us) {
                let req_metrics = shared.obs.per_kernel.handle_for(
                    &route.kernel,
                    &req.shape_sig,
                    req.client_id.as_deref(),
                );
                let wire = req.net_read_us.is_some();
                let total_us =
                    req.submitted.elapsed().as_micros() as u64 + req.net_read_us.unwrap_or(0);
                for m in [&shared.metrics, &*req_metrics] {
                    m.completed.fetch_add(1, Ordering::Relaxed);
                    m.queue_us_total.fetch_add(q_us, Ordering::Relaxed);
                    m.observe_latency_us(total_us);
                }
                // a trace is built when the sampler picked the request,
                // when it is wire-originated (the front door echoes the
                // breakdown), or when the flight recorder may want it as
                // a slow-request event
                let trace = if req.sampled || wire || shared.obs.events.wants_slow() {
                    Some(build_trace(
                        route, &req, drained, plan_span, t0, exec_end, plan_hit, n, coalesced,
                    ))
                } else {
                    None
                };
                let resp_trace = if wire { trace.clone() } else { None };
                let sampled = req.sampled;
                let _ = req.reply.send(Ok(Response {
                    outputs,
                    queue_us: q_us,
                    exec_us,
                    batch_size: n,
                    backend: backend_name,
                    trace: resp_trace,
                    sampled,
                }));
                // wire traces are finished (net_write appended) and
                // recorded by the front door after the reply frame is
                // written; in-process traces land here
                if !wire {
                    if let Some(trace) = trace {
                        shared.obs.note_request_done(sampled, trace);
                    }
                }
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

/// Assemble the span waterfall for one completed request: (net_read →)
/// (tune →) queued → batched → plan lookup/compile → grid execute →
/// reply, all as offsets from the wire ingress start (wire requests) or
/// the submit instant (in-process).  The `NetRead` span only appears on
/// wire-originated requests — it shifts every later span right by the
/// ingress time — and the `Tune` span only on the request that triggered
/// a first-use search.
#[allow(clippy::too_many_arguments)]
fn build_trace(
    route: &RouteKey,
    req: &Request,
    drained: Instant,
    plan_span: Option<(Instant, Instant)>,
    exec_start: Instant,
    exec_end: Instant,
    plan_hit: Option<bool>,
    batch_size: usize,
    coalesced: bool,
) -> crate::obs::Trace {
    use crate::obs::{Span, SpanKind};
    let shift = req.net_read_us.unwrap_or(0);
    let off =
        |t: Instant| t.saturating_duration_since(req.submitted).as_micros() as u64 + shift;
    let reply_end = Instant::now();
    let mut spans = Vec::new();
    if req.net_read_us.is_some() {
        spans.push(Span { kind: SpanKind::NetRead, start_us: 0, end_us: shift });
    }
    let queued_start = match req.tune_us {
        Some(t) => {
            spans.push(Span { kind: SpanKind::Tune, start_us: shift, end_us: shift + t });
            (shift + t).min(off(drained))
        }
        None => shift,
    };
    spans.push(Span { kind: SpanKind::Queued, start_us: queued_start, end_us: off(drained) });
    spans.push(Span { kind: SpanKind::Batch, start_us: off(drained), end_us: off(exec_start) });
    if let Some((ps, pe)) = plan_span {
        spans.push(Span { kind: SpanKind::Plan, start_us: off(ps), end_us: off(pe) });
        spans.push(Span { kind: SpanKind::Execute, start_us: off(pe), end_us: off(exec_end) });
    } else {
        spans.push(Span {
            kind: SpanKind::Execute,
            start_us: off(exec_start),
            end_us: off(exec_end),
        });
    }
    spans.push(Span { kind: SpanKind::Reply, start_us: off(exec_end), end_us: off(reply_end) });
    crate::obs::Trace {
        kernel: route.kernel.clone(),
        shapes: req.shape_sig.clone(),
        batch_size,
        coalesced,
        plan_hit,
        total_us: off(reply_end),
        trace_id: req.trace_id.clone(),
        client_id: req.client_id.clone(),
        spans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::SplitMix64;

    fn native_request(kernel: &str, inputs: Vec<HostTensor>) -> Request {
        let (tx, _rx) = mpsc::channel();
        // leak the receiver so sends do not error mid-test
        std::mem::forget(_rx);
        let shape_sig = {
            let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
            crate::obs::shape_sig(&shapes)
        };
        Request {
            kernel: kernel.to_string(),
            variant: "nt".to_string(),
            inputs,
            submitted: Instant::now(),
            shape_sig,
            sampled: false,
            tune_us: None,
            client_id: None,
            trace_id: None,
            net_read_us: None,
            reply: tx,
        }
    }

    fn native_route(kernel: &str, coalescible: bool) -> RouteKey {
        RouteKey {
            kernel: kernel.to_string(),
            variant: "nt".to_string(),
            packable: false,
            native: true,
            coalescible,
        }
    }

    #[test]
    fn drain_coalesces_consecutive_same_shape_requests() {
        let registry = Registry::native_only(Arc::new(Manifest::builtin()));
        let mut rng = SplitMix64::new(7);
        let mut queue: VecDeque<Request> = VecDeque::new();
        for _ in 0..3 {
            queue.push_back(native_request(
                "softmax",
                vec![HostTensor::randn(vec![4, 16], &mut rng)],
            ));
        }
        queue.push_back(native_request(
            "softmax",
            vec![HostTensor::randn(vec![5, 16], &mut rng)],
        ));
        let route = native_route("softmax", true);
        let batch = drain_batch(&mut queue, &route, &registry, 16, 16);
        assert_eq!(batch.len(), 3, "three same-shape heads must coalesce");
        assert_eq!(queue.len(), 1, "the different-shape tail stays queued");
        // next drain: the [5, 16] request runs alone
        let batch = drain_batch(&mut queue, &route, &registry, 16, 16);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn drain_respects_coalesce_fanin() {
        let registry = Registry::native_only(Arc::new(Manifest::builtin()));
        let mut rng = SplitMix64::new(8);
        let mut queue: VecDeque<Request> = VecDeque::new();
        for _ in 0..5 {
            queue.push_back(native_request(
                "silu",
                vec![HostTensor::randn(vec![64], &mut rng)],
            ));
        }
        let route = native_route("silu", true);
        let batch = drain_batch(&mut queue, &route, &registry, 16, 2);
        assert_eq!(batch.len(), 2);
        // fan-in 1 disables coalescing entirely
        let batch = drain_batch(&mut queue, &route, &registry, 16, 1);
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn drain_never_coalesces_non_coalescible_routes() {
        let registry = Registry::native_only(Arc::new(Manifest::builtin()));
        let mut rng = SplitMix64::new(9);
        let mut queue: VecDeque<Request> = VecDeque::new();
        for _ in 0..3 {
            let a = HostTensor::randn(vec![8, 8], &mut rng);
            let b = HostTensor::randn(vec![8, 8], &mut rng);
            queue.push_back(native_request("mm", vec![a, b]));
        }
        let route = native_route("mm", false);
        let batch = drain_batch(&mut queue, &route, &registry, 16, 16);
        assert_eq!(batch.len(), 1, "mm must never stack");
    }

    #[test]
    fn config_validation_rejects_zeroes() {
        for bad in [
            CoordinatorConfig { workers: 0, ..Default::default() },
            CoordinatorConfig { queue_capacity: 0, ..Default::default() },
            CoordinatorConfig { max_fanin: 0, ..Default::default() },
            CoordinatorConfig { coalesce_fanin: 0, ..Default::default() },
            CoordinatorConfig { plan_cache_capacity: 0, ..Default::default() },
        ] {
            let err = bad.validate().unwrap_err();
            assert!(format!("{err:#}").contains("must be >= 1"), "{err:#}");
            assert!(Coordinator::start(Arc::new(Manifest::builtin()), bad).is_err());
        }
        assert!(CoordinatorConfig::default().validate().is_ok());
    }
}
