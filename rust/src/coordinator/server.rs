//! The coordinator event loop: bounded injector queue, per-route pending
//! queues, a worker-thread pool draining them with slot packing, and
//! graceful shutdown.  (The PJRT execute call is blocking, so OS threads —
//! not an async reactor — are the right concurrency primitive here.)
//!
//! The `xla` crate's handles are `Rc`-based (not `Send`), so executables
//! cannot be shared across threads: **each worker owns its own PJRT client
//! and executable cache**, built lazily from the shared manifest.  This is
//! also what a multi-device deployment looks like (one client per device).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::Packer;
use super::metrics::Metrics;
use super::router::{Request, Response, RouteKey, Router};
use crate::runtime::{Backend, Manifest, Registry};

pub struct CoordinatorConfig {
    pub workers: usize,
    /// injector queue capacity; submits beyond this are rejected (backpressure)
    pub queue_capacity: usize,
    /// max requests fused into one slot-packed execution
    pub max_fanin: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig { workers: 2, queue_capacity: 1024, max_fanin: 16 }
    }
}

struct Shared {
    queues: Mutex<State>,
    available: Condvar,
    metrics: Metrics,
}

struct State {
    /// FIFO of routes with pending work (fairness across kernels)
    order: VecDeque<RouteKey>,
    pending: HashMap<RouteKey, VecDeque<Request>>,
    depth: usize,
    shutdown: bool,
}

pub struct Coordinator {
    shared: Arc<Shared>,
    router: Arc<Router>,
    config: CoordinatorConfig,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    pub fn start(manifest: Arc<Manifest>, config: CoordinatorConfig) -> Coordinator {
        let shared = Arc::new(Shared {
            queues: Mutex::new(State {
                order: VecDeque::new(),
                pending: HashMap::new(),
                depth: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
            metrics: Metrics::new(),
        });
        let router = Arc::new(Router::new(manifest.clone()));
        let mut workers = Vec::new();
        let worker_count = config.workers.max(1);
        for worker_id in 0..worker_count {
            let shared = shared.clone();
            let manifest = manifest.clone();
            let max_fanin = config.max_fanin;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("nt-worker-{worker_id}"))
                    .spawn(move || {
                        // per-worker backend cache; PJRT client when one is
                        // available, native-only otherwise.  Native grid
                        // executions share the machine with the other
                        // workers, so divide the cores among them.
                        let cores = std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1);
                        let registry = Registry::auto(manifest)
                            .with_native_threads((cores / worker_count).max(1));
                        worker_loop(shared, registry, max_fanin)
                    })
                    .expect("spawn worker"),
            );
        }
        Coordinator { shared, router, config, workers }
    }

    /// Submit a request; the response arrives on the receiver.
    /// Fails fast on admission errors and on backpressure.
    pub fn submit(
        &self,
        kernel: &str,
        variant: &str,
        inputs: Vec<crate::runtime::HostTensor>,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            kernel: kernel.to_string(),
            variant: variant.to_string(),
            inputs,
            submitted: Instant::now(),
            reply: tx,
        };
        let route = match self.router.admit(&req) {
            Ok(route) => route,
            Err(e) => {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        };
        {
            let mut state = self.shared.queues.lock().unwrap();
            if state.depth >= self.config.queue_capacity {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow!("coordinator queue full ({})", self.config.queue_capacity));
            }
            if !state.pending.contains_key(&route) {
                state.order.push_back(route.clone());
            }
            state.pending.entry(route).or_default().push_back(req);
            state.depth += 1;
        }
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.available.notify_one();
        Ok(rx)
    }

    pub fn metrics(&self) -> super::metrics::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    pub fn shutdown(mut self) {
        {
            let mut state = self.shared.queues.lock().unwrap();
            state.shutdown = true;
        }
        self.shared.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, registry: Registry, max_fanin: usize) {
    loop {
        // take a batch of requests for one route
        let (route, batch) = {
            let mut state = shared.queues.lock().unwrap();
            loop {
                if let Some(route) = state.order.pop_front() {
                    let queue = state.pending.get_mut(&route).expect("queued route");
                    let batch = drain_batch(queue, &route, &registry, max_fanin);
                    let remaining = !queue.is_empty();
                    if !remaining {
                        state.pending.remove(&route);
                    } else {
                        state.order.push_back(route.clone());
                    }
                    state.depth -= batch.len();
                    break (route, batch);
                }
                if state.shutdown {
                    return;
                }
                state = shared.available.wait(state).unwrap();
            }
        };
        execute_batch(&shared, &registry, &route, batch);
    }
}

/// Pull up to one execution's worth of requests off a route queue.
fn drain_batch(
    queue: &mut VecDeque<Request>,
    route: &RouteKey,
    registry: &Registry,
    max_fanin: usize,
) -> Vec<Request> {
    if !route.packable {
        return queue.pop_front().into_iter().collect();
    }
    let slot = registry
        .manifest()
        .kernel(&route.kernel, &route.variant)
        .map(|a| a.args[0].shape[0])
        .unwrap_or(0);
    let packer = Packer::new(slot, max_fanin);
    let lengths: Vec<usize> = queue.iter().map(|r| r.inputs[0].len()).collect();
    let (taken, _) = packer.plan(&lengths);
    let taken = taken.max(1).min(queue.len()); // oversized head: fail it downstream
    queue.drain(..taken).collect()
}

fn execute_batch(shared: &Shared, registry: &Registry, route: &RouteKey, batch: Vec<Request>) {
    if batch.is_empty() {
        return;
    }
    let backend = match registry.resolve(&route.kernel, &route.variant) {
        Ok(backend) => backend,
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.reply.send(Err(anyhow!("{msg}")));
            }
            return;
        }
    };
    let backend_name = backend.kind().as_str();

    let queue_us: Vec<u64> = batch
        .iter()
        .map(|r| r.submitted.elapsed().as_micros() as u64)
        .collect();

    // slot dimension for packable (artifact) routes; native routes are
    // shape-polymorphic and never packed
    let slot = if route.packable {
        registry
            .manifest()
            .kernel(&route.kernel, &route.variant)
            .map(|a| a.args[0].shape[0])
            .expect("packable routes are artifact routes")
    } else {
        0
    };

    let t0 = Instant::now();
    let result = if route.packable && (batch.len() > 1 || batch[0].inputs[0].len() != slot) {
        // slot-packed execution
        let packer = Packer::new(slot, batch.len());
        let lengths: Vec<usize> = batch.iter().map(|r| r.inputs[0].len()).collect();
        let (taken, plan) = packer.plan(&lengths);
        if taken != batch.len() {
            for req in batch {
                let _ = req
                    .reply
                    .send(Err(anyhow!("request does not fit the {slot}-element slot")));
            }
            return;
        }
        let per_request: Vec<Vec<&crate::runtime::HostTensor>> =
            batch.iter().map(|r| r.inputs.iter().collect()).collect();
        let packed = packer.pack(&plan, &per_request);
        backend.run(&packed).map(|outs| {
            packer
                .unpack(&plan, &outs[0])
                .into_iter()
                .map(|t| vec![t])
                .collect::<Vec<_>>()
        })
    } else {
        backend.run(&batch[0].inputs).map(|outs| vec![outs])
    };
    let exec_us = t0.elapsed().as_micros() as u64;

    shared.metrics.executions.fetch_add(1, Ordering::Relaxed);
    shared.metrics.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
    if batch.len() > 1 {
        shared
            .metrics
            .batched
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
    }

    match result {
        Ok(outputs_per_req) => {
            let n = batch.len();
            for ((req, outputs), q_us) in batch.into_iter().zip(outputs_per_req).zip(queue_us) {
                shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
                shared.metrics.queue_us_total.fetch_add(q_us, Ordering::Relaxed);
                let total_us = req.submitted.elapsed().as_micros() as u64;
                shared.metrics.observe_latency_us(total_us);
                let _ = req.reply.send(Ok(Response {
                    outputs,
                    queue_us: q_us,
                    exec_us,
                    batch_size: n,
                    backend: backend_name,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
