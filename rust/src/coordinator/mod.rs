//! L3 coordinator: a kernel-serving system over the AOT artifacts.
//!
//! The paper's contribution lives at the DSL layer, so the coordinator is
//! the serving shell a production deployment would put around the compiled
//! kernels (vllm-router-like in miniature):
//!
//! * [`router`] — admission + routing: validates request shapes against the
//!   manifest and the arrangement launch plans, picks the executable.
//!   Kernels without AOT artifacts route to the native tile-execution
//!   backend (`crate::exec`) — the coordinator serves them transparently.
//! * [`batcher`] — **slot packing**: AOT artifacts have fixed shapes, so
//!   variable-size element-wise requests are packed into the fixed vector
//!   slot of one artifact execution and split back afterwards (the dynamic
//!   batching strategy available when shapes are frozen ahead of time).
//! * [`server`] — worker-thread pool over an injector queue with bounded
//!   capacity (backpressure) and graceful shutdown.
//! * [`metrics`] — lock-free counters + log2 latency histogram.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{PackPlan, Packer};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::{Request, Response, Router};
pub use server::{Coordinator, CoordinatorConfig};
