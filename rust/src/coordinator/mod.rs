//! L3 coordinator: a kernel-serving system over the compiled kernels.
//!
//! The paper's contribution lives at the DSL layer, so the coordinator is
//! the serving shell a production deployment would put around the compiled
//! kernels (vllm-router-like in miniature):
//!
//! * [`router`] — admission + routing: validates request shapes against the
//!   manifest and the arrangement launch plans, picks the executable.
//!   Kernels without AOT artifacts route to the native tile-execution
//!   backend (`crate::exec`) — the coordinator serves them transparently,
//!   resolving each request to a **cached compiled program** via the
//!   registry's shared plan cache (hit/miss surfaced in [`metrics`]).
//! * [`batcher`] — two fusion strategies: **slot packing** (variable-size
//!   element-wise requests packed into an artifact's frozen vector slot)
//!   and **native coalescing** (same-kernel, same-shape requests for
//!   row-independent kernels stacked along dim 0 into one grid launch and
//!   split back on reply — bit-identical to per-request execution).
//! * [`server`] — worker-thread pool over an injector queue with bounded
//!   capacity (backpressure), startup-validated config (pool size, plan
//!   cache capacity, coalescing fan-in: env + flags) and graceful shutdown.
//! * [`metrics`] — lock-free counters (incl. plan-cache hits/misses,
//!   coalesced/shed requests and wire timeouts) + log2 latency histogram
//!   with an exact sum.
//! * [`net`] — the wire front door: a std-only TCP server speaking
//!   length-prefixed JSON frames (`submit`, `kernels`, `stats`, `health`),
//!   with bounded-queue admission control, load shedding with retry
//!   hints, per-connection timeouts and graceful drain.  The protocol is
//!   specified in `docs/wire-protocol.md`.
//!
//! Every admission outcome (submit, reject, backpressure), batch drain and
//! execution also records into the per-kernel/per-shape
//! [`crate::obs::MetricsRegistry`], and sampled requests leave a span
//! waterfall in the [`crate::obs::TraceRecorder`] —
//! [`Coordinator::obs_snapshot`](server::Coordinator::obs_snapshot)
//! exports the whole picture (`repro stats`).

pub mod batcher;
pub mod metrics;
pub mod net;
pub mod router;
pub mod server;

pub use batcher::{Coalescer, PackPlan, Packer};
pub use metrics::{Metrics, MetricsSnapshot};
pub use net::{Client, NetConfig, Server};
pub use router::{Request, Response, Router};
pub use server::{Coordinator, CoordinatorConfig, SubmitError, SubmitOpts};
