//! Lock-free serving metrics: atomic counters + a log2 latency histogram.
//!
//! One `Metrics` instance is the coordinator's global view; the same
//! struct keyed per (kernel, shape) forms the rows of
//! [`crate::obs::MetricsRegistry`].  Every field is a relaxed atomic, so
//! recording never takes a lock and snapshots are cheap copies.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 28; // 1µs .. ~2min in powers of two

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// requests refused by admission control because the queue depth was
    /// at or beyond the shed watermark (the client is told to retry)
    pub shed: AtomicU64,
    /// wire connections closed because a read or write timed out
    pub net_timeouts: AtomicU64,
    pub batched: AtomicU64,
    /// requests served through a coalesced native launch (stacked
    /// same-shape requests, one grid execution)
    pub coalesced: AtomicU64,
    pub executions: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub queue_us_total: AtomicU64,
    /// first-use autotune searches that elected and installed a winner
    pub tuned_plans: AtomicU64,
    /// wall-clock spent in autotune searches
    pub tune_us_total: AtomicU64,
    /// timed candidate executions performed by autotune searches — the
    /// counter the warm-restart CI gate asserts stays 0 against a table
    pub tune_measurements: AtomicU64,
    /// exact sum of observed latencies, so the mean is not bucket-bounded
    latency_us_sum: AtomicU64,
    latency_hist: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record one request latency.  Bucket `i` holds latencies in
    /// `[2^i, 2^(i+1))` µs: `us=1` lands in bucket 0, `us=2..3` in
    /// bucket 1, and so on (values above the last bucket clamp into it).
    pub fn observe_latency_us(&self, us: u64) {
        let bucket = ((63 - us.max(1).leading_zeros()) as usize).min(BUCKETS - 1);
        self.latency_hist[bucket].fetch_add(1, Ordering::Relaxed);
        self.latency_us_sum.fetch_add(us, Ordering::Relaxed);
    }

    /// Copy the counters out.  The plan-cache counters live on
    /// [`crate::exec::PlanCache`], not here — callers pass them in so a
    /// snapshot is never silently zero (`Coordinator::metrics` supplies
    /// the real values; pass `(0, 0)` only when no cache exists).
    pub fn snapshot(&self, plan_hits: u64, plan_misses: u64) -> MetricsSnapshot {
        let hist: Vec<u64> = self
            .latency_hist
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            net_timeouts: self.net_timeouts.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            executions: self.executions.load(Ordering::Relaxed),
            exec_us_total: self.exec_us_total.load(Ordering::Relaxed),
            queue_us_total: self.queue_us_total.load(Ordering::Relaxed),
            tuned_plans: self.tuned_plans.load(Ordering::Relaxed),
            tune_us_total: self.tune_us_total.load(Ordering::Relaxed),
            tune_measurements: self.tune_measurements.load(Ordering::Relaxed),
            plan_hits,
            plan_misses,
            latency_us_sum: self.latency_us_sum.load(Ordering::Relaxed),
            latency_hist: hist,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// requests load-shed at admission (queue depth >= shed watermark)
    pub shed: u64,
    /// wire connections closed on read/write timeout
    pub net_timeouts: u64,
    pub batched: u64,
    pub coalesced: u64,
    pub executions: u64,
    pub exec_us_total: u64,
    pub queue_us_total: u64,
    /// autotune searches that installed a winner
    pub tuned_plans: u64,
    /// wall-clock spent in autotune searches, µs
    pub tune_us_total: u64,
    /// timed candidate executions performed by autotune searches
    pub tune_measurements: u64,
    /// plan-cache counters, supplied by the caller of
    /// [`Metrics::snapshot`] (the cache lives in `exec::PlanCache`)
    pub plan_hits: u64,
    pub plan_misses: u64,
    /// exact sum of observed latencies in µs
    pub latency_us_sum: u64,
    pub latency_hist: Vec<u64>,
}

impl MetricsSnapshot {
    /// An all-zero snapshot, the identity for [`MetricsSnapshot::merge`].
    pub fn empty() -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: 0,
            completed: 0,
            rejected: 0,
            shed: 0,
            net_timeouts: 0,
            batched: 0,
            coalesced: 0,
            executions: 0,
            exec_us_total: 0,
            queue_us_total: 0,
            tuned_plans: 0,
            tune_us_total: 0,
            tune_measurements: 0,
            plan_hits: 0,
            plan_misses: 0,
            latency_us_sum: 0,
            latency_hist: vec![0; BUCKETS],
        }
    }

    /// Add `other`'s counters and histogram into this snapshot — summing
    /// per-kernel rows yields the global view.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        self.submitted += other.submitted;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.net_timeouts += other.net_timeouts;
        self.batched += other.batched;
        self.coalesced += other.coalesced;
        self.executions += other.executions;
        self.exec_us_total += other.exec_us_total;
        self.queue_us_total += other.queue_us_total;
        self.tuned_plans += other.tuned_plans;
        self.tune_us_total += other.tune_us_total;
        self.tune_measurements += other.tune_measurements;
        self.plan_hits += other.plan_hits;
        self.plan_misses += other.plan_misses;
        self.latency_us_sum += other.latency_us_sum;
        if self.latency_hist.len() < other.latency_hist.len() {
            self.latency_hist.resize(other.latency_hist.len(), 0);
        }
        for (mine, theirs) in self.latency_hist.iter_mut().zip(&other.latency_hist) {
            *mine += theirs;
        }
    }

    /// Latency quantile from the log2 histogram, log-linearly
    /// interpolated inside the bucket the quantile falls in (bucket `i`
    /// spans `[2^i, 2^(i+1))` µs).  The old behaviour — returning the
    /// bucket's inclusive upper bound — overstated p50/p99 by up to 2×;
    /// the interpolated estimate assumes samples spread evenly through
    /// the bucket and is clamped to the bucket's true range, so it can
    /// neither under-run the bucket's lower bound nor overshoot its
    /// upper bound.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil().max(1.0);
        let mut seen = 0.0;
        for (i, &count) in self.latency_hist.iter().enumerate() {
            if count == 0 {
                continue;
            }
            let count = count as f64;
            if seen + count >= target {
                let lo = 1u64 << i;
                let hi = (1u64 << (i + 1)) - 1; // inclusive bucket range
                let into = (target - seen) / count; // (0, 1]
                let est = lo as f64 + into * lo as f64;
                return (est.round() as u64).clamp(lo, hi);
            }
            seen += count;
        }
        (1u64 << BUCKETS) - 1
    }

    /// Exact mean latency from the sum counter (not bucket-bounded).
    pub fn mean_latency_us(&self) -> f64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            0.0
        } else {
            self.latency_us_sum as f64 / total as f64
        }
    }

    pub fn mean_exec_us(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.exec_us_total as f64 / self.executions as f64
        }
    }

    pub fn mean_queue_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_us_total as f64 / self.completed as f64
        }
    }

    pub fn batching_factor(&self) -> f64 {
        if self.executions == 0 {
            1.0
        } else {
            self.completed as f64 / self.executions as f64
        }
    }

    pub fn render(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} shed={} net_timeouts={} executions={} \
             batching={:.2}x coalesced={} plan_cache={}h/{}m tuned={} tune_ms={:.1} \
             mean_exec={:.0}µs mean_queue={:.0}µs mean={:.0}µs p50={}µs p99={}µs",
            self.submitted,
            self.completed,
            self.rejected,
            self.shed,
            self.net_timeouts,
            self.executions,
            self.batching_factor(),
            self.coalesced,
            self.plan_hits,
            self.plan_misses,
            self.tuned_plans,
            self.tune_us_total as f64 / 1000.0,
            self.mean_exec_us(),
            self.mean_queue_us(),
            self.mean_latency_us(),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for us in [1u64, 2, 4, 8, 1024, 2048] {
            m.observe_latency_us(us);
        }
        let s = m.snapshot(0, 0);
        assert!(s.latency_quantile_us(0.5) <= 16);
        assert!(s.latency_quantile_us(1.0) >= 2048);
    }

    #[test]
    fn bucket_zero_is_reachable() {
        let m = Metrics::new();
        m.observe_latency_us(1);
        let s = m.snapshot(0, 0);
        assert_eq!(s.latency_hist[0], 1, "us=1 must land in bucket 0");
        assert_eq!(s.latency_quantile_us(1.0), 1, "bucket 0 upper bound is 1µs");
        // bucket boundaries: 2 and 3 share bucket 1, 4 starts bucket 2
        m.observe_latency_us(2);
        m.observe_latency_us(3);
        m.observe_latency_us(4);
        let s = m.snapshot(0, 0);
        assert_eq!(s.latency_hist[1], 2);
        assert_eq!(s.latency_hist[2], 1);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        // 800 samples of 100µs all land in bucket 6 ([64, 128)).  The
        // old upper-bound estimator returned 127 for every quantile —
        // interpolation spreads the estimates through the bucket.
        let m = Metrics::new();
        for _ in 0..800 {
            m.observe_latency_us(100);
        }
        let s = m.snapshot(0, 0);
        assert_eq!(s.latency_quantile_us(0.25), 80);
        assert_eq!(s.latency_quantile_us(0.5), 96);
        assert_eq!(s.latency_quantile_us(0.99), 127);
        // estimates never leave the bucket's [lo, hi] range
        assert_eq!(s.latency_quantile_us(1e-9), 64);
        assert_eq!(s.latency_quantile_us(1.0), 127);
    }

    #[test]
    fn mean_latency_is_exact() {
        let m = Metrics::new();
        for us in [100u64, 200, 600] {
            m.observe_latency_us(us);
        }
        let s = m.snapshot(0, 0);
        assert_eq!(s.latency_us_sum, 900);
        assert!((s.mean_latency_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_carries_plan_counters() {
        let m = Metrics::new();
        let s = m.snapshot(7, 3);
        assert_eq!((s.plan_hits, s.plan_misses), (7, 3));
        assert!(s.render().contains("7h/3m"));
    }

    #[test]
    fn merge_sums_counters_and_histograms() {
        let a = Metrics::new();
        a.submitted.store(2, Ordering::Relaxed);
        a.shed.store(4, Ordering::Relaxed);
        a.net_timeouts.store(1, Ordering::Relaxed);
        a.observe_latency_us(1);
        let b = Metrics::new();
        b.submitted.store(3, Ordering::Relaxed);
        b.shed.store(1, Ordering::Relaxed);
        b.observe_latency_us(1);
        b.observe_latency_us(1000);
        let mut total = MetricsSnapshot::empty();
        total.merge(&a.snapshot(1, 0));
        total.merge(&b.snapshot(0, 2));
        assert_eq!(total.submitted, 5);
        assert_eq!((total.shed, total.net_timeouts), (5, 1));
        assert_eq!((total.plan_hits, total.plan_misses), (1, 2));
        assert_eq!(total.latency_hist[0], 2);
        assert_eq!(total.latency_us_sum, 1002);
    }

    #[test]
    fn batching_factor() {
        let m = Metrics::new();
        m.completed.store(10, Ordering::Relaxed);
        m.executions.store(4, Ordering::Relaxed);
        assert!((m.snapshot(0, 0).batching_factor() - 2.5).abs() < 1e-9);
    }
}
