//! Lock-free serving metrics: atomic counters + a log2 latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 28; // 1µs .. ~2min in powers of two

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batched: AtomicU64,
    /// requests served through a coalesced native launch (stacked
    /// same-shape requests, one grid execution)
    pub coalesced: AtomicU64,
    pub executions: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub queue_us_total: AtomicU64,
    latency_hist: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_latency_us(&self, us: u64) {
        let bucket = (64 - us.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.latency_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let hist: Vec<u64> = self
            .latency_hist
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batched: self.batched.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            executions: self.executions.load(Ordering::Relaxed),
            exec_us_total: self.exec_us_total.load(Ordering::Relaxed),
            queue_us_total: self.queue_us_total.load(Ordering::Relaxed),
            plan_hits: 0,
            plan_misses: 0,
            latency_hist: hist,
        }
    }
}

#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub batched: u64,
    pub coalesced: u64,
    pub executions: u64,
    pub exec_us_total: u64,
    pub queue_us_total: u64,
    /// plan-cache counters (filled in by `Coordinator::metrics`, which
    /// owns the shared `exec::PlanCache`; zero for a bare snapshot)
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub latency_hist: Vec<u64>,
}

impl MetricsSnapshot {
    /// Latency quantile from the log2 histogram (upper bucket bound).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 = self.latency_hist.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, count) in self.latency_hist.iter().enumerate() {
            seen += count;
            if seen >= target {
                return 1u64 << i;
            }
        }
        1u64 << (BUCKETS - 1)
    }

    pub fn mean_exec_us(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.exec_us_total as f64 / self.executions as f64
        }
    }

    pub fn mean_queue_us(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.queue_us_total as f64 / self.completed as f64
        }
    }

    pub fn batching_factor(&self) -> f64 {
        if self.executions == 0 {
            1.0
        } else {
            self.completed as f64 / self.executions as f64
        }
    }

    pub fn render(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} executions={} batching={:.2}x \
             coalesced={} plan_cache={}h/{}m mean_exec={:.0}µs mean_queue={:.0}µs \
             p50={}µs p99={}µs",
            self.submitted,
            self.completed,
            self.rejected,
            self.executions,
            self.batching_factor(),
            self.coalesced,
            self.plan_hits,
            self.plan_misses,
            self.mean_exec_us(),
            self.mean_queue_us(),
            self.latency_quantile_us(0.5),
            self.latency_quantile_us(0.99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles() {
        let m = Metrics::new();
        for us in [1u64, 2, 4, 8, 1024, 2048] {
            m.observe_latency_us(us);
        }
        let s = m.snapshot();
        assert!(s.latency_quantile_us(0.5) <= 16);
        assert!(s.latency_quantile_us(1.0) >= 2048);
    }

    #[test]
    fn batching_factor() {
        let m = Metrics::new();
        m.completed.store(10, Ordering::Relaxed);
        m.executions.store(4, Ordering::Relaxed);
        assert!((m.snapshot().batching_factor() - 2.5).abs() < 1e-9);
    }
}
