//! Arrangement metadata: loading, validation, launch planning.
//!
//! Two sources of truth meet here:
//!
//! 1. the **manifest** — the arrangement metadata (levels + index
//!    expressions per parameter) the Python DSL exported at AOT time, plus
//!    golden expression evaluations;
//! 2. the **catalog** — the same arrangements re-derived in Rust through
//!    `crate::tensor` (paper Listings 3/5/8 re-expressed against the
//!    mirror).
//!
//! The coordinator validates both against each other and computes launch
//! plans (grid + padded extents) used for request admission and the
//! VMEM/roofline estimates in the benchmark reports.

pub mod catalog;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::json::Json;
use crate::symbolic::{parse, Expr};

/// One parameter of one arrangement, as exported by `Kernel.export_metadata`.
#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub source_ndim: usize,
    pub is_output: bool,
    /// level sizes + variable names
    pub levels: Vec<Vec<(Expr, String)>>,
    /// source-to-target mapping (one expr per source dim)
    pub indices: Vec<Expr>,
    pub pad_value: f64,
}

#[derive(Debug, Clone)]
pub struct ArrangementMeta {
    pub kernel: String,
    pub params: Vec<ParamMeta>,
    pub goldens: Vec<Golden>,
}

#[derive(Debug, Clone)]
pub struct Golden {
    pub expr: String,
    pub env: BTreeMap<String, i64>,
    pub value: i64,
}

impl ArrangementMeta {
    pub fn from_json(v: &Json) -> Result<ArrangementMeta> {
        let kernel = v.str("kernel")?.to_string();
        let mut params = Vec::new();
        for p in v.arr("params")? {
            let mut levels = Vec::new();
            for level in p.arr("levels")? {
                let mut dims = Vec::new();
                for d in level.as_arr().context("level is not an array")? {
                    dims.push((
                        parse(d.str("size")?).with_context(|| format!("size in {kernel}"))?,
                        d.str("var")?.to_string(),
                    ));
                }
                levels.push(dims);
            }
            let indices = p
                .arr("indices")?
                .iter()
                .map(|e| {
                    parse(e.as_str().context("index expr not a string")?)
                        .map_err(anyhow::Error::from)
                })
                .collect::<Result<Vec<_>>>()?;
            params.push(ParamMeta {
                name: p.str("name")?.to_string(),
                source_ndim: p.usize("source_ndim")?,
                is_output: p.req("is_output")?.as_bool().unwrap_or(false),
                levels,
                indices,
                pad_value: p.f64("pad_value").unwrap_or(0.0),
            });
        }
        let mut goldens = Vec::new();
        for g in v.get("goldens").and_then(|g| g.as_arr()).unwrap_or(&[]) {
            let mut env = BTreeMap::new();
            if let Some(Json::Obj(m)) = g.get("env") {
                for (k, val) in m {
                    env.insert(
                        k.clone(),
                        val.as_i64().context("golden env value not an int")?,
                    );
                }
            }
            goldens.push(Golden {
                expr: g.str("expr")?.to_string(),
                env,
                value: g.req("value")?.as_i64().context("golden value")?,
            });
        }
        Ok(ArrangementMeta { kernel, params, goldens })
    }

    /// The §3.2.1 correctness principle: all non-scalar parameters'
    /// outermost levels must have the same rank (sizes are checked
    /// numerically per launch in [`ArrangementMeta::launch_plan`]).
    pub fn validate_structure(&self) -> Result<()> {
        let ranks: Vec<usize> = self
            .params
            .iter()
            .filter(|p| p.source_ndim > 0)
            .map(|p| p.levels[0].len())
            .collect();
        if let Some(first) = ranks.first() {
            if ranks.iter().any(|r| r != first) {
                bail!(
                    "kernel {}: outermost-level ranks disagree: {ranks:?} (paper §3.2.1)",
                    self.kernel
                );
            }
        }
        for p in &self.params {
            if p.indices.len() != p.source_ndim {
                bail!(
                    "kernel {}: parameter {} has {} index exprs for {} source dims",
                    self.kernel,
                    p.name,
                    p.indices.len(),
                    p.source_ndim
                );
            }
        }
        Ok(())
    }

    /// Replay the golden expression evaluations exported by Python —
    /// bit-for-bit agreement check between the two algebra implementations.
    pub fn check_goldens(&self) -> Result<usize> {
        for g in &self.goldens {
            let expr = parse(&g.expr).with_context(|| format!("golden expr {:?}", g.expr))?;
            let value = expr
                .eval(&g.env)
                .with_context(|| format!("golden eval {:?}", g.expr))?;
            if value != g.value {
                bail!(
                    "kernel {}: golden mismatch for {:?}: rust={} python={}",
                    self.kernel,
                    g.expr,
                    value,
                    g.value
                );
            }
        }
        Ok(self.goldens.len())
    }

    /// Compute the launch plan for concrete shape/meta bindings.
    pub fn launch_plan(&self, bindings: &BTreeMap<String, i64>) -> Result<LaunchPlan> {
        let mut grid: Option<Vec<i64>> = None;
        let mut params = Vec::new();
        for p in &self.params {
            // per-variable ranges from concrete level sizes
            let mut ranges: BTreeMap<String, (i64, i64)> = bindings
                .iter()
                .map(|(k, v)| (k.clone(), (*v, *v)))
                .collect();
            let mut level_shapes = Vec::new();
            for level in &p.levels {
                let mut shape = Vec::new();
                for (size, var) in level {
                    let s = size.substitute_consts(bindings).eval(bindings).with_context(
                        || format!("kernel {} param {} size {size}", self.kernel, p.name),
                    )?;
                    ranges.insert(var.clone(), (0, (s - 1).max(0)));
                    shape.push(s);
                }
                level_shapes.push(shape);
            }
            if p.source_ndim > 0 {
                let g = level_shapes[0].clone();
                match &grid {
                    None => grid = Some(g),
                    Some(prev) if *prev != g => bail!(
                        "kernel {}: outermost-level shapes disagree: {prev:?} vs {g:?} \
                         — the arrangement is invalid (paper §3.2.1)",
                        self.kernel
                    ),
                    _ => {}
                }
            }
            let mut extents = Vec::new();
            for e in &p.indices {
                let spec = e.substitute_consts(bindings);
                let hi = match spec.constant() {
                    Some(c) => c,
                    None => spec.bounds(&ranges)?.1,
                };
                extents.push(hi + 1);
            }
            params.push(ParamPlan {
                name: p.name.clone(),
                is_output: p.is_output,
                block_shape: level_shapes.last().cloned().unwrap_or_default(),
                padded_extents: extents,
            });
        }
        let grid = grid.unwrap_or_else(|| vec![1]);
        Ok(LaunchPlan { programs: grid.iter().product::<i64>().max(1), grid, params })
    }
}

/// Concrete launch geometry for one specialization.
#[derive(Debug, Clone)]
pub struct LaunchPlan {
    pub grid: Vec<i64>,
    pub programs: i64,
    pub params: Vec<ParamPlan>,
}

#[derive(Debug, Clone)]
pub struct ParamPlan {
    pub name: String,
    pub is_output: bool,
    pub block_shape: Vec<i64>,
    pub padded_extents: Vec<i64>,
}

impl LaunchPlan {
    /// Bytes of tile data one program touches (f32) — the VMEM-footprint
    /// estimate used in the §Perf real-TPU discussion.
    pub fn vmem_bytes_per_program(&self) -> i64 {
        self.params
            .iter()
            .map(|p| p.block_shape.iter().product::<i64>().max(1) * 4)
            .sum()
    }
}

/// Load every arrangement in the manifest.
pub fn load_all(manifest: &Json) -> Result<Vec<ArrangementMeta>> {
    manifest
        .arr("arrangements")?
        .iter()
        .map(ArrangementMeta::from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;

    fn meta_from(json: &str) -> ArrangementMeta {
        ArrangementMeta::from_json(&Json::parse(json).unwrap()).unwrap()
    }

    const ADD_META: &str = r#"{
        "kernel": "add",
        "params": [
            {"name": "input", "source_ndim": 1, "is_output": false,
             "levels": [[{"size": "cdiv(n, B)", "var": "o"}], [{"size": "B", "var": "t"}]],
             "indices": ["o * B + t"], "pad_value": 0.0},
            {"name": "output", "source_ndim": 1, "is_output": true,
             "levels": [[{"size": "cdiv(n, B)", "var": "p"}], [{"size": "B", "var": "u"}]],
             "indices": ["p * B + u"], "pad_value": 0.0}
        ],
        "goldens": [
            {"expr": "o * B + t", "env": {"o": 3, "B": 16, "t": 5}, "value": 53}
        ]
    }"#;

    fn env(pairs: &[(&str, i64)]) -> std::collections::BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn parses_and_validates() {
        let meta = meta_from(ADD_META);
        meta.validate_structure().unwrap();
        assert_eq!(meta.check_goldens().unwrap(), 1);
    }

    #[test]
    fn launch_plan_geometry() {
        let meta = meta_from(ADD_META);
        let plan = meta.launch_plan(&env(&[("n", 100), ("B", 16)])).unwrap();
        assert_eq!(plan.grid, vec![7]);
        assert_eq!(plan.programs, 7);
        assert_eq!(plan.params[0].padded_extents, vec![112]);
        assert!(plan.params[1].is_output);
        assert_eq!(plan.vmem_bytes_per_program(), 2 * 16 * 4);
    }

    #[test]
    fn grid_disagreement_detected() {
        // second param tiled with a different block: grids diverge
        let bad = ADD_META.replace("p * B + u", "p * C + u").replace(
            r#"[[{"size": "cdiv(n, B)", "var": "p"}], [{"size": "B", "var": "u"}]]"#,
            r#"[[{"size": "cdiv(n, C)", "var": "p"}], [{"size": "C", "var": "u"}]]"#,
        );
        let meta = meta_from(&bad);
        let err = meta
            .launch_plan(&env(&[("n", 100), ("B", 16), ("C", 32)]))
            .unwrap_err();
        assert!(err.to_string().contains("3.2.1"), "{err}");
    }

    #[test]
    fn golden_mismatch_detected() {
        let bad = ADD_META.replace("\"value\": 53", "\"value\": 54");
        let meta = meta_from(&bad);
        assert!(meta.check_goldens().is_err());
    }

    #[test]
    fn rank_mismatch_detected() {
        let bad = ADD_META.replace(
            r#"[[{"size": "cdiv(n, B)", "var": "p"}], [{"size": "B", "var": "u"}]]"#,
            r#"[[{"size": "cdiv(n, B)", "var": "p"}, {"size": "1", "var": "q"}], [{"size": "B", "var": "u"}]]"#,
        );
        let meta = meta_from(&bad);
        assert!(meta.validate_structure().is_err());
    }
}
