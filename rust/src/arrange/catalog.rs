//! The paper's arrangements re-derived in Rust against the tensor mirror.
//!
//! These are the Rust renderings of paper Listings 3 (add), 5 (mm) and 8
//! (conv2d), plus the remaining evaluation kernels.  They serve as an
//! executable cross-check that the two algebra implementations (Python DSL
//! and Rust mirror) derive identical launch geometry — `cargo test` compares
//! grids and padded extents against the manifest metadata.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::symbolic::Expr;
use crate::tensor::SymTensor;

fn c(v: i64) -> Option<Expr> {
    Some(Expr::Const(v))
}

fn s(name: &str) -> Option<Expr> {
    Some(Expr::sym(name))
}

/// 1-D element-wise arrangement: every parameter tiled by BLOCK_SIZE
/// (paper Listing 3 generalized to any parameter list).
pub fn elementwise_1d(names: &[&str]) -> Result<Vec<SymTensor>> {
    names
        .iter()
        .map(|name| SymTensor::new(name, 1).tile(&[s("BLOCK_SIZE")], None))
        .collect()
}

/// Vector addition (paper Listing 3): each tensor tiled by BLOCK_SIZE.
pub fn add() -> Result<Vec<SymTensor>> {
    elementwise_1d(&["input", "other", "output"])
}

/// Matrix multiplication (paper Listing 5).
pub fn mm() -> Result<Vec<SymTensor>> {
    let input = SymTensor::new("input", 2);
    let other = SymTensor::new("other", 2);
    let output = SymTensor::new("output", 2);

    let output_arranged = output.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_N")], None)?;
    let out_shape = output_arranged.shape();

    let mut input_arranged = input.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_K")], None)?;
    input_arranged = input_arranged.tile(&[c(1), None], None)?;
    input_arranged = input_arranged.expand(&[None, Some(out_shape[1].clone())])?;
    let v = input_arranged.dtype().squeeze(&[0])?;
    input_arranged.set_dtype(v);

    let mut other_arranged = other.tile(&[s("BLOCK_SIZE_K"), s("BLOCK_SIZE_N")], None)?;
    other_arranged = other_arranged.tile(&[None, c(1)], None)?;
    other_arranged = other_arranged.expand(&[Some(out_shape[0].clone()), None])?;
    let v = other_arranged.dtype().squeeze(&[1])?;
    other_arranged.set_dtype(v);

    Ok(vec![input_arranged, other_arranged, output_arranged])
}

/// addmm (paper task 2): the mm arrangement plus a broadcast bias
/// epilogue.  The bias is always arranged rank-2 (`[1, n]` for rank-1 /
/// row-broadcast biases): with `row_bias` it is tiled `[1, BLOCK_SIZE_N]`
/// and its row-grid dimension expanded across the output's row grid —
/// every row of output tiles re-reads the same bias tile; otherwise it is
/// tiled exactly like the output.  Returned order: `[bias, input, other,
/// output]` (torch.addmm argument order, output last).
pub fn addmm(row_bias: bool) -> Result<Vec<SymTensor>> {
    let mm_tensors = mm()?;
    let out_shape = mm_tensors[2].shape();
    let bias = SymTensor::new("bias", 2);
    let bias_arranged = if row_bias {
        let tiled = bias.tile(&[c(1), s("BLOCK_SIZE_N")], None)?;
        tiled.expand(&[Some(out_shape[0].clone()), None])?
    } else {
        bias.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_N")], None)?
    };
    let mut tensors = vec![bias_arranged];
    tensors.extend(mm_tensors);
    Ok(tensors)
}

/// 2D convolution via implicit GEMM (paper Listing 8): meta-operations map
/// NCHW convolution onto the mm arrangement.
pub fn conv2d() -> Result<Vec<SymTensor>> {
    let input = SymTensor::new("input", 4);
    let filter = SymTensor::new("filter", 4);
    let output = SymTensor::new("output", 4);

    let f_shape = filter.shape();

    let mut input_arranged = input.tile(
        &[
            c(1),
            Some(f_shape[1].clone()),
            Some(f_shape[2].clone()),
            Some(f_shape[3].clone()),
        ],
        Some(&[None, None, c(1), c(1)]),
    )?;
    input_arranged = input_arranged.squeeze(&[1])?;
    let v = input_arranged.dtype().squeeze(&[0])?;
    input_arranged.set_dtype(v);
    input_arranged = input_arranged.ravel();
    input_arranged = input_arranged.flatten(0, Some(3))?.flatten(1, None)?;

    let filter_arranged = filter.flatten(1, None)?.permute(&[1, 0])?;
    let output_arranged = output.permute(&[0, 2, 3, 1])?.flatten(0, Some(3))?;

    // now the mm arrangement over the flattened views
    let out2 = output_arranged.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_N")], None)?;
    let out_shape = out2.shape();

    let mut in2 = input_arranged.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_K")], None)?;
    in2 = in2.tile(&[c(1), None], None)?;
    in2 = in2.expand(&[None, Some(out_shape[1].clone())])?;
    let v = in2.dtype().squeeze(&[0])?;
    in2.set_dtype(v);

    let mut fl2 = filter_arranged.tile(&[s("BLOCK_SIZE_K"), s("BLOCK_SIZE_N")], None)?;
    fl2 = fl2.tile(&[None, c(1)], None)?;
    fl2 = fl2.expand(&[Some(out_shape[0].clone()), None])?;
    let v = fl2.dtype().squeeze(&[1])?;
    fl2.set_dtype(v);

    Ok(vec![in2, fl2, out2])
}

/// Batched matrix multiplication (paper task 3): the mm arrangement with a
/// leading batch grid dimension (mirrors `python/compile/kernels/nt/bmm.py`).
pub fn bmm() -> Result<Vec<SymTensor>> {
    let input = SymTensor::new("input", 3);
    let other = SymTensor::new("other", 3);
    let output = SymTensor::new("output", 3);

    let mut output_arranged =
        output.tile(&[c(1), s("BLOCK_SIZE_M"), s("BLOCK_SIZE_N")], None)?;
    let v = output_arranged.dtype().squeeze(&[0])?;
    output_arranged.set_dtype(v);
    let out_shape = output_arranged.shape();

    let mut input_arranged = input.tile(&[c(1), s("BLOCK_SIZE_M"), s("BLOCK_SIZE_K")], None)?;
    let v = input_arranged.dtype().squeeze(&[0])?;
    input_arranged.set_dtype(v);
    input_arranged = input_arranged.tile(&[c(1), c(1), None], None)?;
    input_arranged = input_arranged.expand(&[None, None, Some(out_shape[2].clone())])?;
    let v = input_arranged.dtype().squeeze(&[0, 1])?;
    input_arranged.set_dtype(v);

    let mut other_arranged = other.tile(&[c(1), s("BLOCK_SIZE_K"), s("BLOCK_SIZE_N")], None)?;
    let v = other_arranged.dtype().squeeze(&[0])?;
    other_arranged.set_dtype(v);
    other_arranged = other_arranged.tile(&[c(1), None, c(1)], None)?;
    other_arranged = other_arranged.expand(&[None, Some(out_shape[1].clone()), None])?;
    let v = other_arranged.dtype().squeeze(&[0, 2])?;
    other_arranged.set_dtype(v);

    Ok(vec![input_arranged, other_arranged, output_arranged])
}

/// Row-wise kernels (softmax / rms_norm): one program per row.
pub fn rowwise() -> Result<Vec<SymTensor>> {
    let mut out = Vec::new();
    for name in ["input", "output"] {
        out.push(SymTensor::new(name, 2).tile(&[c(1), None], None)?);
    }
    Ok(out)
}

/// FlashAttention-2-style sdpa (paper task 8; mirrors
/// `python/compile/kernels/nt/sdpa.py` / `sdpa_bias.py`).
///
/// Each program owns one `[BLOCK_SIZE_M, d]` query row-block; the
/// key/value `[BLOCK_SIZE_N, d]` column-blocks are grouped into the
/// per-program loop level the application's online softmax iterates —
/// the canonical loop-carried tiled computation.  With `with_bias`, an
/// `[s, s]` additive score-bias tensor is arranged exactly like mm's
/// input — tiled `[BLOCK_SIZE_M, BLOCK_SIZE_N]`, its column-blocks
/// grouped into the same loop level, and broadcast over batch and heads
/// with `unsqueeze` + `expand` — expressing causal masking (and any
/// other attention mask) through the arrangement algebra rather than a
/// bespoke kernel.  Returned order: `[query, key, value, (bias,) output]`.
pub fn sdpa(with_bias: bool) -> Result<Vec<SymTensor>> {
    let query = SymTensor::new("query", 4);
    let key = SymTensor::new("key", 4);
    let value = SymTensor::new("value", 4);
    let output = SymTensor::new("output", 4);

    let mut q = query.tile(&[c(1), c(1), s("BLOCK_SIZE_M"), None], None)?;
    let v_ = q.dtype().squeeze(&[0, 1])?;
    q.set_dtype(v_);
    let q_shape = q.shape();

    let arrange_kv = |t: SymTensor| -> Result<SymTensor> {
        let mut a = t.tile(&[c(1), c(1), s("BLOCK_SIZE_N"), None], None)?;
        let v_ = a.dtype().squeeze(&[0, 1])?;
        a.set_dtype(v_);
        a = a.tile(&[c(1), c(1), None, c(1)], None)?;
        a = a.expand(&[None, None, Some(q_shape[2].clone()), None])?;
        let v_ = a.dtype().squeeze(&[0, 1, 3])?;
        a.set_dtype(v_);
        Ok(a)
    };

    let k = arrange_kv(key)?;
    let v2 = arrange_kv(value)?;
    let mut o = output.tile(&[c(1), c(1), s("BLOCK_SIZE_M"), None], None)?;
    let v_ = o.dtype().squeeze(&[0, 1])?;
    o.set_dtype(v_);

    let mut tensors = vec![q, k, v2];
    if with_bias {
        let bias = SymTensor::new("bias", 2);
        let mut b = bias.tile(&[s("BLOCK_SIZE_M"), s("BLOCK_SIZE_N")], None)?;
        b = b.tile(&[c(1), None], None)?;
        let v_ = b.dtype().squeeze(&[0])?;
        b.set_dtype(v_);
        b = b.unsqueeze(0)?;
        b = b.unsqueeze(0)?;
        b = b.expand(&[Some(q_shape[0].clone()), Some(q_shape[1].clone()), None, None])?;
        tensors.push(b);
    }
    tensors.push(o);
    Ok(tensors)
}

/// Rotary position embedding (paper task 7, half-rotation convention;
/// mirrors `python/compile/kernels/nt/rope.py`).  `input`/`output` are
/// `[B, S, H, D]`, one program per `(batch, seq, head)` row; the cos/sin
/// tables are `[S, D/2]`, broadcast over batch and heads by
/// `unsqueeze` + `expand` exactly as the Python arrangement does.
pub fn rope() -> Result<Vec<SymTensor>> {
    let input = SymTensor::new("input", 4);
    let cos = SymTensor::new("cos", 2);
    let sin = SymTensor::new("sin", 2);
    let output = SymTensor::new("output", 4);

    let arrange_rows = |t: SymTensor| -> Result<SymTensor> {
        let mut a = t.tile(&[c(1), c(1), c(1), None], None)?;
        let v = a.dtype().squeeze(&[0, 1, 2])?;
        a.set_dtype(v);
        Ok(a)
    };
    let input_arranged = arrange_rows(input)?;
    let in_shape = input_arranged.shape(); // [B, S, H, 1]

    let arrange_table = |t: SymTensor| -> Result<SymTensor> {
        let mut a = t.tile(&[c(1), None], None)?;
        a = a.unsqueeze(0)?;
        a = a.unsqueeze(2)?;
        a = a.expand(&[Some(in_shape[0].clone()), None, Some(in_shape[2].clone()), None])?;
        let v = a.dtype().squeeze(&[0])?;
        a.set_dtype(v);
        Ok(a)
    };
    let cos_arranged = arrange_table(cos)?;
    let sin_arranged = arrange_table(sin)?;
    let output_arranged = arrange_rows(output)?;
    Ok(vec![input_arranged, cos_arranged, sin_arranged, output_arranged])
}

/// Grid / extent agreement check between a catalog arrangement and the
/// manifest metadata, under concrete bindings.  Variable names differ
/// between the two derivations, so agreement is judged on evaluated
/// geometry: grid and padded extents.
pub fn geometry(
    tensors: &[SymTensor],
    bindings: &BTreeMap<String, i64>,
) -> Result<(Vec<i64>, Vec<Vec<i64>>)> {
    let mut grid = Vec::new();
    let mut extents = Vec::new();
    for (i, t) in tensors.iter().enumerate() {
        let g = t.grid(bindings)?;
        if i == 0 {
            grid = g;
        }
        extents.push(t.padded_extents(bindings)?);
    }
    Ok((grid, extents))
}
