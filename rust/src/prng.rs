//! SplitMix64 PRNG — deterministic, dependency-free randomness for the
//! property tests, workload generators and benchmark harness (the offline
//! crate set has no `rand`).

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// A vector of standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = SplitMix64::new(7);
        let xs = rng.normal_vec(20_000);
        let mean = xs.iter().map(|x| *x as f64).sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_in_range() {
        let mut rng = SplitMix64::new(3);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }
}
