//! Dependency-free CLI argument parsing (no clap in the offline crate set).

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, `--key value` /
/// `--flag` options.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Args {
        let mut args = Args::default();
        let raw: Vec<String> = raw.collect();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    args.options.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a.clone());
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Strict variant of [`Args::opt_usize`] for startup-validated knobs:
    /// `Ok(None)` when absent, a clean error (instead of a silent default)
    /// when the value is not a positive integer.
    pub fn opt_positive(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.opt(name) {
            None => Ok(None),
            Some(raw) => parse_positive(raw).map(Some).ok_or_else(|| {
                anyhow::anyhow!("--{name} must be a positive integer, got {raw:?}")
            }),
        }
    }
}

/// Strict positive-integer parse — the one rule shared by CLI flags
/// ([`Args::opt_positive`]) and env knobs (`exec::pool::parse_env_usize`).
pub fn parse_positive(raw: &str) -> Option<usize> {
    raw.trim().parse::<usize>().ok().filter(|&v| v > 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse(&["serve", "--workers", "4", "extra", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.opt("workers"), Some("4"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["bench", "--iters=12"]);
        assert_eq!(a.opt_usize("iters", 0), 12);
    }

    #[test]
    fn opt_positive_is_strict() {
        let a = parse(&["serve", "--pool-threads", "4", "--coalesce-fanin", "zero"]);
        assert_eq!(a.opt_positive("pool-threads").unwrap(), Some(4));
        assert_eq!(a.opt_positive("absent").unwrap(), None);
        let err = a.opt_positive("coalesce-fanin").unwrap_err();
        assert!(format!("{err:#}").contains("positive integer"));
        let zero = parse(&["serve", "--plan-cache-cap", "0"]);
        assert!(zero.opt_positive("plan-cache-cap").is_err());
    }
}
