//! `kernel::make` — the first-class kernel-definition API.
//!
//! The paper's core contribution is the **arrange-and-apply** paradigm: a
//! kernel is *declared* by composing an [`Arrangement`] (tiling geometry,
//! §3.2), an application (per-tile compute, §3.3) and symbolic tensors,
//! and `ninetoothed.make` derives everything else.  This module is the
//! Rust rendering of that API: [`make`] takes
//!
//! 1. an [`Arrangement`] — a composable function over symbolic tensors
//!    (the `arrange::catalog` entries rehomed as values of this type),
//!    plus its meta-parameter policy ([`Meta`]: block-size choices);
//! 2. an application — a serial tile program authored through the typed
//!    [`AppBuilder`] over `exec::ir` (loads/stores/dot/reductions/
//!    element-wise ops, written as if for one tile), including
//!    **loop-carried reductions** via [`AppBuilder::loop_over`]: declared
//!    carry registers persist across the arrangement's sub-tile loop,
//!    which is what lets flash-style sdpa express its online softmax
//!    (running max, running denominator, rescaled accumulator) serially;
//! 3. the kernel's [`TensorSpec`]s — each parameter's symbolic shape,
//!    role (input/output) and pad value;
//!
//! and derives the whole serving contract that used to be hand-written
//! per kernel in `exec/native.rs`:
//!
//! * **arity** and **shape preconditions** — by unifying the declared
//!   size symbols against request shapes (conflicting bindings, rank
//!   mismatches and failed [`DimSpec::Expr`] checks reject at admission);
//! * **output shape inference** — output dims evaluated under the
//!   unified bindings (callers never pass output tensors);
//! * the **per-shape specializer** consumed by `exec::compile` — meta
//!   bindings from the arrangement's [`Meta`] policy, size bindings from
//!   the request, then `ParamView` lowering with §3.2.1 agreement checks;
//! * the **coalescibility flag** — row-independence *detected from the
//!   arrangement* (see `KernelDef::coalesce`), not asserted by hand.
//!
//! Definitions register in the global [`KernelRegistry`] (name →
//! `Arc<KernelDef>`, hash lookup), which the runtime registry, the plan
//! cache, the batcher's coalescer and the coordinator all resolve
//! through — a kernel registered at startup flows through compile /
//! cache / coalesce / serving with zero additional wiring.  The builtin
//! catalog (and `rope`, which is defined *only* through this API) lives
//! in [`builtins`].

pub mod builtins;
pub mod verify;

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{anyhow, bail, Context, Result};

use crate::exec::ir::{Instr, TileProgram};
use crate::exec::scheduler::GridScheduler;
use crate::exec::tile::{BinOp, ReduceOp, UnaryOp};
use crate::exec::view::ParamView;
use crate::runtime::HostTensor;
use crate::symbolic::Expr;
use crate::tensor::SymTensor;

/// Concrete values for a kernel's size symbols, produced by unification.
pub type DimBindings = BTreeMap<String, i64>;

/// A fully specialized launch: concrete views + output shapes (what the
/// compile stage caches per shape signature).
pub struct Specialization {
    /// outermost-level (grid) shape, identical across parameters
    pub grid: Vec<i64>,
    /// flattened middle-level (loop) shape shared by looped parameters
    pub loop_shape: Vec<usize>,
    /// one lowered view per parameter, in declaration order
    pub views: Vec<ParamView>,
    /// inferred concrete shapes of the output parameters
    pub output_shapes: Vec<Vec<usize>>,
}

impl Specialization {
    /// Number of program instances one launch runs.
    pub fn programs(&self) -> i64 {
        self.grid.iter().product::<i64>().max(1)
    }
}

/// One dimension of a kernel parameter's symbolic shape.
#[derive(Debug, Clone)]
pub enum DimSpec {
    /// A size symbol, bound by unification against request shapes.  The
    /// `probe` value is used for the registration-time structural
    /// analyses (lowerability, row-independence) and must satisfy the
    /// kernel's constraints.
    Sym {
        /// symbol name, e.g. `"m"`
        name: &'static str,
        /// representative size for registration-time probing
        probe: i64,
    },
    /// A derived size: an expression over previously declared symbols
    /// (checked on inputs, inferred on outputs) — e.g. rope's cos table
    /// is `[s, d // 2]`.
    Expr(Expr),
}

/// A size symbol with a probe value — shorthand for [`DimSpec::Sym`].
pub fn dim(name: &'static str, probe: i64) -> DimSpec {
    DimSpec::Sym { name, probe }
}

/// A derived size — shorthand for [`DimSpec::Expr`].
pub fn derived(expr: Expr) -> DimSpec {
    DimSpec::Expr(expr)
}

impl DimSpec {
    fn eval(&self, dims: &DimBindings) -> Result<i64> {
        match self {
            DimSpec::Sym { name, .. } => dims
                .get(*name)
                .copied()
                .ok_or_else(|| anyhow!("size symbol {name} is unbound")),
            DimSpec::Expr(e) => Ok(e.eval(dims)?),
        }
    }
}

impl std::fmt::Display for DimSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DimSpec::Sym { name, .. } => write!(f, "{name}"),
            DimSpec::Expr(e) => write!(f, "{e}"),
        }
    }
}

/// One kernel parameter: symbolic shape, role, and pad value.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// parameter name — must match the arrangement's `SymTensor` name
    pub name: &'static str,
    /// symbolic shape, one [`DimSpec`] per dimension
    pub dims: Vec<DimSpec>,
    /// outputs are allocated by the executor, never passed by callers
    pub is_output: bool,
    /// value out-of-range (padded) reads observe
    pub pad: f32,
    /// accept rank `n-1` inputs by implying a leading size-1 dim
    /// (addmm's rank-1 bias broadcasting as `[1, n]`)
    pub implied_leading: bool,
}

impl TensorSpec {
    /// An input parameter (pad value 0).
    pub fn input(name: &'static str, dims: Vec<DimSpec>) -> TensorSpec {
        TensorSpec { name, dims, is_output: false, pad: 0.0, implied_leading: false }
    }

    /// An output parameter.
    pub fn output(name: &'static str, dims: Vec<DimSpec>) -> TensorSpec {
        TensorSpec { name, dims, is_output: true, pad: 0.0, implied_leading: false }
    }

    /// Set the pad value out-of-range reads observe (softmax loads pad
    /// with `-inf` so padded lanes never win the row max).
    pub fn with_pad(mut self, pad: f32) -> TensorSpec {
        self.pad = pad;
        self
    }

    /// Accept rank `n-1` request tensors by implying a leading 1.
    pub fn with_implied_leading(mut self) -> TensorSpec {
        self.implied_leading = true;
        self
    }
}

/// Meta-parameter policy: how an [`Arrangement`]'s block-size symbols are
/// chosen for concrete dims.  Tuning only — never correctness.
#[derive(Debug, Clone)]
pub enum Meta {
    /// the arrangement uses no meta symbols
    None,
    /// one power-of-two block covering dim `of` (≤ 4096), bound to `sym`
    ElementwiseBlock {
        /// block symbol, e.g. `"BLOCK_SIZE"`
        sym: &'static str,
        /// the dim symbol the block covers
        of: &'static str,
    },
    /// the adaptive mm tiling: `BLOCK_SIZE_M/N/K` from dims `(m, k, n)`
    MatmulBlocks {
        /// output-rows dim symbol
        m: &'static str,
        /// reduction dim symbol
        k: &'static str,
        /// output-cols dim symbol
        n: &'static str,
    },
    /// the flash-attention tiling: `BLOCK_SIZE_M` (query rows per
    /// program) and `BLOCK_SIZE_N` (key/value rows per online-softmax
    /// step) — one power-of-two block covering short sequences exactly,
    /// capped at 64 (the Python sdpa kernel's `block_size(64)` default)
    /// and clamped against the head dim (a degenerate `head_dim 1` must
    /// not allocate a 64x64 score tile for 64x1 operand tiles)
    AttentionBlocks {
        /// the sequence-length dim symbol
        seq: &'static str,
        /// the head-dim symbol the block is clamped against
        head: &'static str,
    },
    /// fixed bindings, independent of the request shapes
    Fixed(&'static [(&'static str, i64)]),
}

impl Meta {
    fn bindings(&self, dims: &DimBindings) -> Result<Vec<(String, i64)>> {
        let get = |name: &str| -> Result<i64> {
            dims.get(name)
                .copied()
                .ok_or_else(|| anyhow!("meta policy references unbound dim {name}"))
        };
        Ok(match self {
            Meta::None => Vec::new(),
            Meta::ElementwiseBlock { sym, of } => {
                vec![((*sym).to_string(), elementwise_block(get(of)? as usize))]
            }
            Meta::MatmulBlocks { m, k, n } => {
                let (bm, bn, bk) =
                    mm_blocks(get(m)? as usize, get(k)? as usize, get(n)? as usize);
                vec![
                    ("BLOCK_SIZE_M".to_string(), bm),
                    ("BLOCK_SIZE_N".to_string(), bn),
                    ("BLOCK_SIZE_K".to_string(), bk),
                ]
            }
            Meta::AttentionBlocks { seq, head } => {
                let block = attention_block(get(seq)? as usize, get(head)? as usize);
                vec![
                    ("BLOCK_SIZE_M".to_string(), block),
                    ("BLOCK_SIZE_N".to_string(), block),
                ]
            }
            Meta::Fixed(pairs) => {
                pairs.iter().map(|(s, v)| ((*s).to_string(), *v)).collect()
            }
        })
    }

    /// The autotuner's candidate space for concrete dims: a short
    /// power-of-two sweep around the heuristic.  Two invariants the
    /// whole `exec::tune` subsystem rests on:
    ///
    /// 1. **Candidate 0 is always [`Meta::bindings`]** — the heuristic is
    ///    the guaranteed fallback, so a search that skips every other
    ///    candidate (compile failure, slower) degenerates to the status
    ///    quo.
    /// 2. **Candidates never vary a symbol that changes reduction or
    ///    accumulation order** — `BLOCK_SIZE_K` and attention's key/value
    ///    block (`BLOCK_SIZE_N`) are pinned to the heuristic value.  Every
    ///    candidate therefore computes *bit-identical* outputs to the
    ///    heuristic plan, which is what lets `NT_TUNE=first_use` serving
    ///    be byte-for-byte equal to `NT_TUNE=off`.
    ///
    /// Untunable policies ([`Meta::None`], [`Meta::Fixed`]) return a
    /// single candidate.
    pub fn candidates(&self, dims: &DimBindings) -> Result<Vec<Vec<(String, i64)>>> {
        fn push(cand: Vec<(String, i64)>, out: &mut Vec<Vec<(String, i64)>>) {
            if !out.contains(&cand) {
                out.push(cand);
            }
        }
        let base = self.bindings(dims)?;
        let mut out: Vec<Vec<(String, i64)>> = vec![base.clone()];
        match self {
            Meta::None | Meta::Fixed(_) => {}
            Meta::ElementwiseBlock { sym, .. } => {
                let b0 = base[0].1;
                for b in [b0 / 4, b0 / 2, b0 * 2, b0 * 4] {
                    let b = b.clamp(32, 4096);
                    push(vec![((*sym).to_string(), b)], &mut out);
                }
            }
            Meta::MatmulBlocks { .. } => {
                // base order: BLOCK_SIZE_M, BLOCK_SIZE_N, BLOCK_SIZE_K;
                // K is pinned (it sets the accumulation split)
                let (bm, bn, bk) = (base[0].1, base[1].1, base[2].1);
                for m in [bm / 2, bm, bm * 2] {
                    for n in [bn / 2, bn, bn * 2] {
                        let (m, n) = (m.clamp(16, 128), n.clamp(16, 128));
                        push(
                            vec![
                                ("BLOCK_SIZE_M".to_string(), m),
                                ("BLOCK_SIZE_N".to_string(), n),
                                ("BLOCK_SIZE_K".to_string(), bk),
                            ],
                            &mut out,
                        );
                    }
                }
            }
            Meta::AttentionBlocks { .. } => {
                // only the query-rows block is swept; the key/value block
                // (BLOCK_SIZE_N) sets the online-softmax step order and
                // stays pinned to the heuristic
                let (bm, bn) = (base[0].1, base[1].1);
                for m in [bm / 2, bm * 2] {
                    let m = m.clamp(16, 128);
                    push(
                        vec![("BLOCK_SIZE_M".to_string(), m), ("BLOCK_SIZE_N".to_string(), bn)],
                        &mut out,
                    );
                }
            }
        }
        Ok(out)
    }
}

/// Element-wise block size: a power of two covering small inputs exactly.
fn elementwise_block(n: usize) -> i64 {
    (n.next_power_of_two() as i64).min(4096)
}

/// Attention block size: covers short sequences in one block, caps at 64
/// — and clamps against the head dim, so degenerate heads (`head_dim 1`)
/// do not over-allocate the `[block, block]` score tile relative to the
/// `[block, head]` operand tiles it is computed from.  Heads of 4 or more
/// (every realistic model) leave the seq-derived block unchanged.
fn attention_block(seq: usize, head: usize) -> i64 {
    let seq_block = (seq.next_power_of_two() as i64).min(64);
    let head_cap = ((head.next_power_of_two() as i64) * 16).max(16);
    seq_block.min(head_cap)
}

const MM_BLOCK: i64 = 32;

/// Matmul tiling for concrete `[m, k] x [k, n]` sizes.  Small problems
/// keep the legacy 32-wide blocks (one gather per tile, no packing
/// overhead); larger ones take 64x64 output tiles with K panels up to
/// 256 deep, so the fused `DotAcc` GEMM amortizes packing while the grid
/// still fans out across the worker pool (8x8 cells for a 512^3 mm).
fn mm_blocks(m: usize, k: usize, n: usize) -> (i64, i64, i64) {
    if m.max(n).max(k) <= 128 {
        (MM_BLOCK, MM_BLOCK, MM_BLOCK)
    } else {
        (64, 64, k.min(256) as i64)
    }
}

/// A composable tiling strategy over symbolic tensors — the
/// `arrange::catalog` entries rehomed as first-class values.
///
/// The build function receives the unified dim bindings, so a kernel may
/// pick an arrangement *variant* from concrete sizes (addmm arranges a
/// `[1, n]` bias differently from an `[m, n]` one); most arrangements
/// ignore the bindings entirely.
///
/// **Contract:** variants selected from the bindings must preserve the
/// arrangement's *access structure* — in particular its row-independence
/// (which source dims are driven by which grid axes).  [`make`] derives
/// the coalescibility flag from one probe-shape specialization; a build
/// function that is row-independent at small sizes but row-coupled at
/// large ones would make the batcher stack requests it must not.  The
/// builtin variants (addmm's bias rows) only change *which* broadcast
/// view is built, never the stacking structure.
///
/// ```
/// use ninetoothed_repro::arrange::catalog;
/// use ninetoothed_repro::kernel::Arrangement;
///
/// let rowwise = Arrangement::new("one program per row", |_| catalog::rowwise());
/// assert_eq!(rowwise.summary, "one program per row");
/// ```
#[derive(Clone)]
pub struct Arrangement {
    /// one-line human description (shown by `repro kernels`)
    pub summary: &'static str,
    /// builds the arranged symbolic tensors, in parameter order
    pub build: fn(&DimBindings) -> Result<Vec<SymTensor>>,
    /// block-size policy for the arrangement's meta symbols
    pub meta: Meta,
}

impl Arrangement {
    /// A new arrangement with no meta symbols.
    pub fn new(
        summary: &'static str,
        build: fn(&DimBindings) -> Result<Vec<SymTensor>>,
    ) -> Arrangement {
        Arrangement { summary, build, meta: Meta::None }
    }

    /// Attach a meta-parameter (block-size) policy.
    pub fn with_meta(mut self, meta: Meta) -> Arrangement {
        self.meta = meta;
        self
    }
}

/// SSA-style handle to a tile-program register, issued by [`AppBuilder`].
#[derive(Debug, Clone, Copy)]
pub struct Val(usize);

/// Typed builder for application functions: a serial tile program written
/// as if for one tile (paper §3.3), lowered to the `exec::ir` register
/// machine with automatic register allocation.
///
/// ```
/// use ninetoothed_repro::exec::{BinOp, ReduceOp, UnaryOp};
/// use ninetoothed_repro::kernel::AppBuilder;
///
/// // softmax over one row: y = exp(x - max(x)) / sum(exp(x - max(x)))
/// let mut app = AppBuilder::new("softmax");
/// let x = app.load(0);
/// let m = app.reduce(x, None, ReduceOp::Max);
/// let centered = app.binary(x, m, BinOp::Sub);
/// let e = app.unary(centered, UnaryOp::Exp);
/// let denom = app.reduce(e, None, ReduceOp::Sum);
/// let y = app.binary(e, denom, BinOp::Div);
/// app.store(1, y);
/// let program = app.build();
/// program.validate(2, &[false, true]).unwrap();
/// assert_eq!(program.instrs.len(), 7);
/// ```
pub struct AppBuilder {
    name: &'static str,
    regs: usize,
    instrs: Vec<Instr>,
}

impl AppBuilder {
    /// Start a program; `name` becomes the kernel name in [`make`].
    ///
    /// Names are `&'static` because `TileProgram` embeds one (kernel
    /// definitions live for the process).  A caller composing kernels
    /// with runtime-computed names can intern them via `Box::leak`.
    pub fn new(name: &'static str) -> AppBuilder {
        AppBuilder { name, regs: 0, instrs: Vec::new() }
    }

    fn fresh(&mut self) -> usize {
        let r = self.regs;
        self.regs += 1;
        r
    }

    /// Load the current sub-tile of a parameter.
    pub fn load(&mut self, param: usize) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Load { dst, param });
        Val(dst)
    }

    /// A zero tile shaped like a parameter's application block
    /// (`ntl.zeros(output.shape)`).
    pub fn zeros_like(&mut self, param: usize) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Zeros { dst, like_param: param });
        Val(dst)
    }

    /// A scalar constant tile.
    pub fn constant(&mut self, value: f32) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Const { dst, value });
        Val(dst)
    }

    /// Element-wise unary operation.
    pub fn unary(&mut self, a: Val, op: UnaryOp) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Unary { dst, a: a.0, op });
        Val(dst)
    }

    /// Element-wise (broadcasting) binary operation.
    pub fn binary(&mut self, a: Val, b: Val, op: BinOp) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Binary { dst, a: a.0, b: b.0, op });
        Val(dst)
    }

    /// Keep-dims reduction; `axis: None` reduces all axes.
    pub fn reduce(&mut self, a: Val, axis: Option<usize>, op: ReduceOp) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Reduce { dst, a: a.0, axis, op });
        Val(dst)
    }

    /// 2-D matrix product of two register tiles (`ntl.dot`), e.g. flash
    /// attention's `dot(q, trans(k))` score product.  The mm-family
    /// k-loops use the fused [`dot_acc`] instead (it feeds the blocked
    /// GEMM from the source tensors without materializing operand tiles).
    ///
    /// [`dot_acc`]: AppBuilder::dot_acc
    pub fn dot(&mut self, a: Val, b: Val) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Dot { dst, a: a.0, b: b.0 });
        Val(dst)
    }

    /// Fused `acc += dot(param_a, param_b)` over the current sub-tiles
    /// (the mm-family k-loop body; routes through the blocked GEMM).
    /// `acc` must be a declared carry of the enclosing [`loop_over`].
    ///
    /// [`loop_over`]: AppBuilder::loop_over
    pub fn dot_acc(&mut self, acc: Val, a_param: usize, b_param: usize) {
        self.instrs.push(Instr::DotAcc { acc: acc.0, a_param, b_param });
    }

    /// 2-D matrix transpose (`ntl.trans`), e.g. flash attention's
    /// `dot(q, trans(k))` score product.
    pub fn transpose(&mut self, a: Val) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Transpose { dst, a: a.0 });
        Val(dst)
    }

    /// The padding mask of a parameter's current sub-tile: `0.0` on
    /// in-range lanes, `value` on padded ones.  Adding it (with a large
    /// negative `value`) to attention scores keeps padded key rows out of
    /// an online softmax — how sdpa stays correct on sequence lengths
    /// that are not multiples of the block size.
    pub fn pad_mask(&mut self, param: usize, value: f32) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::PadMask { dst, like_param: param, value });
        Val(dst)
    }

    /// The concrete extent of a parameter's application block along
    /// `axis`, as a scalar (the `query.shape[-1]` the Python sdpa
    /// application scales by — resolved per specialization).
    pub fn block_dim(&mut self, param: usize, axis: usize) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::BlockDim { dst, param, axis });
        Val(dst)
    }

    /// Copy `src` into an existing register — how a [`loop_over`] body
    /// updates its carried registers (`m = m_new`).
    ///
    /// [`loop_over`]: AppBuilder::loop_over
    pub fn assign(&mut self, dst: Val, src: Val) {
        self.instrs.push(Instr::Assign { dst: dst.0, src: src.0 });
    }

    /// Iterate `body` once per sub-tile of the arrangement's loop
    /// (middle) level — the `for k in range(...)` of the mm application,
    /// the key/value-block loop of sdpa.  Loops do not nest.
    ///
    /// `carries` declares the registers whose values persist across
    /// iterations; everything else assigned inside `body` is
    /// iteration-local (cleared after every pass).  Carries must be
    /// initialized before the loop and are updated in the body with
    /// [`assign`] (or in place by [`dot_acc`]); relying on undeclared
    /// persistence is rejected by program validation inside [`make`].
    ///
    /// ```
    /// use ninetoothed_repro::exec::BinOp;
    /// use ninetoothed_repro::kernel::AppBuilder;
    ///
    /// // running sum across the k sub-tiles: `acc` is the declared carry,
    /// // the loaded tile is iteration-local
    /// let mut app = AppBuilder::new("k_sum");
    /// let acc = app.zeros_like(1);
    /// app.loop_over(&[acc], |b| {
    ///     let x = b.load(0);
    ///     let next = b.binary(acc, x, BinOp::Add);
    ///     b.assign(acc, next);
    /// });
    /// app.store(1, acc);
    /// let program = app.build();
    /// program.validate(2, &[false, true]).unwrap();
    /// assert_eq!(program.loop_carries(), Some(1));
    /// ```
    ///
    /// [`assign`]: AppBuilder::assign
    /// [`dot_acc`]: AppBuilder::dot_acc
    pub fn loop_over(&mut self, carries: &[Val], body: impl FnOnce(&mut AppBuilder)) {
        let mark = self.instrs.len();
        body(self);
        let body_instrs = self.instrs.split_off(mark);
        self.instrs.push(Instr::Loop {
            carried: carries.iter().map(|v| v.0).collect(),
            body: body_instrs,
        });
    }

    /// Split a tile into equal halves along `axis` (rope's `x[:half]` /
    /// `x[half:]`).
    pub fn split_half(&mut self, a: Val, axis: usize) -> (Val, Val) {
        let lo = self.fresh();
        let hi = self.fresh();
        self.instrs.push(Instr::SplitHalf { lo, hi, a: a.0, axis });
        (Val(lo), Val(hi))
    }

    /// Concatenate two tiles along `axis` (`ntl.cat`).
    pub fn concat(&mut self, a: Val, b: Val, axis: usize) -> Val {
        let dst = self.fresh();
        self.instrs.push(Instr::Concat { dst, a: a.0, b: b.0, axis });
        Val(dst)
    }

    /// Store a register into the current sub-tile of an output parameter.
    pub fn store(&mut self, param: usize, src: Val) {
        self.instrs.push(Instr::Store { param, src: src.0 });
    }

    /// Finish: the serial tile program [`make`] pairs with an arrangement.
    pub fn build(self) -> TileProgram {
        TileProgram { name: self.name, regs: self.regs, instrs: self.instrs }
    }
}

/// A complete kernel definition, produced by [`make`]: everything the
/// serving stack needs — admission checks, output inference, the
/// per-shape specializer, and the derived coalescibility flag.
///
/// ```
/// use ninetoothed_repro::kernel;
///
/// let mm = kernel::lookup("mm").unwrap();
/// assert_eq!((mm.arity, mm.coalesce, mm.executable()), (2, false, true));
/// let spec = mm.specialize_shapes(&[&[70, 50], &[50, 90]]).unwrap();
/// assert_eq!(spec.output_shapes, vec![vec![70, 90]]);
/// assert_eq!(spec.grid, vec![3, 3]);
/// ```
pub struct KernelDef {
    /// kernel name (from the application program)
    pub name: String,
    /// number of input (non-output) parameters
    pub arity: usize,
    /// parameter declarations, in arrangement order
    pub tensors: Vec<TensorSpec>,
    /// the tiling strategy + meta policy
    pub arrangement: Arrangement,
    /// the serial per-tile application program
    pub program: TileProgram,
    /// same-shape requests may be stacked along dim 0 into one launch.
    /// **Derived** from the arrangement (row-independence: every
    /// parameter stacks along one shared size symbol that maps to a
    /// single common grid axis, partitioned without loop-carried or
    /// cross-row access), never asserted by hand.
    pub coalesce: bool,
    /// extra admission predicates over the unified dims: each expression
    /// must evaluate to 0
    constraints: Vec<(Expr, &'static str)>,
    /// the arrangement lowers to affine views at the probe shapes
    executable: bool,
    /// why the probe specialization failed, when it did — surfaced by
    /// admission errors and `repro kernels` so a broken arrangement is
    /// diagnosable instead of a silent "not lowerable"
    probe_error: Option<String>,
}

/// Declare a kernel from an arrangement, an application and its symbolic
/// tensors — the paper's `ninetoothed.make` (§3.1).
///
/// ```
/// use ninetoothed_repro::arrange::catalog;
/// use ninetoothed_repro::exec::{BinOp, GridScheduler};
/// use ninetoothed_repro::kernel::{dim, make, AppBuilder, Arrangement, Meta, TensorSpec};
/// use ninetoothed_repro::runtime::HostTensor;
///
/// // arrangement: every parameter in BLOCK_SIZE tiles (paper Listing 3)
/// let arrangement = Arrangement::new(
///     "1-D element-wise",
///     |_| catalog::elementwise_1d(&["input", "output"]),
/// )
/// .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" });
///
/// // application: y = x * 2, written as if for one tile
/// let mut app = AppBuilder::new("double");
/// let x = app.load(0);
/// let two = app.constant(2.0);
/// let y = app.binary(x, two, BinOp::Mul);
/// app.store(1, y);
///
/// let double = make(
///     arrangement,
///     app.build(),
///     vec![
///         TensorSpec::input("input", vec![dim("n", 17)]),
///         TensorSpec::output("output", vec![dim("n", 17)]),
///     ],
/// )
/// .unwrap();
/// assert_eq!(double.arity, 1);
/// assert!(double.coalesce, "element-wise kernels derive as row-independent");
///
/// let x = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
/// let out = double.run(&[x], &GridScheduler::serial()).unwrap();
/// assert_eq!(out[0].as_f32().unwrap()[..], [2.0, 4.0, 6.0]);
/// ```
pub fn make(
    arrangement: Arrangement,
    application: TileProgram,
    tensors: Vec<TensorSpec>,
) -> Result<KernelDef> {
    let def = assemble(arrangement, application, tensors)?;
    let report = verify::verify(&def);
    if report.has_errors() {
        bail!("make: kernel {} fails declaration verification:\n{}", def.name, report.render());
    }
    Ok(def)
}

/// [`make`] without the verification gate: structural checks + probe
/// derivation only.  This is what the `verify::corpus` negative
/// declarations go through — a deliberately broken definition must be
/// *constructible* so the verifier can report on it, it just must never
/// pass [`make`] or registration.
fn assemble(
    arrangement: Arrangement,
    application: TileProgram,
    tensors: Vec<TensorSpec>,
) -> Result<KernelDef> {
    if tensors.is_empty() {
        bail!("make: kernel {} declares no tensors", application.name);
    }
    let is_output: Vec<bool> = tensors.iter().map(|t| t.is_output).collect();
    if !is_output.iter().any(|&o| o) {
        bail!("make: kernel {} declares no output tensor", application.name);
    }
    application
        .validate_structure(tensors.len(), &is_output)
        .with_context(|| format!("make: application {} is malformed", application.name))?;
    // every size symbol an output (or a derived dim) references must be
    // bound by some input's bare symbol — otherwise the kernel would
    // register cleanly but fail output inference on every request
    let bound: std::collections::BTreeSet<&str> = tensors
        .iter()
        .filter(|t| !t.is_output)
        .flat_map(|t| t.dims.iter())
        .filter_map(|ds| match ds {
            DimSpec::Sym { name, .. } => Some(*name),
            DimSpec::Expr(_) => None,
        })
        .collect();
    for spec in &tensors {
        for (d, ds) in spec.dims.iter().enumerate() {
            let free: Vec<String> = match ds {
                DimSpec::Sym { name, .. } if spec.is_output => vec![(*name).to_string()],
                DimSpec::Sym { .. } => Vec::new(),
                DimSpec::Expr(e) => e.free_symbols().into_iter().collect(),
            };
            for sym in free {
                if !bound.contains(sym.as_str()) {
                    bail!(
                        "make: kernel {}: {} dim {d} references size symbol {sym}, which \
                         no input binds — outputs and derived dims must be inferable \
                         from the inputs",
                        application.name,
                        spec.name
                    );
                }
            }
        }
    }
    let arity = tensors.iter().filter(|t| !t.is_output).count();
    let mut def = KernelDef {
        name: application.name.to_string(),
        arity,
        tensors,
        arrangement,
        program: application,
        coalesce: false,
        constraints: Vec::new(),
        executable: false,
        probe_error: None,
    };
    let probe = def.probe_dims()?;
    def.derive(&probe);
    Ok(def)
}

impl KernelDef {
    /// Add an admission predicate over the unified dims: `expr` must
    /// evaluate to 0 (e.g. rope's even head dimension).  The declared
    /// probe sizes are checked against the constraint immediately, so a
    /// self-contradictory declaration (or a constraint referencing an
    /// undeclared dim) errors at definition time, not per request.
    pub fn with_constraint(mut self, expr: Expr, msg: &'static str) -> Result<KernelDef> {
        let probe = self.probe_dims()?;
        let v = expr.eval(&probe).with_context(|| {
            format!("kernel {}: constraint {expr} references undeclared dims", self.name)
        })?;
        if v != 0 {
            bail!(
                "kernel {}: the declared probe sizes violate constraint {expr} ({msg}; \
                 got {v}, expected 0)",
                self.name
            );
        }
        self.constraints.push((expr, msg));
        Ok(self)
    }

    /// True when the arrangement lowers to affine views (probed at
    /// definition time).  A registered but non-executable kernel (the
    /// conv2d implicit-GEMM arrangement needs non-affine `%`/`//` index
    /// lowering) is rejected at admission instead of mid-pipeline.
    pub fn executable(&self) -> bool {
        self.executable
    }

    /// The probe-specialization failure for a non-executable kernel.
    pub fn probe_error(&self) -> Option<&str> {
        self.probe_error.as_deref()
    }

    /// Number of loop-carried registers in the application program
    /// (`None` for straight-line programs).  `repro kernels` surfaces
    /// this so carried-reduction kernels (mm's accumulator, sdpa's
    /// running max / running sum / accumulator) are inspectable at serve
    /// time.
    pub fn loop_carries(&self) -> Option<usize> {
        self.program.loop_carries()
    }

    fn inputs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.tensors.iter().filter(|t| !t.is_output)
    }

    /// Canonical (declared-rank) shapes for the request's input tensors.
    fn canonical_input_shapes(&self, shapes: &[&[usize]]) -> Result<Vec<Vec<usize>>> {
        let mut canon = Vec::with_capacity(shapes.len());
        for (i, (spec, shape)) in self.inputs().zip(shapes).enumerate() {
            if shape.is_empty() {
                bail!(
                    "kernel {}: input {i} is rank-0 (scalar tensors are not tileable)",
                    self.name
                );
            }
            if shape.iter().any(|&d| d == 0) {
                bail!("kernel {}: input {i} has a zero-length dimension {shape:?}", self.name);
            }
            let declared = spec.dims.len();
            if shape.len() == declared {
                canon.push(shape.to_vec());
            } else if spec.implied_leading && shape.len() + 1 == declared {
                let mut s = Vec::with_capacity(declared);
                s.push(1);
                s.extend_from_slice(shape);
                canon.push(s);
            } else {
                bail!(
                    "kernel {}: {} expects rank {declared}{}, got shape {shape:?}",
                    self.name,
                    spec.name,
                    if spec.implied_leading { " (or one less, with an implied leading 1)" } else { "" }
                );
            }
        }
        Ok(canon)
    }

    /// Unify the declared size symbols against request shapes — the
    /// derived shape preconditions.  Returns the dim bindings plus the
    /// canonical input shapes.
    fn bind(&self, shapes: &[&[usize]]) -> Result<(DimBindings, Vec<Vec<usize>>)> {
        if shapes.len() != self.arity {
            bail!("kernel {} expects {} inputs, got {}", self.name, self.arity, shapes.len());
        }
        let canon = self.canonical_input_shapes(shapes)?;
        let mut dims = DimBindings::new();
        // pass 1: bind bare size symbols, rejecting conflicts
        for (spec, shape) in self.inputs().zip(&canon) {
            for (d, ds) in spec.dims.iter().enumerate() {
                if let DimSpec::Sym { name, .. } = ds {
                    let v = shape[d] as i64;
                    let prev = dims.get(*name).copied();
                    match prev {
                        None => {
                            dims.insert((*name).to_string(), v);
                        }
                        Some(prev) if prev != v => bail!(
                            "kernel {}: size {name} is {prev} from an earlier argument, \
                             but {} has {v} at dim {d} (shape {shape:?})",
                            self.name,
                            spec.name
                        ),
                        _ => {}
                    }
                }
            }
        }
        // pass 2: derived dims must match
        for (spec, shape) in self.inputs().zip(&canon) {
            for (d, ds) in spec.dims.iter().enumerate() {
                if let DimSpec::Expr(e) = ds {
                    let want = e.eval(&dims).with_context(|| {
                        format!("kernel {}: evaluating {} dim {d} ({e})", self.name, spec.name)
                    })?;
                    if want != shape[d] as i64 {
                        bail!(
                            "kernel {}: {} dim {d} must be {e} = {want}, got {} \
                             (shape {shape:?})",
                            self.name,
                            spec.name,
                            shape[d]
                        );
                    }
                }
            }
        }
        // declared constraints
        for (expr, msg) in &self.constraints {
            let v = expr.eval(&dims).with_context(|| {
                format!("kernel {}: evaluating constraint {expr}", self.name)
            })?;
            if v != 0 {
                bail!("kernel {}: {msg} ({expr} = {v}, expected 0)", self.name);
            }
        }
        Ok((dims, canon))
    }

    /// Canonical shapes for **all** parameters: inputs as given (rank
    /// canonicalized), outputs inferred from the unified dims.
    fn all_shapes(
        &self,
        dims: &DimBindings,
        canon_inputs: &[Vec<usize>],
    ) -> Result<Vec<Vec<usize>>> {
        let mut out = Vec::with_capacity(self.tensors.len());
        let mut next_input = 0usize;
        for spec in &self.tensors {
            if spec.is_output {
                let mut shape = Vec::with_capacity(spec.dims.len());
                for (d, ds) in spec.dims.iter().enumerate() {
                    let v = ds.eval(dims).with_context(|| {
                        format!("kernel {}: inferring output {} dim {d}", self.name, spec.name)
                    })?;
                    if v <= 0 {
                        bail!(
                            "kernel {}: inferred non-positive size {v} for output {} dim {d}",
                            self.name,
                            spec.name
                        );
                    }
                    shape.push(v as usize);
                }
                out.push(shape);
            } else {
                out.push(canon_inputs[next_input].clone());
                next_input += 1;
            }
        }
        Ok(out)
    }

    /// Shape-only admission checks (arity, ranks, unification, derived
    /// dims, constraints, output inference).  No affine lowering.
    pub fn check_shapes(&self, shapes: &[&[usize]]) -> Result<()> {
        let (dims, canon) = self.bind(shapes)?;
        self.all_shapes(&dims, &canon).map(|_| ())
    }

    /// Cheap admission-time validation over concrete tensors: the shape
    /// checks plus dtype.  The router calls this per request; the
    /// expensive specialization happens once per shape, in the compile
    /// stage.
    pub fn check(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.arity {
            bail!("kernel {} expects {} inputs, got {}", self.name, self.arity, inputs.len());
        }
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        self.check_shapes(&shapes)?;
        for (i, t) in inputs.iter().enumerate() {
            t.as_f32()
                .map_err(|_| anyhow!("kernel {}: input {i} must be f32", self.name))?;
        }
        Ok(())
    }

    /// The inferred output shapes for given input shapes.
    pub fn output_shapes(&self, shapes: &[&[usize]]) -> Result<Vec<Vec<usize>>> {
        let (dims, canon) = self.bind(shapes)?;
        let all = self.all_shapes(&dims, &canon)?;
        Ok(self
            .tensors
            .iter()
            .zip(all)
            .filter(|(t, _)| t.is_output)
            .map(|(_, s)| s)
            .collect())
    }

    /// Validate shapes and compute the concrete launch for them — the
    /// derived per-shape specializer `exec::compile` runs once per shape
    /// signature.  A function of **shapes only** (no tensor data), which
    /// is what lets the plan cache memoize the result.
    pub fn specialize_shapes(&self, shapes: &[&[usize]]) -> Result<Specialization> {
        let (dims, canon) = self.bind(shapes)?;
        let all = self.all_shapes(&dims, &canon)?;
        self.specialize_with(&dims, &all)
    }

    /// [`KernelDef::specialize_shapes`] with the arrangement's meta
    /// bindings replaced by `meta` — how the autotuner compiles a
    /// candidate block configuration through the ordinary specializer
    /// (every downstream check — grid agreement, probe verification —
    /// still runs, so an infeasible candidate is a clean error).
    pub fn specialize_shapes_with_meta(
        &self,
        shapes: &[&[usize]],
        meta: &[(String, i64)],
    ) -> Result<Specialization> {
        let (dims, canon) = self.bind(shapes)?;
        let all = self.all_shapes(&dims, &canon)?;
        self.specialize_with_meta(&dims, &all, Some(meta))
    }

    /// The tunable block-configuration space for concrete input shapes:
    /// [`Meta::candidates`] evaluated at the request's dim bindings.
    /// Candidate 0 is always the heuristic; a single-candidate space
    /// means the kernel is not tunable for these shapes.
    pub fn meta_candidates(&self, shapes: &[&[usize]]) -> Result<Vec<Vec<(String, i64)>>> {
        let (dims, _) = self.bind(shapes)?;
        self.arrangement.meta.candidates(&dims)
    }

    /// Validate inputs and compute the concrete launch for them.
    pub fn specialize(&self, inputs: &[HostTensor]) -> Result<Specialization> {
        self.check(inputs)?;
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        self.specialize_shapes(&shapes)
    }

    /// Compile-and-execute in one step (uncached — callers that serve
    /// repeated traffic go through `exec::PlanCache` instead).
    pub fn run(&self, inputs: &[HostTensor], scheduler: &GridScheduler) -> Result<Vec<HostTensor>> {
        let spec = self.specialize(inputs)?;
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        scheduler.run(&self.program, &spec.views, &refs, &spec.output_shapes)
    }

    /// The specializer core: meta + size bindings, arrangement build,
    /// view lowering, §3.2.1 agreement.  `shapes` covers all parameters
    /// (outputs included), in declaration order.
    fn specialize_with(&self, dims: &DimBindings, shapes: &[Vec<usize>]) -> Result<Specialization> {
        self.specialize_with_meta(dims, shapes, None)
    }

    /// [`KernelDef::specialize_with`] with an optional meta override: the
    /// autotuner substitutes a candidate's block bindings for the
    /// heuristic's; everything downstream is identical.
    fn specialize_with_meta(
        &self,
        dims: &DimBindings,
        shapes: &[Vec<usize>],
        meta_override: Option<&[(String, i64)]>,
    ) -> Result<Specialization> {
        let mut bindings: BTreeMap<String, i64> = BTreeMap::new();
        let meta = match meta_override {
            Some(pairs) => pairs.to_vec(),
            None => self.arrangement.meta.bindings(dims)?,
        };
        for (sym, v) in meta {
            bindings.insert(sym, v);
        }
        for (spec, shape) in self.tensors.iter().zip(shapes) {
            for (d, &s) in shape.iter().enumerate() {
                bindings.insert(format!("{}_size_{d}", spec.name), s as i64);
            }
        }
        let arranged = (self.arrangement.build)(dims)?;
        if arranged.len() != self.tensors.len() {
            bail!(
                "kernel {}: arrangement produced {} parameters for {} declared tensors",
                self.name,
                arranged.len(),
                self.tensors.len()
            );
        }
        let mut views = Vec::with_capacity(arranged.len());
        for ((sym_t, spec), shape) in arranged.iter().zip(&self.tensors).zip(shapes) {
            if sym_t.name != spec.name {
                bail!(
                    "kernel {}: arrangement parameter {:?} does not match declared tensor \
                     {:?} (orders must agree)",
                    self.name,
                    sym_t.name,
                    spec.name
                );
            }
            views.push(ParamView::specialize(sym_t, &bindings, shape, spec.is_output, spec.pad)?);
        }
        let grid = views[0].grid.clone();
        for v in &views {
            if v.grid != grid {
                bail!(
                    "outermost-level shapes disagree: {:?} ({}) vs {grid:?} (paper §3.2.1)",
                    v.grid,
                    v.name
                );
            }
        }
        let mut loop_shape = Vec::new();
        for v in &views {
            if !v.loop_shape.is_empty() {
                if loop_shape.is_empty() {
                    loop_shape = v.loop_shape.clone();
                } else if loop_shape != v.loop_shape {
                    bail!("loop-level shapes disagree: {:?} ({})", v.loop_shape, v.name);
                }
            }
        }
        let output_shapes = self
            .tensors
            .iter()
            .zip(shapes)
            .filter(|(t, _)| t.is_output)
            .map(|(_, s)| s.clone())
            .collect();
        Ok(Specialization { grid, loop_shape, views, output_shapes })
    }

    // -- registration-time derivations ---------------------------------------

    /// Probe bindings: every size symbol at its declared probe value.
    fn probe_dims(&self) -> Result<DimBindings> {
        let mut dims = DimBindings::new();
        for spec in &self.tensors {
            for ds in &spec.dims {
                if let DimSpec::Sym { name, probe } = ds {
                    let prev = dims.get(*name).copied();
                    match prev {
                        None => {
                            dims.insert((*name).to_string(), *probe);
                        }
                        Some(prev) if prev != *probe => bail!(
                            "kernel {}: dim {name} declared with conflicting probe sizes \
                             {prev} and {probe}",
                            self.name
                        ),
                        _ => {}
                    }
                }
            }
        }
        Ok(dims)
    }

    /// Derive `executable` and `coalesce` by specializing at the probe
    /// shapes and analyzing the lowered views.
    fn derive(&mut self, probe: &DimBindings) {
        self.executable = false;
        self.coalesce = false;
        let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(self.tensors.len());
        for spec in &self.tensors {
            let mut s = Vec::with_capacity(spec.dims.len());
            for ds in &spec.dims {
                match ds.eval(probe) {
                    Ok(v) if v > 0 => s.push(v as usize),
                    Ok(v) => {
                        self.probe_error = Some(format!(
                            "probe shape for {} has non-positive size {v}",
                            spec.name
                        ));
                        return;
                    }
                    Err(e) => {
                        self.probe_error = Some(format!("{e:#}"));
                        return;
                    }
                }
            }
            shapes.push(s);
        }
        match self.specialize_with(probe, &shapes) {
            Ok(spec) => {
                self.executable = true;
                self.coalesce = self.derive_stackable(&spec);
            }
            Err(e) => self.probe_error = Some(format!("{e:#}")),
        }
    }

    /// Row-independence, detected from the arrangement.  Stacking all
    /// arguments along dim 0 is bit-identical to per-request execution
    /// iff:
    ///
    /// 1. every parameter's dim 0 is the *same* bare size symbol, which
    ///    appears in no other dimension (the batcher stacks every
    ///    argument, so all of them must share the stacking dim);
    /// 2. at the probe specialization, every parameter's dim-0 access is
    ///    driven by exactly one common grid axis — no loop-carried
    ///    motion, cells partition dim 0 (cell stride covers the block's
    ///    dim-0 span), and no *other* source dim depends on that axis;
    /// 3. if any block extends along dim 0 (1-D element-wise tiles), the
    ///    application must be lane-wise (no reductions / dots that could
    ///    mix rows regrouped by stacking).
    fn derive_stackable(&self, spec: &Specialization) -> bool {
        let stack_sym = match self.tensors.iter().find(|t| t.is_output).and_then(|t| t.dims.first())
        {
            Some(DimSpec::Sym { name, .. }) => *name,
            _ => return false,
        };
        for t in &self.tensors {
            if t.implied_leading {
                return false;
            }
            match t.dims.first() {
                Some(DimSpec::Sym { name, .. }) if *name == stack_sym => {}
                _ => return false,
            }
            for ds in &t.dims[1..] {
                let mentions = match ds {
                    DimSpec::Sym { name, .. } => *name == stack_sym,
                    DimSpec::Expr(e) => e.free_symbols().contains(stack_sym),
                };
                if mentions {
                    return false;
                }
            }
        }
        let mut g_star: Option<usize> = None;
        let mut any_inner = false;
        for view in &spec.views {
            let (cell, sub_span, inner_span) = view.dim_profile(0);
            if sub_span != 0 {
                return false;
            }
            let axes: Vec<usize> = cell
                .iter()
                .enumerate()
                .filter(|(_, &c)| c != 0)
                .map(|(g, _)| g)
                .collect();
            if axes.len() != 1 {
                return false;
            }
            let g = axes[0];
            match g_star {
                None => g_star = Some(g),
                Some(prev) if prev != g => return false,
                _ => {}
            }
            if cell[g].abs() < 1 + inner_span {
                return false;
            }
            if inner_span > 0 {
                any_inner = true;
            }
            for d in 1..view.src_shape.len() {
                let (cell_d, _, _) = view.dim_profile(d);
                if cell_d.get(g).copied().unwrap_or(0) != 0 {
                    return false;
                }
            }
        }
        if g_star.is_none() {
            return false;
        }
        if any_inner && !lanewise(&self.program.instrs) {
            return false;
        }
        true
    }
}

/// True if every instruction computes each output lane from the same
/// lane of its operands (no reductions, dots, loops, transposes, or
/// position-dependent masks).
fn lanewise(instrs: &[Instr]) -> bool {
    instrs.iter().all(|i| {
        matches!(
            i,
            Instr::Load { .. }
                | Instr::Const { .. }
                | Instr::Unary { .. }
                | Instr::Binary { .. }
                | Instr::Assign { .. }
                | Instr::Store { .. }
        )
    })
}

/// The mutable kernel registry: name → `Arc<KernelDef>` behind a hash
/// lookup.  One process-global instance ([`registry`]) is what the
/// runtime registry, router and plan cache resolve through.
pub struct KernelRegistry {
    map: RwLock<HashMap<String, Arc<KernelDef>>>,
}

impl KernelRegistry {
    /// An empty registry (tests; production code uses [`registry`]).
    pub fn new() -> KernelRegistry {
        KernelRegistry { map: RwLock::new(HashMap::new()) }
    }

    /// Register (or replace) a definition under its name.
    ///
    /// The definition is re-verified here even though [`make`] already
    /// gated it: `KernelDef` has public fields (notably `coalesce`, which
    /// the batcher's coalescer trusts), so a definition tampered with —
    /// or assembled outside `make` — between construction and
    /// registration must not enter the serving path.  Definite (`Error`)
    /// findings reject; warnings register but still show in `repro lint`.
    ///
    /// Replacing an existing name does **not** invalidate backends or
    /// compiled plans already resolved from the old definition (the
    /// runtime registry memoizes per `(kernel, variant)` and the plan
    /// cache per shape signature), so redefinition mid-serving can leave
    /// old and new programs serving different shapes.  Register new
    /// kernels under fresh names; replacement is for startup composition.
    pub fn register(&self, def: KernelDef) -> Result<Arc<KernelDef>> {
        let report = verify::verify(&def);
        if report.has_errors() {
            bail!(
                "register: kernel {} fails declaration verification:\n{}",
                def.name,
                report.render()
            );
        }
        let def = Arc::new(def);
        self.map.write().unwrap().insert(def.name.clone(), def.clone());
        Ok(def)
    }

    /// Hash lookup by kernel name.
    pub fn lookup(&self, name: &str) -> Option<Arc<KernelDef>> {
        self.map.read().unwrap().get(name).cloned()
    }

    /// All registered definitions, sorted by name.
    pub fn snapshot(&self) -> Vec<Arc<KernelDef>> {
        let mut defs: Vec<Arc<KernelDef>> = self.map.read().unwrap().values().cloned().collect();
        defs.sort_by(|a, b| a.name.cmp(&b.name));
        defs
    }

    /// Number of registered kernels.
    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for KernelRegistry {
    fn default() -> Self {
        KernelRegistry::new()
    }
}

/// The process-global registry, seeded with the builtin catalog (and
/// rope) on first use.
pub fn registry() -> &'static KernelRegistry {
    static GLOBAL: OnceLock<KernelRegistry> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let reg = KernelRegistry::new();
        for def in builtins::defaults().expect("builtin kernel definitions are valid") {
            reg.register(def).expect("builtin kernel definitions verify clean");
        }
        reg
    })
}

/// All registered kernels (sorted by name).
pub fn kernels() -> Vec<Arc<KernelDef>> {
    registry().snapshot()
}

/// Look up a registered kernel by name.
pub fn lookup(name: &str) -> Option<Arc<KernelDef>> {
    registry().lookup(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn def(name: &str) -> Arc<KernelDef> {
        lookup(name).unwrap_or_else(|| panic!("{name} must be registered"))
    }

    #[test]
    fn registry_serves_all_builtins() {
        let names: Vec<String> = kernels().iter().map(|k| k.name.clone()).collect();
        for want in [
            "add", "silu", "gelu", "softmax", "rms_norm", "layer_norm", "mm", "bmm", "addmm",
            "conv2d", "rope", "sdpa", "sdpa_bias",
        ] {
            assert!(names.iter().any(|n| n == want), "{want} missing from {names:?}");
        }
        assert!(lookup("nope").is_none());
    }

    #[test]
    fn coalescibility_is_derived_from_the_arrangement() {
        // row-independent: element-wise 1-D, rowwise 2-D, batch-led bmm,
        // and batch-led sdpa (the online-softmax loop walks the sequence
        // dim, never the batch dim — carried state is per program)
        for name in ["add", "silu", "gelu", "softmax", "rms_norm", "layer_norm", "bmm", "sdpa"] {
            assert!(def(name).coalesce, "{name} must derive as coalescible");
        }
        // not row-independent: mm/addmm read other rows via the k loop;
        // rope's cos/sin tables and sdpa_bias's [s, s] bias lack the
        // batch (stacking) dim
        for name in ["mm", "addmm", "rope", "conv2d", "sdpa_bias"] {
            assert!(!def(name).coalesce, "{name} must never derive as coalescible");
        }
    }

    #[test]
    fn loop_carries_are_reported_per_kernel() {
        // mm-family: the accumulator is the single declared carry; sdpa
        // carries the full online-softmax state; element-wise kernels
        // have no loop at all
        for (name, want) in [
            ("mm", Some(1)),
            ("bmm", Some(1)),
            ("addmm", Some(1)),
            ("sdpa", Some(3)),
            ("sdpa_bias", Some(3)),
            ("add", None),
            ("softmax", None),
            ("rope", None),
        ] {
            assert_eq!(def(name).loop_carries(), want, "{name}");
        }
    }

    #[test]
    fn conv2d_is_registered_but_not_lowerable() {
        let conv = def("conv2d");
        assert!(!conv.executable(), "implicit GEMM needs non-affine lowering");
        // the executable flag is what keeps it out of the serving path
        assert!(crate::runtime::native_fallback_kind("conv2d", "nt").is_err());
    }

    #[test]
    fn unification_binds_and_rejects() {
        let mm = def("mm");
        assert!(mm.check_shapes(&[&[4, 3], &[3, 5]]).is_ok());
        // inner-dim conflict: k bound to 3 by input, 7 by other
        let err = mm.check_shapes(&[&[4, 3], &[7, 5]]).unwrap_err();
        assert!(format!("{err:#}").contains("size k"), "{err:#}");
        // rank mismatch
        assert!(mm.check_shapes(&[&[4, 3, 1], &[3, 5]]).is_err());
        // arity
        assert!(mm.check_shapes(&[&[4, 3]]).is_err());
        assert_eq!(mm.output_shapes(&[&[4, 3], &[3, 5]]).unwrap(), vec![vec![4, 5]]);
    }

    #[test]
    fn constraints_and_derived_dims_check() {
        let rope = def("rope");
        assert!(rope.check_shapes(&[&[2, 5, 3, 8], &[5, 4], &[5, 4]]).is_ok());
        // odd head dim violates the evenness constraint
        let err = rope.check_shapes(&[&[2, 5, 3, 7], &[5, 3], &[5, 3]]).unwrap_err();
        assert!(format!("{err:#}").contains("even"), "{err:#}");
        // cos table must be [s, d/2]
        assert!(rope.check_shapes(&[&[2, 5, 3, 8], &[5, 3], &[5, 3]]).is_err());
        assert!(rope.check_shapes(&[&[2, 4, 3, 8], &[5, 4], &[5, 4]]).is_err());
    }

    #[test]
    fn implied_leading_canonicalizes_rank() {
        let addmm = def("addmm");
        // rank-1 bias [n] admits as [1, n]
        assert!(addmm.check_shapes(&[&[5], &[4, 3], &[3, 5]]).is_ok());
        assert!(addmm.check_shapes(&[&[1, 5], &[4, 3], &[3, 5]]).is_ok());
        assert!(addmm.check_shapes(&[&[4, 5], &[4, 3], &[3, 5]]).is_ok());
        // rows must be 1 or m
        let err = addmm.check_shapes(&[&[2, 5], &[4, 3], &[3, 5]]).unwrap_err();
        assert!(format!("{err:#}").contains("broadcast"), "{err:#}");
    }

    #[test]
    fn make_rejects_malformed_applications() {
        use crate::arrange::catalog;
        // store to a non-output parameter fails validation inside make
        let mut app = AppBuilder::new("bad");
        let x = app.load(0);
        app.store(0, x);
        let err = make(
            Arrangement::new("1-D element-wise", |_| catalog::elementwise_1d(&["input", "output"])),
            app.build(),
            vec![
                TensorSpec::input("input", vec![dim("n", 8)]),
                TensorSpec::output("output", vec![dim("n", 8)]),
            ],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("non-output"), "{err:#}");
        // no outputs at all
        let app = AppBuilder::new("bad2");
        let err = make(
            Arrangement::new("1-D element-wise", |_| catalog::elementwise_1d(&["input"])),
            app.build(),
            vec![TensorSpec::input("input", vec![dim("n", 8)])],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("no output"), "{err:#}");
    }

    #[test]
    fn registry_accepts_runtime_registration() {
        let reg = KernelRegistry::new();
        assert!(reg.is_empty());
        let mut app = AppBuilder::new("copy");
        let x = app.load(0);
        app.store(1, x);
        let def = make(
            Arrangement::new(
                "1-D element-wise",
                |_| crate::arrange::catalog::elementwise_1d(&["input", "output"]),
            )
            .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" }),
            app.build(),
            vec![
                TensorSpec::input("input", vec![dim("n", 9)]),
                TensorSpec::output("output", vec![dim("n", 9)]),
            ],
        )
        .unwrap();
        let arc = reg.register(def).unwrap();
        assert_eq!(reg.len(), 1);
        assert!(arc.executable() && arc.coalesce);
        assert!(Arc::ptr_eq(&reg.lookup("copy").unwrap(), &arc));
    }
}
