//! The negative-declaration corpus: one deliberately broken kernel per
//! diagnostic code, each constructed through the un-gated assembly path
//! so the verifier can report on it (the same declarations would never
//! survive [`crate::kernel::make`]).
//!
//! Consumed twice: `rust/tests/verify.rs` asserts each case fires
//! *exactly* its intended code and nothing else, and `repro lint
//! --corpus` prints the table (exiting non-zero, which CI uses to prove
//! the gate bites).  `docs/diagnostics.md` documents the same
//! declarations with their fixes.

use anyhow::Result;

use crate::arrange::catalog;
use crate::exec::ir::{Instr, TileProgram};
use crate::exec::tile::{BinOp, ReduceOp, UnaryOp};
use crate::kernel::{assemble, dim, AppBuilder, Arrangement, Meta, TensorSpec};

use super::{verify, Code, Report};

/// One broken declaration and the verdict on it.
pub struct Case {
    /// corpus kernel name (also the assembled kernel's name)
    pub name: &'static str,
    /// the single code the declaration is built to fire
    pub expected: Code,
    /// what the declaration does wrong
    pub summary: &'static str,
    /// the verifier's findings on it
    pub report: Report,
}

fn elementwise() -> Arrangement {
    Arrangement::new("1-D element-wise", |_| catalog::elementwise_1d(&["input", "output"]))
        .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" })
}

fn ew_tensors(probe: i64) -> Vec<TensorSpec> {
    vec![
        TensorSpec::input("input", vec![dim("n", probe)]),
        TensorSpec::output("output", vec![dim("n", probe)]),
    ]
}

fn rowwise_arrangement() -> Arrangement {
    Arrangement::new("one program per row", |_| catalog::rowwise())
}

fn rw_tensors(rows: i64, cols: i64) -> Vec<TensorSpec> {
    vec![
        TensorSpec::input("input", vec![dim("rows", rows), dim("cols", cols)]),
        TensorSpec::output("output", vec![dim("rows", rows), dim("cols", cols)]),
    ]
}

fn mm_arrangement() -> Arrangement {
    Arrangement::new("mm tiling", |_| catalog::mm())
        .with_meta(Meta::MatmulBlocks { m: "m", k: "k", n: "n" })
}

fn mm_tensors() -> Vec<TensorSpec> {
    vec![
        TensorSpec::input("input", vec![dim("m", 70), dim("k", 50)]),
        TensorSpec::input("other", vec![dim("k", 50), dim("n", 90)]),
        TensorSpec::output("output", vec![dim("m", 70), dim("n", 90)]),
    ]
}

fn case(
    name: &'static str,
    expected: Code,
    summary: &'static str,
    arrangement: Arrangement,
    program: TileProgram,
    tensors: Vec<TensorSpec>,
) -> Result<Case> {
    let def = assemble(arrangement, program, tensors)?;
    Ok(Case { name, expected, summary, report: verify(&def) })
}

/// Build the full corpus: one case per `NT-V*` code, in code order.
pub fn cases() -> Result<Vec<Case>> {
    let mut out = Vec::new();

    // NT-V001: reg 0 is read by the Unary but nothing ever assigns it
    out.push(case(
        "corpus_v001",
        Code::UseBeforeDef,
        "reads a register no instruction assigns",
        elementwise(),
        TileProgram {
            name: "corpus_v001",
            regs: 2,
            instrs: vec![
                Instr::Unary { dst: 1, a: 0, op: UnaryOp::Exp },
                Instr::Store { param: 1, src: 1 },
            ],
        },
        ew_tensors(8),
    )?);

    // NT-V002: the accumulator carry is never initialized before the loop
    out.push(case(
        "corpus_v002",
        Code::CarryUninitialized,
        "declares a loop carry without initializing it",
        mm_arrangement(),
        TileProgram {
            name: "corpus_v002",
            regs: 1,
            instrs: vec![
                Instr::Loop {
                    carried: vec![0],
                    body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
                },
                Instr::Store { param: 2, src: 0 },
            ],
        },
        mm_tensors(),
    )?);

    // NT-V003: the body updates a pre-loop register without declaring the
    // carry (the pre-migration implicit-persistence form)
    out.push(case(
        "corpus_v003",
        Code::UndeclaredCarry,
        "overwrites a pre-loop register inside the loop without a carry",
        mm_arrangement(),
        TileProgram {
            name: "corpus_v003",
            regs: 1,
            instrs: vec![
                Instr::Zeros { dst: 0, like_param: 2 },
                Instr::Loop {
                    carried: vec![],
                    body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
                },
                Instr::Store { param: 2, src: 0 },
            ],
        },
        mm_tensors(),
    )?);

    // NT-V004: the carry is read after the loop but no body instruction
    // can ever change it
    let mut app = AppBuilder::new("corpus_v004");
    let acc = app.zeros_like(2);
    app.loop_over(&[acc], |b| {
        let x = b.load(0);
        let r = b.reduce(x, None, ReduceOp::Sum);
        let y = b.binary(acc, r, BinOp::Add);
        b.store(2, y);
    });
    app.store(2, acc);
    out.push(case(
        "corpus_v004",
        Code::CarryNeverAssigned,
        "carries a register the loop body never assigns, then reads it after",
        mm_arrangement(),
        app.build(),
        mm_tensors(),
    )?);

    // NT-V005: a constant is computed and never used
    let mut app = AppBuilder::new("corpus_v005");
    let x = app.load(0);
    let _dead = app.constant(7.0);
    let y = app.unary(x, UnaryOp::Exp);
    app.store(1, y);
    out.push(case(
        "corpus_v005",
        Code::DeadRegister,
        "computes a constant no instruction reads",
        elementwise(),
        app.build(),
        ew_tensors(8),
    )?);

    // NT-V006: the Unary's result is overwritten by the Assign before
    // anything reads it
    let mut app = AppBuilder::new("corpus_v006");
    let x = app.load(0);
    let y = app.unary(x, UnaryOp::Exp);
    app.assign(y, x);
    app.store(1, y);
    out.push(case(
        "corpus_v006",
        Code::DeadStore,
        "overwrites a register before its previous value is read",
        elementwise(),
        app.build(),
        ew_tensors(8),
    )?);

    // NT-V007: transpose of a rank-1 element-wise tile
    let mut app = AppBuilder::new("corpus_v007");
    let x = app.load(0);
    let t = app.transpose(x);
    app.store(1, t);
    out.push(case(
        "corpus_v007",
        Code::RankMismatch,
        "transposes a rank-1 tile",
        elementwise(),
        app.build(),
        ew_tensors(8),
    )?);

    // NT-V008: dot(x, x) on a [1, cols] row tile — inner dims 6 vs 1
    let mut app = AppBuilder::new("corpus_v008");
    let x = app.load(0);
    let d = app.dot(x, x);
    app.store(1, d);
    out.push(case(
        "corpus_v008",
        Code::DotDimMismatch,
        "dot inner dimensions disagree",
        rowwise_arrangement(),
        app.build(),
        rw_tensors(4, 6),
    )?);

    // NT-V009: stores the [1, 1] row max into the [1, cols] output block
    let mut app = AppBuilder::new("corpus_v009");
    let x = app.load(0);
    let m = app.reduce(x, None, ReduceOp::Max);
    app.store(1, m);
    out.push(case(
        "corpus_v009",
        Code::ShapeMismatch,
        "stores a reduced tile into a full-width output block",
        rowwise_arrangement(),
        app.build(),
        rw_tensors(4, 6),
    )?);

    // NT-V010: reduce axis 1 of a rank-1 tile
    let mut app = AppBuilder::new("corpus_v010");
    let x = app.load(0);
    let r = app.reduce(x, Some(1), ReduceOp::Sum);
    app.store(1, r);
    out.push(case(
        "corpus_v010",
        Code::AxisOutOfBounds,
        "reduces along an axis the tile does not have",
        elementwise(),
        app.build(),
        ew_tensors(8),
    )?);

    // NT-V011: split_half along a 7-wide row
    let mut app = AppBuilder::new("corpus_v011");
    let x = app.load(0);
    let (lo, hi) = app.split_half(x, 1);
    let y = app.binary(lo, hi, BinOp::Add);
    app.store(1, y);
    out.push(case(
        "corpus_v011",
        Code::OddSplit,
        "splits an odd extent in half",
        rowwise_arrangement(),
        app.build(),
        rw_tensors(4, 7),
    )?);

    // NT-V012: a row-mixing reduction kernel whose coalesce flag is
    // tampered to true after derivation — the seeded unsound declaration
    let mut app = AppBuilder::new("corpus_v012");
    let x = app.load(0);
    let m = app.reduce(x, None, ReduceOp::Max);
    let y = app.binary(x, m, BinOp::Sub);
    app.store(1, y);
    let mut def = assemble(elementwise(), app.build(), ew_tensors(8))?;
    assert!(!def.coalesce, "derivation must refuse to coalesce a 1-D reduction");
    def.coalesce = true;
    out.push(Case {
        name: "corpus_v012",
        expected: Code::CoalesceUnsound,
        summary: "claims coalesce on a block-wide reduction (tampered flag)",
        report: verify(&def),
    });

    // NT-V013: the same reduction over a *padded* element-wise view with
    // pad 0 — padded lanes can win the max (softmax without its -inf pad)
    let mut app = AppBuilder::new("corpus_v013");
    let x = app.load(0);
    let m = app.reduce(x, None, ReduceOp::Max);
    let y = app.binary(x, m, BinOp::Sub);
    app.store(1, y);
    out.push(case(
        "corpus_v013",
        Code::UnmaskedPadding,
        "max-reduces a padded load whose pad value is not neutral",
        elementwise(),
        app.build(),
        ew_tensors(1000),
    )?);

    Ok(out)
}
