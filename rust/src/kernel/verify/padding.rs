//! Padding-safety taint analysis: flag padded loads that flow into
//! order-sensitive reductions (or matrix products) without an
//! intervening `PadMask`/neutralization — the bug class the sdpa `-1e30`
//! score mask exists to prevent (a padded key row winning the online
//! softmax).
//!
//! Whether a view pads at all is decided concretely at the probe
//! specialization: a parameter **may pad** if any (cell, sub) block maps
//! some coordinate out of range ([`crate::exec::view::ParamView::dense_window`]
//! returns `None`).  The abstract state then tracks what the padded
//! lanes of each register hold:
//!
//! * `Clean` — no padded lanes (unpadded load, or neutralized);
//! * `Uniform(v)` — *every* lane holds `v` (constants, `Zeros`) — what
//!   lets `max(-inf, scores)` and `acc * alpha` stay precise;
//! * `Tainted(Some(v))` — padded lanes hold (approximately) `v`, tracked
//!   numerically through unary/binary arithmetic so `exp(x - 1e30·mask)
//!   = 0` is provable;
//! * `Tainted(None)` — padded lanes hold something unknown.
//!
//! A `Reduce` over a tainted register fires NT-V013 unless the tracked
//! pad value is the reduction's neutral element (`0` for Sum, `≤ -1e29`
//! for Max — the sdpa mask magnitude; Mean has none).  `Dot`/`DotAcc`
//! contract over lanes, so any non-zero taint in an operand fires too.

use crate::exec::ir::{Instr, TileProgram};
use crate::exec::tile::{BinOp, ReduceOp, UnaryOp};
use crate::kernel::Specialization;

use super::{Code, Report, Span};

/// Pad values at or below `-1e29` are treated as mask-magnitude: the
/// sdpa `-1e30` and `-inf` both neutralize a Max.
const MASK_MAG: f32 = 1e29;

#[derive(Debug, Clone, Copy)]
enum PadState {
    Clean,
    Uniform(f32),
    Tainted(Option<f32>),
}

impl PadState {
    /// Bit-exact comparison (NaN-safe) for the loop fixpoint.
    fn same(self, other: PadState) -> bool {
        match (self, other) {
            (PadState::Clean, PadState::Clean) => true,
            (PadState::Uniform(a), PadState::Uniform(b)) => a.to_bits() == b.to_bits(),
            (PadState::Tainted(a), PadState::Tainted(b)) => {
                a.map(f32::to_bits) == b.map(f32::to_bits)
            }
            _ => false,
        }
    }
}

pub(super) fn analyze(program: &TileProgram, spec: &Specialization, report: &mut Report) {
    let may_pad: Vec<bool> = spec.views.iter().map(may_pad).collect();
    let pads: Vec<f32> = spec.views.iter().map(|v| v.pad_value).collect();
    let mut states: Vec<PadState> = vec![PadState::Clean; program.regs];
    for _ in 0..4 {
        let before = states.clone();
        walk(program, &may_pad, &pads, &mut states, None);
        if states.iter().zip(&before).all(|(a, b)| a.same(*b)) {
            break;
        }
    }
    walk(program, &may_pad, &pads, &mut states, Some(report));
}

/// Does any (cell, sub) block of this view read out-of-range (padded)
/// source coordinates at the probe shapes?
fn may_pad(view: &crate::exec::view::ParamView) -> bool {
    let mut cell = vec![0i64; view.grid.len()];
    loop {
        let mut sub = vec![0usize; view.loop_shape.len()];
        loop {
            if view.dense_window(&cell, &sub).is_none() {
                return true;
            }
            if !odometer(&mut sub, &view.loop_shape) {
                break;
            }
        }
        let mut done = true;
        for d in (0..cell.len()).rev() {
            cell[d] += 1;
            if cell[d] < view.grid[d] {
                done = false;
                break;
            }
            cell[d] = 0;
        }
        if done {
            return false;
        }
    }
}

fn odometer(coords: &mut [usize], shape: &[usize]) -> bool {
    for d in (0..coords.len()).rev() {
        coords[d] += 1;
        if coords[d] < shape[d] {
            return true;
        }
        coords[d] = 0;
    }
    false
}

fn walk(
    program: &TileProgram,
    may_pad: &[bool],
    pads: &[f32],
    states: &mut [PadState],
    mut report: Option<&mut Report>,
) {
    for (i, instr) in program.instrs.iter().enumerate() {
        if let Instr::Loop { body, .. } = instr {
            for (j, instr) in body.iter().enumerate() {
                step(instr, Span::body(i, j), may_pad, pads, states, report.as_deref_mut());
            }
        } else {
            step(instr, Span::top(i), may_pad, pads, states, report.as_deref_mut());
        }
    }
}

fn step(
    instr: &Instr,
    span: Span,
    may_pad: &[bool],
    pads: &[f32],
    states: &mut [PadState],
    mut report: Option<&mut Report>,
) {
    use PadState::{Clean, Tainted, Uniform};
    let mut diag = |message: String| {
        if let Some(r) = report.as_deref_mut() {
            r.push(Code::UnmaskedPadding, Some(span), message);
        }
    };
    match instr {
        Instr::Load { dst, param } => {
            states[*dst] = if may_pad[*param] { Tainted(Some(pads[*param])) } else { Clean };
        }
        Instr::PadMask { dst, like_param, value } => {
            // in-range lanes hold 0, padded lanes hold `value`
            states[*dst] = if may_pad[*like_param] { Tainted(Some(*value)) } else { Uniform(0.0) };
        }
        Instr::Zeros { dst, .. } => states[*dst] = Uniform(0.0),
        Instr::Const { dst, value } => states[*dst] = Uniform(*value),
        Instr::BlockDim { dst, .. } => states[*dst] = Clean,
        Instr::Unary { dst, a, op } => {
            states[*dst] = match states[*a] {
                Clean => Clean,
                Uniform(v) => Uniform(apply1(*op, v)),
                Tainted(Some(v)) => {
                    let r = apply1(*op, v);
                    Tainted(if r.is_nan() { None } else { Some(r) })
                }
                Tainted(None) => Tainted(None),
            };
        }
        Instr::Binary { dst, a, b, op } => {
            states[*dst] = binary(*op, states[*a], states[*b]);
        }
        Instr::Reduce { dst, a, op, .. } => {
            states[*dst] = match states[*a] {
                Clean => Clean,
                Uniform(v) => match op {
                    ReduceOp::Max | ReduceOp::Mean => Uniform(v),
                    ReduceOp::Sum if v == 0.0 => Uniform(0.0),
                    ReduceOp::Sum => Clean,
                },
                Tainted(Some(v)) if neutral(*op, v) => Tainted(Some(v)),
                Tainted(v) => {
                    diag(format!(
                        "{op:?} reduction over a tile whose padded lanes hold {} — \
                         neutralize them first (PadMask, or declare the right pad value)",
                        describe(v)
                    ));
                    Clean
                }
            };
        }
        Instr::Dot { dst, a, b } => {
            let mut tainted_zero = false;
            for &r in &[*a, *b] {
                match states[r] {
                    Tainted(Some(v)) if v == 0.0 => tainted_zero = true,
                    Tainted(v) => {
                        diag(format!(
                            "dot contracts over lanes whose padded values hold {} — only \
                             zero-padded operands contribute nothing to the product",
                            describe(v)
                        ));
                    }
                    _ => {}
                }
            }
            states[*dst] = if tainted_zero { Tainted(Some(0.0)) } else { Clean };
        }
        Instr::DotAcc { acc, a_param, b_param } => {
            let mut any_pad = false;
            for &p in &[*a_param, *b_param] {
                if may_pad[p] {
                    any_pad = true;
                    if pads[p] != 0.0 {
                        diag(format!(
                            "dot_acc contracts over parameter {p} whose pad value is {} — \
                             only zero padding contributes nothing to the product",
                            pads[p]
                        ));
                    }
                }
            }
            // zero-padded lanes contribute nothing, but the accumulator
            // rows covering padded output rows are no longer pristine
            if any_pad {
                if let Clean | Uniform(_) = states[*acc] {
                    states[*acc] = Tainted(Some(0.0));
                }
            }
        }
        Instr::Broadcast { dst, a, .. } | Instr::Transpose { dst, a } => {
            states[*dst] = states[*a];
        }
        Instr::Assign { dst, src } => states[*dst] = states[*src],
        Instr::SplitHalf { lo, hi, a, .. } => {
            states[*lo] = states[*a];
            states[*hi] = states[*a];
        }
        Instr::Concat { dst, a, b, .. } => {
            states[*dst] = match (states[*a], states[*b]) {
                (Clean, Clean) => Clean,
                (Uniform(x), Uniform(y)) if x.to_bits() == y.to_bits() => Uniform(x),
                (Tainted(Some(x)), Tainted(Some(y))) if x.to_bits() == y.to_bits() => {
                    Tainted(Some(x))
                }
                (Clean | Uniform(_), Clean | Uniform(_)) => Clean,
                _ => Tainted(None),
            };
        }
        Instr::Store { .. } | Instr::Loop { .. } => {}
    }
}

/// Is `v` the neutral element of `op` — a pad value that cannot affect
/// the reduction?
fn neutral(op: ReduceOp, v: f32) -> bool {
    match op {
        ReduceOp::Sum => v == 0.0,
        ReduceOp::Max => v <= -MASK_MAG,
        ReduceOp::Mean => false,
    }
}

fn describe(v: Option<f32>) -> String {
    match v {
        Some(v) => format!("{v}"),
        None => "an unknown value".to_string(),
    }
}

fn apply1(op: UnaryOp, v: f32) -> f32 {
    match op {
        UnaryOp::Exp => v.exp(),
        UnaryOp::Neg => -v,
        UnaryOp::Rsqrt => 1.0 / v.sqrt(),
        UnaryOp::Sigmoid => 1.0 / (1.0 + (-v).exp()),
    }
}

fn apply2(op: BinOp, a: f32, b: f32) -> f32 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
        BinOp::Max => a.max(b),
    }
}

fn binary(op: BinOp, a: PadState, b: PadState) -> PadState {
    use PadState::{Clean, Tainted, Uniform};
    match (a, b) {
        (Clean, Clean) | (Clean, Uniform(_)) | (Uniform(_), Clean) => Clean,
        (Uniform(x), Uniform(y)) => Uniform(apply2(op, x, y)),
        (Tainted(None), _) | (_, Tainted(None)) => Tainted(None),
        // a uniform operand holds its value on *every* lane, so it pairs
        // exactly with the other operand's padded lanes
        (Tainted(Some(x)), Uniform(u)) => tainted(apply2(op, x, u)),
        (Uniform(u), Tainted(Some(x))) => tainted(apply2(op, u, x)),
        // two tainted operands need not pad the same lanes (a reduced-
        // and-rebroadcast tile holds per-row data on its in-range side),
        // so mixing tracked values is only sound when one dominates: a
        // mask-magnitude value swallows Add/Sub and loses every Max
        (Tainted(Some(x)), Tainted(Some(y))) => {
            if x <= -MASK_MAG || y <= -MASK_MAG {
                dominated(op, x, y)
            } else {
                tainted(apply2(op, x, y))
            }
        }
        (Tainted(Some(x)), Clean) => taint_with_clean(op, x, true),
        (Clean, Tainted(Some(x))) => taint_with_clean(op, x, false),
    }
}

fn tainted(r: f32) -> PadState {
    PadState::Tainted(if r.is_nan() { None } else { Some(r) })
}

/// One side of a `Tainted ⊗ Tainted` is mask-magnitude (`≤ -1e29`): it
/// swallows Add, survives/flips Sub depending on its side, and always
/// loses a Max.
fn dominated(op: BinOp, x: f32, y: f32) -> PadState {
    use PadState::Tainted;
    match op {
        BinOp::Add => Tainted(Some(x.min(y))),
        BinOp::Sub => {
            if x <= -MASK_MAG {
                Tainted(Some(x))
            } else {
                // x - (-1e30) explodes positive — track the sign so a
                // later Max cannot be mistaken for neutral
                Tainted(Some(-y))
            }
        }
        BinOp::Max => Tainted(Some(x.max(y))),
        BinOp::Mul | BinOp::Div => Tainted(None),
    }
}

/// `Tainted ⊗ Clean`: the clean operand's lane values are unknown, so
/// only value-independent identities stay precise.
fn taint_with_clean(op: BinOp, x: f32, taint_left: bool) -> PadState {
    use PadState::Tainted;
    match op {
        BinOp::Mul if x == 0.0 => Tainted(Some(0.0)),
        BinOp::Add if x.abs() >= MASK_MAG => Tainted(Some(x)),
        BinOp::Sub if taint_left && x.abs() >= MASK_MAG => Tainted(Some(x)),
        BinOp::Sub if !taint_left && x.abs() >= MASK_MAG => Tainted(Some(-x)),
        _ => Tainted(None),
    }
}
