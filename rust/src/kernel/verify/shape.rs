//! Symbolic shape abstract interpretation: propagate per-register block
//! shapes through every instruction at the probe specialization,
//! mirroring the `exec::tile::Tile` op semantics exactly — so a Dot
//! inner-dim disagreement or an odd SplitHalf surfaces at `make` time
//! instead of as a runtime error in the first specialized launch.
//!
//! Codes: NT-V007 (Dot/Transpose rank), NT-V008 (Dot/DotAcc dims),
//! NT-V009 (Binary/Broadcast/Concat/Store compatibility), NT-V010 (axis
//! bounds), NT-V011 (odd SplitHalf extent).
//!
//! An instruction whose inputs are unknown (an earlier finding poisoned
//! them) produces an unknown output instead of cascading; loop bodies are
//! interpreted to a carry-shape fixpoint before findings are recorded.

use crate::exec::ir::{Instr, TileProgram};
use crate::kernel::Specialization;

use super::{Code, Report, Span};

type Shape = Option<Vec<usize>>;

pub(super) fn analyze(program: &TileProgram, spec: &Specialization, report: &mut Report) {
    let blocks: Vec<&[usize]> = spec.views.iter().map(|v| v.block_shape.as_slice()).collect();
    let mut shapes: Vec<Shape> = vec![None; program.regs];
    // silent passes to the loop-carry fixpoint (carry shapes stabilize in
    // at most a few iterations — sdpa's scalar-to-row promotion takes 2)
    for _ in 0..4 {
        let before = shapes.clone();
        walk(program, &blocks, &mut shapes, None);
        if shapes == before {
            break;
        }
    }
    walk(program, &blocks, &mut shapes, Some(report));
}

fn walk(
    program: &TileProgram,
    blocks: &[&[usize]],
    shapes: &mut [Shape],
    mut report: Option<&mut Report>,
) {
    for (i, instr) in program.instrs.iter().enumerate() {
        if let Instr::Loop { body, .. } = instr {
            for (j, instr) in body.iter().enumerate() {
                step(instr, Span::body(i, j), blocks, shapes, report.as_deref_mut());
            }
        } else {
            step(instr, Span::top(i), blocks, shapes, report.as_deref_mut());
        }
    }
}

fn step(
    instr: &Instr,
    span: Span,
    blocks: &[&[usize]],
    shapes: &mut [Shape],
    mut report: Option<&mut Report>,
) {
    let mut diag = |code: Code, message: String| {
        if let Some(r) = report.as_deref_mut() {
            r.push(code, Some(span), message);
        }
    };
    match instr {
        Instr::Load { dst, param } | Instr::Zeros { dst, like_param: param } => {
            shapes[*dst] = Some(blocks[*param].to_vec());
        }
        Instr::Const { dst, .. } => shapes[*dst] = Some(vec![1]),
        Instr::PadMask { dst, like_param, .. } => {
            shapes[*dst] = Some(blocks[*like_param].to_vec());
        }
        Instr::BlockDim { dst, param, axis } => {
            if *axis >= blocks[*param].len() {
                diag(
                    Code::AxisOutOfBounds,
                    format!(
                        "block_dim axis {axis} out of range for parameter {param} \
                         (block {:?})",
                        blocks[*param]
                    ),
                );
                shapes[*dst] = None;
            } else {
                shapes[*dst] = Some(vec![1]);
            }
        }
        Instr::Unary { dst, a, .. } => shapes[*dst] = shapes[*a].clone(),
        Instr::Assign { dst, src } => shapes[*dst] = shapes[*src].clone(),
        Instr::Binary { dst, a, b, .. } => {
            shapes[*dst] = match (&shapes[*a], &shapes[*b]) {
                (Some(sa), Some(sb)) => match broadcast(sa, sb) {
                    Some(s) => Some(s),
                    None => {
                        diag(
                            Code::ShapeMismatch,
                            format!("binary operands {sa:?} and {sb:?} do not broadcast"),
                        );
                        None
                    }
                },
                _ => None,
            };
        }
        Instr::Reduce { dst, a, axis, .. } => {
            shapes[*dst] = match &shapes[*a] {
                Some(sa) => match axis {
                    Some(ax) if *ax >= sa.len() => {
                        diag(
                            Code::AxisOutOfBounds,
                            format!("reduce axis {ax} out of range for tile {sa:?}"),
                        );
                        None
                    }
                    Some(ax) => {
                        let mut s = sa.clone();
                        s[*ax] = 1;
                        Some(s)
                    }
                    None => Some(vec![1; sa.len()]),
                },
                None => None,
            };
        }
        Instr::Dot { dst, a, b } => {
            shapes[*dst] = match (&shapes[*a], &shapes[*b]) {
                (Some(sa), Some(sb)) => {
                    if sa.len() != 2 || sb.len() != 2 {
                        diag(
                            Code::RankMismatch,
                            format!("dot needs rank-2 tiles, got {sa:?} x {sb:?}"),
                        );
                        None
                    } else if sa[1] != sb[0] {
                        diag(
                            Code::DotDimMismatch,
                            format!("dot inner dims disagree: {sa:?} x {sb:?}"),
                        );
                        None
                    } else {
                        Some(vec![sa[0], sb[1]])
                    }
                }
                _ => None,
            };
        }
        Instr::DotAcc { acc, a_param, b_param } => {
            let (sa, sb) = (blocks[*a_param], blocks[*b_param]);
            if sa.len() != 2 || sb.len() != 2 {
                diag(
                    Code::RankMismatch,
                    format!("dot_acc needs rank-2 parameter blocks, got {sa:?} x {sb:?}"),
                );
                shapes[*acc] = None;
            } else if sa[1] != sb[0] {
                diag(
                    Code::DotDimMismatch,
                    format!("dot_acc inner dims disagree: {sa:?} x {sb:?}"),
                );
                shapes[*acc] = None;
            } else {
                let want = vec![sa[0], sb[1]];
                if let Some(got) = &shapes[*acc] {
                    if *got != want {
                        diag(
                            Code::DotDimMismatch,
                            format!("dot_acc accumulator is {got:?}, product is {want:?}"),
                        );
                    }
                }
                shapes[*acc] = Some(want);
            }
        }
        Instr::Broadcast { dst, a, like_param } => {
            let target = blocks[*like_param];
            shapes[*dst] = match &shapes[*a] {
                Some(sa) => match broadcast(sa, target) {
                    Some(s) if s == target => Some(s),
                    _ => {
                        diag(
                            Code::ShapeMismatch,
                            format!("tile {sa:?} does not broadcast to block {target:?}"),
                        );
                        None
                    }
                },
                None => None,
            };
        }
        Instr::Transpose { dst, a } => {
            shapes[*dst] = match &shapes[*a] {
                Some(sa) if sa.len() == 2 => Some(vec![sa[1], sa[0]]),
                Some(sa) => {
                    diag(Code::RankMismatch, format!("transpose needs a rank-2 tile, got {sa:?}"));
                    None
                }
                None => None,
            };
        }
        Instr::SplitHalf { lo, hi, a, axis } => {
            let half = match &shapes[*a] {
                Some(sa) if *axis >= sa.len() => {
                    diag(
                        Code::AxisOutOfBounds,
                        format!("split axis {axis} out of range for tile {sa:?}"),
                    );
                    None
                }
                Some(sa) if sa[*axis] % 2 != 0 => {
                    diag(
                        Code::OddSplit,
                        format!("split_half along axis {axis} of {sa:?}: extent is odd"),
                    );
                    None
                }
                Some(sa) => {
                    let mut s = sa.clone();
                    s[*axis] /= 2;
                    Some(s)
                }
                None => None,
            };
            shapes[*lo] = half.clone();
            shapes[*hi] = half;
        }
        Instr::Concat { dst, a, b, axis } => {
            shapes[*dst] = match (&shapes[*a], &shapes[*b]) {
                (Some(sa), Some(sb)) => {
                    if *axis >= sa.len() {
                        diag(
                            Code::AxisOutOfBounds,
                            format!("concat axis {axis} out of range for tile {sa:?}"),
                        );
                        None
                    } else if sa.len() != sb.len()
                        || (0..sa.len()).any(|d| d != *axis && sa[d] != sb[d])
                    {
                        diag(
                            Code::ShapeMismatch,
                            format!("concat along axis {axis}: {sa:?} and {sb:?} disagree \
                                     off-axis"),
                        );
                        None
                    } else {
                        let mut s = sa.clone();
                        s[*axis] += sb[*axis];
                        Some(s)
                    }
                }
                _ => None,
            };
        }
        Instr::Store { param, src } => {
            if let Some(s) = &shapes[*src] {
                if s.as_slice() != blocks[*param] {
                    diag(
                        Code::ShapeMismatch,
                        format!(
                            "store of tile {s:?} into parameter {param} with block {:?}",
                            blocks[*param]
                        ),
                    );
                }
            }
        }
        Instr::Loop { .. } => {}
    }
}

/// NumPy-style right-aligned broadcast, mirroring `Tile::broadcast_shape`.
fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        if da != db && da != 1 && db != 1 {
            return None;
        }
        out[i] = da.max(db);
    }
    Some(out)
}
