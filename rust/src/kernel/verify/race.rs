//! Coalescibility race audit: an independent re-derivation of
//! row-independence from the *lowered* probe views, checked against the
//! `coalesce` flag `KernelDef::derive` computed at `make` time.
//!
//! The batcher's coalescer stacks same-shape requests along dim 0 and
//! runs one launch; that is bit-identical to per-request execution only
//! if no program instance reads or reduces across the stacking boundary.
//! This audit re-proves that from the view access profiles alone:
//!
//! * dim 0 of every parameter must be partitioned by exactly one common
//!   grid axis — any loop-level motion along dim 0 (`sub_span != 0`)
//!   means a carried reduction walks the stacked rows, and a cell stride
//!   smaller than the block's dim-0 footprint means neighbouring
//!   programs overlap rows;
//! * that axis must drive no other source dimension (a cross-row gather
//!   like mm's k-loop reads *other* requests' rows after stacking);
//! * if one tile covers several stacked rows (1-D element-wise blocks),
//!   every instruction must be row-local — a reduction or dot would
//!   regroup rows that stacking re-partitioned.
//!
//! The audit deliberately re-implements the view-level reasoning instead
//! of calling `derive_stackable` — it is the check *on* that derivation.
//! `derive` additionally requires symbol-level conditions (a shared dim-0
//! size symbol appearing nowhere else), so `derive ⇒ audit`; the reverse
//! direction is allowed to disagree (the audit being more permissive is
//! safe) and only `coalesce && !audit` — unsound stacking — is a finding
//! (NT-V012).

use crate::exec::ir::Instr;
use crate::kernel::{KernelDef, Specialization};

use super::{Code, Report};

pub(super) fn analyze(def: &KernelDef, spec: &Specialization, report: &mut Report) {
    if def.coalesce && !stackable(def, spec) {
        report.push(
            Code::CoalesceUnsound,
            None,
            "declaration claims coalesce (same-shape requests stacked along dim 0) but \
             the race audit finds cross-row access or an order-sensitive reduction over \
             the stacked dim — batching would corrupt replies"
                .to_string(),
        );
    }
}

/// The audit's own verdict: may same-shape requests be stacked along
/// dim 0 into one launch?
pub(super) fn stackable(def: &KernelDef, spec: &Specialization) -> bool {
    let mut stack_axis: Option<usize> = None;
    let mut tile_spans_rows = false;
    for view in &spec.views {
        let (cell, sub_span, inner_span) = view.dim_profile(0);
        if sub_span != 0 {
            return false;
        }
        let driving: Vec<usize> =
            cell.iter().enumerate().filter(|(_, &c)| c != 0).map(|(g, _)| g).collect();
        let axis = match driving.as_slice() {
            &[axis] => axis,
            _ => return false,
        };
        if *stack_axis.get_or_insert(axis) != axis {
            return false;
        }
        // adjacent cells must own disjoint row ranges
        if cell[axis].abs() < 1 + inner_span {
            return false;
        }
        if inner_span > 0 {
            tile_spans_rows = true;
        }
        // the stacking axis must steer no other source dim
        for d in 1..view.src_shape.len() {
            let (cell_d, _, _) = view.dim_profile(d);
            if cell_d.get(axis).copied().unwrap_or(0) != 0 {
                return false;
            }
        }
    }
    if stack_axis.is_none() {
        return false;
    }
    if tile_spans_rows && !row_local(&def.program.instrs) {
        return false;
    }
    true
}

/// Every output lane computed from the same lane of its inputs: the only
/// instruction set safe when one tile covers several stacked rows.
fn row_local(instrs: &[Instr]) -> bool {
    instrs.iter().all(|i| {
        matches!(
            i,
            Instr::Load { .. }
                | Instr::Const { .. }
                | Instr::Unary { .. }
                | Instr::Binary { .. }
                | Instr::Assign { .. }
                | Instr::Store { .. }
        )
    })
}
