//! Declaration-time static analysis over [`KernelDef`] — the checker
//! behind [`super::make`]'s hard gate, [`super::KernelRegistry::register`]'s
//! re-check and the `repro lint` CLI.
//!
//! The paper's promise is that a *serial* tile declaration can be
//! transformed into parallel code automatically **and safely**.  That
//! transformation carries safety obligations the runtime used to discover
//! one panic at a time: carries must be initialized, tile ops must be
//! shape-consistent, batch stacking must not reorder a reduction, padded
//! loads must be neutralized before they reach one.  This module checks
//! all of them statically, at `make`/registration time, with four
//! analyses:
//!
//! * `dataflow` — register liveness over the whole program:
//!   use-before-def, uninitialized / undeclared / never-assigned loop
//!   carries, dead registers and dead stores (`NT-V001`–`NT-V006`);
//! * `shape` — abstract interpretation of per-register block shapes
//!   through every instruction, mirroring the `Tile` op semantics
//!   (`NT-V007`–`NT-V011`), so a Dot inner-dim mismatch surfaces at
//!   `make` time instead of at the first specialization;
//! * `race` — an independent coalescibility audit re-deriving
//!   row-independence from the lowered views; it must agree with the
//!   derived `coalesce` flag, and flags the unsound direction
//!   (`NT-V012`);
//! * `padding` — taint analysis of pad values through the program,
//!   flagging padded loads that flow into order-sensitive reductions
//!   without `PadMask`/neutralization (`NT-V013`, the bug class the sdpa
//!   `-1e30` mask exists to prevent).
//!
//! Findings carry stable [`Code`]s with instruction-level [`Span`]s.
//! `Error`-severity findings make [`super::make`] and registration fail;
//! `Warning`s pass `make` but fail `repro lint` (and CI).  Every code is
//! documented with a minimal broken declaration in `docs/diagnostics.md`,
//! and [`corpus`] keeps those declarations executable as the negative
//! test corpus.

mod dataflow;
mod padding;
mod race;
mod shape;

pub mod corpus;

use std::fmt;

use super::{KernelDef, Specialization};

/// Stable diagnostic codes.  The `NT-V*` string form is the public
/// contract: tests, docs and CI grep for it, so variants are append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Code {
    /// NT-V001 — a register is read before any instruction assigns it.
    UseBeforeDef,
    /// NT-V002 — a loop-carried register is not initialized before its
    /// loop.
    CarryUninitialized,
    /// NT-V003 — a loop body overwrites a pre-loop register without
    /// declaring it as a carry (undeclared cross-iteration persistence).
    UndeclaredCarry,
    /// NT-V004 — a carry is read after the loop but the body never
    /// assigns it: the loop cannot change it, so either the carry or the
    /// post-loop read is a mistake.
    CarryNeverAssigned,
    /// NT-V005 — a register is written but never read anywhere.
    DeadRegister,
    /// NT-V006 — a register is overwritten before its previous value is
    /// read (dead store).
    DeadStore,
    /// NT-V007 — Dot/Transpose applied to a tile that is not rank-2.
    RankMismatch,
    /// NT-V008 — Dot/DotAcc operand inner dimensions (or the accumulator
    /// shape) disagree.
    DotDimMismatch,
    /// NT-V009 — incompatible shapes in Binary/Broadcast/Concat, or a
    /// Store whose tile does not match the output block.
    ShapeMismatch,
    /// NT-V010 — Reduce/BlockDim/SplitHalf/Concat axis out of bounds.
    AxisOutOfBounds,
    /// NT-V011 — SplitHalf along an odd extent.
    OddSplit,
    /// NT-V012 — the declaration claims `coalesce` but the independent
    /// race audit proves stacking would mix rows (unsound batching).
    CoalesceUnsound,
    /// NT-V013 — a padded load flows into an order-sensitive reduction
    /// (or a matrix product) without PadMask/neutralization.
    UnmaskedPadding,
}

impl Code {
    /// The stable wire/doc form, e.g. `"NT-V001"`.
    pub fn as_str(self) -> &'static str {
        match self {
            Code::UseBeforeDef => "NT-V001",
            Code::CarryUninitialized => "NT-V002",
            Code::UndeclaredCarry => "NT-V003",
            Code::CarryNeverAssigned => "NT-V004",
            Code::DeadRegister => "NT-V005",
            Code::DeadStore => "NT-V006",
            Code::RankMismatch => "NT-V007",
            Code::DotDimMismatch => "NT-V008",
            Code::ShapeMismatch => "NT-V009",
            Code::AxisOutOfBounds => "NT-V010",
            Code::OddSplit => "NT-V011",
            Code::CoalesceUnsound => "NT-V012",
            Code::UnmaskedPadding => "NT-V013",
        }
    }

    /// Definite violations are errors ([`make`](super::make) rejects);
    /// suspicious-but-runnable declarations are warnings (`repro lint`
    /// still fails on them, so nothing ships dirty).
    pub fn severity(self) -> Severity {
        match self {
            Code::UseBeforeDef
            | Code::CarryUninitialized
            | Code::UndeclaredCarry
            | Code::RankMismatch
            | Code::DotDimMismatch
            | Code::ShapeMismatch
            | Code::AxisOutOfBounds
            | Code::OddSplit
            | Code::CoalesceUnsound => Severity::Error,
            Code::CarryNeverAssigned
            | Code::DeadRegister
            | Code::DeadStore
            | Code::UnmaskedPadding => Severity::Warning,
        }
    }
}

impl fmt::Display for Code {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// Instruction-level location: index in the top-level instruction list,
/// plus the index inside a loop body when the finding is in one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Span {
    pub outer: usize,
    pub inner: Option<usize>,
}

impl Span {
    pub fn top(outer: usize) -> Span {
        Span { outer, inner: None }
    }

    pub fn body(outer: usize, inner: usize) -> Span {
        Span { outer, inner: Some(inner) }
    }
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.inner {
            Some(i) => write!(f, "#{}.{i}", self.outer),
            None => write!(f, "#{}", self.outer),
        }
    }
}

/// One finding: stable code, derived severity, instruction span, and a
/// human-readable message.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub code: Code,
    pub severity: Severity,
    pub span: Option<Span>,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.code, self.severity)?;
        if let Some(span) = self.span {
            write!(f, " at {span}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// The result of verifying one declaration.
#[derive(Debug, Clone)]
pub struct Report {
    /// kernel name the report is about
    pub kernel: String,
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    fn new(kernel: &str) -> Report {
        Report { kernel: kernel.to_string(), diagnostics: Vec::new() }
    }

    /// Record a finding, deduplicating by `(code, span)` — the shape
    /// fixpoint and the twice-walked loop body would otherwise repeat
    /// themselves.
    fn push(&mut self, code: Code, span: Option<Span>, message: String) {
        if self.diagnostics.iter().any(|d| d.code == code && d.span == span) {
            return;
        }
        self.diagnostics.push(Diagnostic { code, severity: code.severity(), span, message });
    }

    /// No findings at all.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Any `Error`-severity finding (what makes `make`/register fail).
    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    /// The distinct codes that fired, sorted.
    pub fn codes(&self) -> Vec<Code> {
        let mut codes: Vec<Code> = self.diagnostics.iter().map(|d| d.code).collect();
        codes.sort();
        codes.dedup();
        codes
    }

    /// All findings, one per line — the body of `make`/register errors
    /// and of the `repro lint` table.
    pub fn render(&self) -> String {
        self.diagnostics
            .iter()
            .map(|d| format!("  {d}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Run all four analyses over a declaration.
///
/// The dataflow pass needs only the program; the shape, race and padding
/// passes interpret the *lowered* probe specialization, so they are
/// skipped for non-executable declarations (conv2d's implicit-GEMM
/// arrangement does not lower to affine views — its diagnosis is the
/// probe error itself, surfaced by [`lowerability`]).
pub fn verify(def: &KernelDef) -> Report {
    let mut report = Report::new(&def.name);
    dataflow::analyze(&def.program, &mut report);
    if let Some(spec) = probe_spec(def) {
        shape::analyze(&def.program, &spec, &mut report);
        race::analyze(def, &spec, &mut report);
        padding::analyze(&def.program, &spec, &mut report);
    }
    report
}

/// The independent coalescibility verdict for a declaration, from the
/// `race` analysis alone (`None` when the declaration does not lower at
/// its probe shapes).  Exposed so tests can assert the audit agrees with
/// the derived `coalesce` flag for every registered kernel.
pub fn race_audit(def: &KernelDef) -> Option<bool> {
    probe_spec(def).map(|spec| race::stackable(def, &spec))
}

/// Why a registered declaration is not natively executable, in the short
/// form `repro kernels` and `repro lint` print (`None` for executable
/// kernels).
pub fn lowerability(def: &KernelDef) -> Option<String> {
    if def.executable() {
        return None;
    }
    match def.probe_error() {
        Some(e) if e.contains("is not affine") => {
            Some("non-affine indexing not lowerable".to_string())
        }
        Some(e) => Some(format!("probe specialization failed: {e}")),
        None => Some("probe specialization failed".to_string()),
    }
}

/// The probe-shape specialization the view-level analyses interpret —
/// the same lowering `KernelDef::derive` ran at `make` time.
fn probe_spec(def: &KernelDef) -> Option<Specialization> {
    let probe = def.probe_dims().ok()?;
    let mut shapes: Vec<Vec<usize>> = Vec::with_capacity(def.tensors.len());
    for spec in &def.tensors {
        let mut s = Vec::with_capacity(spec.dims.len());
        for ds in &spec.dims {
            match ds.eval(&probe) {
                Ok(v) if v > 0 => s.push(v as usize),
                _ => return None,
            }
        }
        shapes.push(s);
    }
    def.specialize_with(&probe, &shapes).ok()
}
