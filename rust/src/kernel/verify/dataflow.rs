//! Register dataflow: the whole-program generalization of the carry
//! rules that used to live only inside `TileProgram::validate`.
//!
//! A declaration is a straight-line prefix, at most non-nested loops, and
//! a straight-line suffix; the interpreter clears body-local registers
//! after every iteration.  This pass walks that structure once and
//! reports, instead of bailing at the first violation:
//!
//! * NT-V001 — read of a register nothing has assigned (including reads
//!   of body-locals at the top of the next iteration);
//! * NT-V002 — loop carry not initialized before the loop;
//! * NT-V003 — body overwrites a pre-loop register it did not declare as
//!   a carry;
//! * NT-V004 — carry read *after* the loop that the body never assigns
//!   (the loop cannot change it — previously unchecked);
//! * NT-V005 — register written but never read anywhere;
//! * NT-V006 — register overwritten before its previous value is read
//!   (dead store).  Loop bodies are walked twice so a carry overwritten
//!   every iteration without an intervening read is caught; body-locals
//!   are exempt at the iteration boundary (the interpreter clears them —
//!   that is discard, not overwrite).

use std::collections::{BTreeMap, BTreeSet};

use crate::exec::ir::{Instr, Reg, TileProgram};

use super::{Code, Report, Span};

pub(super) fn analyze(program: &TileProgram, report: &mut Report) {
    let mut census = Census::new(program.regs);
    census.walk(&program.instrs, None);
    for r in 0..program.regs {
        if census.written[r] && !census.read[r] {
            report.push(
                Code::DeadRegister,
                census.first_write[r],
                format!("register {r} is written but never read"),
            );
        }
    }

    let mut state = Flow { init: BTreeSet::new(), pending: BTreeMap::new() };
    for (i, instr) in program.instrs.iter().enumerate() {
        if let Instr::Loop { carried, body } = instr {
            analyze_loop(i, carried, body, &mut state, &program.instrs[i + 1..], report);
        } else {
            state.step(instr, Span::top(i), false, report);
        }
    }
}

/// Global read/write census (loop bodies included) for NT-V005.
struct Census {
    read: Vec<bool>,
    written: Vec<bool>,
    first_write: Vec<Option<Span>>,
}

impl Census {
    fn new(regs: usize) -> Census {
        Census { read: vec![false; regs], written: vec![false; regs], first_write: vec![None; regs] }
    }

    fn walk(&mut self, instrs: &[Instr], outer: Option<usize>) {
        for (i, instr) in instrs.iter().enumerate() {
            if let Instr::Loop { body, .. } = instr {
                self.walk(body, Some(i));
                continue;
            }
            let span = match outer {
                Some(o) => Span::body(o, i),
                None => Span::top(i),
            };
            let (reads, writes, _) = instr.effects();
            for r in reads {
                if r < self.read.len() {
                    self.read[r] = true;
                }
            }
            for w in writes {
                if w < self.written.len() {
                    self.written[w] = true;
                    self.first_write[w].get_or_insert(span);
                }
            }
        }
    }
}

/// Straight-line state: which registers hold a value, and which hold a
/// value no instruction has read yet (dead-store candidates).
struct Flow {
    init: BTreeSet<Reg>,
    pending: BTreeMap<Reg, Span>,
}

impl Flow {
    fn step(&mut self, instr: &Instr, span: Span, in_loop: bool, report: &mut Report) {
        let (reads, writes, _) = instr.effects();
        for r in reads {
            if !self.init.contains(&r) {
                report.push(
                    Code::UseBeforeDef,
                    Some(span),
                    format!(
                        "register {r} is read before it is assigned{}",
                        if in_loop {
                            " (iteration-local values do not persist across loop \
                             iterations — declare a loop carry)"
                        } else {
                            ""
                        }
                    ),
                );
                // report once, then treat as assigned so one missing def
                // does not cascade into a finding per downstream read
                self.init.insert(r);
            }
            self.pending.remove(&r);
        }
        for w in writes {
            if let Some(prev) = self.pending.insert(w, span) {
                report.push(
                    Code::DeadStore,
                    Some(span),
                    format!(
                        "register {w} is overwritten before the value assigned at {prev} \
                         is read"
                    ),
                );
            }
            self.init.insert(w);
        }
    }
}

fn analyze_loop(
    outer: usize,
    carried: &[Reg],
    body: &[Instr],
    state: &mut Flow,
    rest: &[Instr],
    report: &mut Report,
) {
    let loop_span = Span::top(outer);
    for &c in carried {
        if !state.init.contains(&c) {
            report.push(
                Code::CarryUninitialized,
                Some(loop_span),
                format!("loop-carried register {c} must be initialized before the loop"),
            );
            // suppress the cascading NT-V001 on the body's reads of it
            state.init.insert(c);
        }
    }
    let mut carried_set: BTreeSet<Reg> = carried.iter().copied().collect();
    let pre = state.init.clone();
    let mut body_writes: Vec<Reg> = Vec::new();
    for (j, instr) in body.iter().enumerate() {
        // nested loops are a structural error caught before verification
        let (_, writes, _) = instr.effects();
        for &w in &writes {
            if pre.contains(&w) && !carried_set.contains(&w) {
                report.push(
                    Code::UndeclaredCarry,
                    Some(Span::body(outer, j)),
                    format!(
                        "register {w} is assigned inside the loop but initialized outside \
                         it — declare it as a loop carry"
                    ),
                );
                // repair: analyze the rest of the loop as if the carry
                // were declared, so the same mistake does not cascade
                // into cross-iteration NT-V001s
                carried_set.insert(w);
            }
        }
        body_writes.extend(writes);
    }
    body_writes.sort_unstable();
    body_writes.dedup();

    // NT-V004: a carry the body can never change, read after the loop
    for &c in carried {
        if body_writes.contains(&c) {
            continue;
        }
        if reads_after(rest, c) {
            report.push(
                Code::CarryNeverAssigned,
                Some(loop_span),
                format!(
                    "loop-carried register {c} is read after the loop but no body \
                     instruction assigns it — the loop cannot change it (drop the carry \
                     or assign it in the body)"
                ),
            );
        }
    }

    // walk the body as iteration 1, clear the locals, then iteration 2 —
    // the second pass sees carries as the previous iteration left them,
    // catching cross-iteration use-before-def and carry dead stores
    let locals: Vec<Reg> =
        body_writes.iter().copied().filter(|r| !carried_set.contains(r)).collect();
    for _ in 0..2 {
        for (j, instr) in body.iter().enumerate() {
            state.step(instr, Span::body(outer, j), true, report);
        }
        for &r in &locals {
            state.init.remove(&r);
            // the interpreter clears body-locals between iterations:
            // their unread values are discarded, not overwritten
            state.pending.remove(&r);
        }
    }
    // after the loop only pre-loop registers (carries included) hold
    // values; restore exactly them
    state.init = pre;
    for &c in carried {
        state.init.insert(c);
    }
}

/// Is `reg` read anywhere in `rest` (subsequent loop bodies included)?
fn reads_after(rest: &[Instr], reg: Reg) -> bool {
    rest.iter().any(|instr| {
        if let Instr::Loop { body, .. } = instr {
            return reads_after(body, reg);
        }
        instr.effects().0.contains(&reg)
    })
}
