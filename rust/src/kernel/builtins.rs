//! The builtin kernel catalog, declared **only** through [`make`]: each
//! entry pairs a catalog arrangement (`crate::arrange::catalog`, the
//! paper Listings re-derived against the Rust tensor mirror) with an
//! application authored through [`AppBuilder`] and the kernel's symbolic
//! tensors.  Arity, shape preconditions, output inference, the per-shape
//! specializer and the coalescibility flag are all derived by `make` —
//! nothing here is hand-wired per kernel beyond the declaration itself.
//!
//! `rope` is the proof of the API: a new kernel shipped with zero edits
//! to the execution subsystem.  `sdpa` / `sdpa_bias` are the proof of the
//! **loop-carried reduction** subsystem: flash-style attention declared
//! purely as an arrangement plus an online-softmax application whose
//! running max / running denominator / accumulator are explicit loop
//! carries ([`AppBuilder::loop_over`]).  `conv2d` declares the paper's
//! implicit-GEMM arrangement (Listing 8); its `%`/`//` index mapping is
//! not affine, so `make` derives it as non-executable and admission
//! rejects it cleanly until the view layer learns non-affine lowering.
//!
//! Every declaration below passes the [`crate::kernel::verify`] static
//! analyses with **zero** findings — errors and warnings — which CI pins
//! via `repro lint --all` and `tests/verify.rs`.  Notably the sdpa online
//! softmax verifies padding-clean because its `-1e30` [`AppBuilder::pad_mask`]
//! is tracked through `exp(score - max) = 0` into the running sum.

use anyhow::Result;

use super::{
    derived, dim, make, AppBuilder, Arrangement, DimBindings, KernelDef, Meta, TensorSpec,
};
use crate::arrange::catalog;
use crate::exec::ir::TileProgram;
use crate::exec::tile::{BinOp, ReduceOp, UnaryOp};
use crate::symbolic::Expr;
use crate::tensor::SymTensor;

// -- arrangement build fns (the catalog entries as `Arrangement` values) ------

fn arr_add(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::add()
}

fn arr_elementwise(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::elementwise_1d(&["input", "output"])
}

fn arr_rowwise(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::rowwise()
}

fn arr_mm(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::mm()
}

fn arr_bmm(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::bmm()
}

/// addmm picks its bias variant from the unified dims: a `[1, n]` bias is
/// tiled `[1, BLOCK_SIZE_N]` and expanded across the output's row grid, a
/// full `[m, n]` bias is tiled exactly like the output.
fn arr_addmm(dims: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::addmm(dims.get("bias_rows").copied() == Some(1))
}

fn arr_conv2d(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::conv2d()
}

fn arr_rope(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::rope()
}

fn arr_sdpa(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::sdpa(false)
}

fn arr_sdpa_bias(_: &DimBindings) -> Result<Vec<SymTensor>> {
    catalog::sdpa(true)
}

// -- application programs (authored through the typed builder) ----------------

fn app_add() -> TileProgram {
    let mut b = AppBuilder::new("add");
    let x = b.load(0);
    let y = b.load(1);
    let sum = b.binary(x, y, BinOp::Add);
    b.store(2, sum);
    b.build()
}

fn app_silu() -> TileProgram {
    let mut b = AppBuilder::new("silu");
    let x = b.load(0);
    let sig = b.unary(x, UnaryOp::Sigmoid);
    let y = b.binary(x, sig, BinOp::Mul);
    b.store(1, y);
    b.build()
}

/// tanh-approximated GELU via the identity `1 + tanh(y) = 2*sigmoid(2y)`:
/// `gelu(x) = 0.5*x*(1 + tanh(sqrt(2/pi)*(x + 0.044715*x^3)))
///          = x * sigmoid(2*sqrt(2/pi)*(x + 0.044715*x^3))`,
/// which needs only Mul/Add/Const/Sigmoid.
fn app_gelu() -> TileProgram {
    // 2 * sqrt(2 / pi)
    const TWO_SQRT_2_OVER_PI: f32 = 1.595_769_1;
    const CUBIC: f32 = 0.044_715;
    let mut b = AppBuilder::new("gelu");
    let x = b.load(0);
    let x2 = b.binary(x, x, BinOp::Mul);
    let x3 = b.binary(x2, x, BinOp::Mul);
    let c_cubic = b.constant(CUBIC);
    let scaled = b.binary(x3, c_cubic, BinOp::Mul);
    let inner = b.binary(x, scaled, BinOp::Add);
    let c_coef = b.constant(TWO_SQRT_2_OVER_PI);
    let arg = b.binary(inner, c_coef, BinOp::Mul);
    let sig = b.unary(arg, UnaryOp::Sigmoid);
    let y = b.binary(x, sig, BinOp::Mul);
    b.store(1, y);
    b.build()
}

fn app_softmax() -> TileProgram {
    let mut b = AppBuilder::new("softmax");
    let x = b.load(0);
    let row_max = b.reduce(x, None, ReduceOp::Max);
    let centered = b.binary(x, row_max, BinOp::Sub);
    let e = b.unary(centered, UnaryOp::Exp);
    let denom = b.reduce(e, None, ReduceOp::Sum);
    let y = b.binary(e, denom, BinOp::Div);
    b.store(1, y);
    b.build()
}

fn app_rms_norm() -> TileProgram {
    let mut b = AppBuilder::new("rms_norm");
    let x = b.load(0);
    let sq = b.binary(x, x, BinOp::Mul);
    let ms = b.reduce(sq, None, ReduceOp::Mean);
    let eps = b.constant(1e-6);
    let stabilized = b.binary(ms, eps, BinOp::Add);
    let scale = b.unary(stabilized, UnaryOp::Rsqrt);
    let y = b.binary(x, scale, BinOp::Mul);
    b.store(1, y);
    b.build()
}

/// `layer_norm(x) = (x - mean(x)) * rsqrt(var(x) + eps)` over each row
/// (no affine weight/bias, eps = 1e-6 — consistent with rms_norm).
fn app_layer_norm() -> TileProgram {
    let mut b = AppBuilder::new("layer_norm");
    let x = b.load(0);
    let mean = b.reduce(x, None, ReduceOp::Mean);
    let centered = b.binary(x, mean, BinOp::Sub);
    let sq = b.binary(centered, centered, BinOp::Mul);
    let var = b.reduce(sq, None, ReduceOp::Mean);
    let eps = b.constant(1e-6);
    let stabilized = b.binary(var, eps, BinOp::Add);
    let scale = b.unary(stabilized, UnaryOp::Rsqrt);
    let y = b.binary(centered, scale, BinOp::Mul);
    b.store(1, y);
    b.build()
}

/// The mm/bmm/conv2d application: `acc = zeros(output.shape); for k: acc
/// += dot(input[k], other[k]); output = acc`.  The accumulator is an
/// explicit loop carry; the k-loop body is the fused `DotAcc` (blocked
/// GEMM over the parameter views directly).
fn app_matmul(name: &'static str) -> TileProgram {
    let mut b = AppBuilder::new(name);
    let acc = b.zeros_like(2);
    b.loop_over(&[acc], |b| b.dot_acc(acc, 0, 1));
    b.store(2, acc);
    b.build()
}

/// The addmm application: the mm k-loop (accumulator carried) followed
/// by a broadcast bias add (`output = acc + bias`).  Parameters are
/// `[bias, input, other, output]` (torch.addmm argument order, output
/// last); the bias tile is `[1, BN]` for broadcast biases and `[BM, BN]`
/// for full ones — the element-wise add broadcasts either onto the
/// accumulator.
fn app_addmm() -> TileProgram {
    let mut b = AppBuilder::new("addmm");
    let acc = b.zeros_like(3);
    b.loop_over(&[acc], |b| b.dot_acc(acc, 1, 2));
    let bias = b.load(0);
    let y = b.binary(acc, bias, BinOp::Add);
    b.store(3, y);
    b.build()
}

/// Additive score-bias value padded key rows / bias lanes observe: large
/// and negative but finite, so the online softmax never computes
/// `-inf - -inf` (the same `-1e30` the Python `sdpa_bias` kernel pads
/// with).  A masked lane's probability is `exp(-1e30 - m) == 0` exactly.
const SDPA_MASK: f32 = -1e30;

/// The flash-attention application (FA2 single pass, mirroring
/// `python/compile/kernels/nt/sdpa.py` / `sdpa_bias.py`): one query
/// row-block per program, with the key/value column-blocks visited in a
/// loop that carries the online-softmax state — running maximum `m`,
/// running denominator `l`, and the rescaled accumulator.
///
/// Per iteration over key/value block `j`:
///
/// ```text
/// scores = dot(q * rsqrt(d), trans(k[j])) + mask_j
/// m_new  = max(m, rowmax(scores))
/// p      = exp(scores - m_new)
/// alpha  = exp(m - m_new)            // rescales history to the new max
/// l      = l * alpha + rowsum(p)
/// acc    = acc * alpha + dot(p, v[j])
/// m      = m_new
/// ```
///
/// `mask_j` is the declared `[s, s]` bias block when `with_bias` (its
/// `-1e30` pad value also masks padded key columns), and otherwise the
/// key block's derived pad mask — so sequence lengths that are not
/// multiples of the block size stay exact.  After the loop, `output =
/// acc / max(l, 1e-20)`.  A bias row that masks *every* key is a
/// degenerate input (softmax over constant `-1e30` scores): the result
/// is finite but unspecified — the blockwise weighting differs from the
/// naive oracle's uniform average, exactly as in the Python `sdpa_bias`
/// kernel.  Any row with at least one unmasked key (every causal row)
/// is exact.
fn app_sdpa(name: &'static str, with_bias: bool) -> TileProgram {
    let out_param = if with_bias { 4 } else { 3 };
    let mut b = AppBuilder::new(name);
    let q = b.load(0);
    let head_dim = b.block_dim(0, 1);
    let scale = b.unary(head_dim, UnaryOp::Rsqrt);
    let q_scaled = b.binary(q, scale, BinOp::Mul);
    // online-softmax carries: running max, running denominator, accumulator
    let m = b.constant(f32::NEG_INFINITY);
    let l = b.constant(0.0);
    let acc = b.zeros_like(out_param);
    b.loop_over(&[m, l, acc], |b| {
        let k = b.load(1);
        let k_t = b.transpose(k);
        let raw = b.dot(q_scaled, k_t);
        let scores = if with_bias {
            let bias = b.load(3);
            b.binary(raw, bias, BinOp::Add)
        } else {
            // mask padded key rows: [BN, d] pad mask -> [1, BN] column mask
            let k_mask = b.pad_mask(1, SDPA_MASK);
            let row_valid = b.reduce(k_mask, Some(1), ReduceOp::Max);
            let col_mask = b.transpose(row_valid);
            b.binary(raw, col_mask, BinOp::Add)
        };
        let row_max = b.reduce(scores, Some(1), ReduceOp::Max);
        let m_new = b.binary(m, row_max, BinOp::Max);
        let centered = b.binary(scores, m_new, BinOp::Sub);
        let p = b.unary(centered, UnaryOp::Exp);
        let m_shift = b.binary(m, m_new, BinOp::Sub);
        let alpha = b.unary(m_shift, UnaryOp::Exp);
        let l_scaled = b.binary(l, alpha, BinOp::Mul);
        let p_sum = b.reduce(p, Some(1), ReduceOp::Sum);
        let l_new = b.binary(l_scaled, p_sum, BinOp::Add);
        let v = b.load(2);
        let pv = b.dot(p, v);
        let acc_scaled = b.binary(acc, alpha, BinOp::Mul);
        let acc_new = b.binary(acc_scaled, pv, BinOp::Add);
        b.assign(m, m_new);
        b.assign(l, l_new);
        b.assign(acc, acc_new);
    });
    let floor = b.constant(1e-20);
    let l_safe = b.binary(l, floor, BinOp::Max);
    let out = b.binary(acc, l_safe, BinOp::Div);
    b.store(out_param, out);
    b.build()
}

/// Rotary position embedding, half-rotation (Llama) convention: split
/// the head dim in half, rotate by the per-position cos/sin tables, and
/// concatenate (`python/compile/kernels/nt/rope.py`'s application).
fn app_rope() -> TileProgram {
    let mut b = AppBuilder::new("rope");
    let x = b.load(0);
    let cos = b.load(1);
    let sin = b.load(2);
    let (x1, x2) = b.split_half(x, 0);
    let x1c = b.binary(x1, cos, BinOp::Mul);
    let x2s = b.binary(x2, sin, BinOp::Mul);
    let lo = b.binary(x1c, x2s, BinOp::Sub);
    let x2c = b.binary(x2, cos, BinOp::Mul);
    let x1s = b.binary(x1, sin, BinOp::Mul);
    let hi = b.binary(x2c, x1s, BinOp::Add);
    let y = b.concat(lo, hi, 0);
    b.store(3, y);
    b.build()
}

// -- the catalog --------------------------------------------------------------

/// Every builtin definition, in registration order.
pub fn defaults() -> Result<Vec<KernelDef>> {
    type BuildFn = fn(&DimBindings) -> Result<Vec<SymTensor>>;
    let elementwise = |build: BuildFn| {
        Arrangement::new("1-D element-wise: BLOCK_SIZE tiles (Listing 3)", build)
            .with_meta(Meta::ElementwiseBlock { sym: "BLOCK_SIZE", of: "n" })
    };
    let rowwise = Arrangement::new("row-wise: one program per row", arr_rowwise);
    let matmul = |summary: &'static str, build: BuildFn| {
        Arrangement::new(summary, build).with_meta(Meta::MatmulBlocks { m: "m", k: "k", n: "n" })
    };
    Ok(vec![
        make(
            elementwise(arr_add),
            app_add(),
            vec![
                TensorSpec::input("input", vec![dim("n", 1000)]),
                TensorSpec::input("other", vec![dim("n", 1000)]),
                TensorSpec::output("output", vec![dim("n", 1000)]),
            ],
        )?,
        make(
            elementwise(arr_elementwise),
            app_silu(),
            vec![
                TensorSpec::input("input", vec![dim("n", 777)]),
                TensorSpec::output("output", vec![dim("n", 777)]),
            ],
        )?,
        make(
            elementwise(arr_elementwise),
            app_gelu(),
            vec![
                TensorSpec::input("input", vec![dim("n", 513)]),
                TensorSpec::output("output", vec![dim("n", 513)]),
            ],
        )?,
        make(
            rowwise.clone(),
            app_softmax(),
            vec![
                TensorSpec::input("input", vec![dim("rows", 7), dim("cols", 301)])
                    .with_pad(f32::NEG_INFINITY),
                TensorSpec::output("output", vec![dim("rows", 7), dim("cols", 301)]),
            ],
        )?,
        make(
            rowwise.clone(),
            app_rms_norm(),
            vec![
                TensorSpec::input("input", vec![dim("rows", 5), dim("cols", 257)]),
                TensorSpec::output("output", vec![dim("rows", 5), dim("cols", 257)]),
            ],
        )?,
        make(
            rowwise,
            app_layer_norm(),
            vec![
                TensorSpec::input("input", vec![dim("rows", 6), dim("cols", 259)]),
                TensorSpec::output("output", vec![dim("rows", 6), dim("cols", 259)]),
            ],
        )?,
        make(
            matmul("output [BM, BN] tiles; k-loop over A/B panels (Listing 5)", arr_mm),
            app_matmul("mm"),
            vec![
                TensorSpec::input("input", vec![dim("m", 70), dim("k", 50)]),
                TensorSpec::input("other", vec![dim("k", 50), dim("n", 90)]),
                TensorSpec::output("output", vec![dim("m", 70), dim("n", 90)]),
            ],
        )?,
        make(
            matmul("mm with a leading batch grid dimension", arr_bmm),
            app_matmul("bmm"),
            vec![
                TensorSpec::input("input", vec![dim("b", 3), dim("m", 33), dim("k", 17)]),
                TensorSpec::input("other", vec![dim("b", 3), dim("k", 17), dim("n", 29)]),
                TensorSpec::output("output", vec![dim("b", 3), dim("m", 33), dim("n", 29)]),
            ],
        )?,
        make(
            matmul("mm + broadcast bias epilogue", arr_addmm),
            app_addmm(),
            vec![
                TensorSpec::input("bias", vec![dim("bias_rows", 1), dim("n", 90)])
                    .with_implied_leading(),
                TensorSpec::input("input", vec![dim("m", 70), dim("k", 50)]),
                TensorSpec::input("other", vec![dim("k", 50), dim("n", 90)]),
                TensorSpec::output("output", vec![dim("m", 70), dim("n", 90)]),
            ],
        )?
        .with_constraint(
            Expr::mul(
                Expr::sub(Expr::sym("bias_rows"), Expr::Const(1)),
                Expr::sub(Expr::sym("bias_rows"), Expr::sym("m")),
            ),
            "bias does not broadcast to the output (rows must be 1 or m)",
        )?,
        make(
            Arrangement::new(
                "implicit GEMM over NCHW (Listing 8; non-affine %// lowering pending)",
                arr_conv2d,
            )
            .with_meta(Meta::Fixed(&[
                ("BLOCK_SIZE_M", 32),
                ("BLOCK_SIZE_N", 32),
                ("BLOCK_SIZE_K", 32),
            ])),
            app_matmul("conv2d"),
            vec![
                TensorSpec::input(
                    "input",
                    vec![dim("batch", 2), dim("c", 3), dim("h", 10), dim("w", 10)],
                ),
                TensorSpec::input(
                    "filter",
                    vec![dim("f", 4), dim("c", 3), dim("r", 3), dim("s", 3)],
                ),
                TensorSpec::output(
                    "output",
                    vec![
                        dim("batch", 2),
                        dim("f", 4),
                        derived(Expr::add(
                            Expr::sub(Expr::sym("h"), Expr::sym("r")),
                            Expr::Const(1),
                        )),
                        derived(Expr::add(
                            Expr::sub(Expr::sym("w"), Expr::sym("s")),
                            Expr::Const(1),
                        )),
                    ],
                ),
            ],
        )?,
        make(
            Arrangement::new(
                "one program per (batch, seq, head) row; cos/sin broadcast over batch+heads",
                arr_rope,
            ),
            app_rope(),
            vec![
                TensorSpec::input(
                    "input",
                    vec![dim("b", 2), dim("s", 6), dim("h", 3), dim("d", 8)],
                ),
                TensorSpec::input(
                    "cos",
                    vec![
                        dim("s", 6),
                        derived(Expr::floordiv(Expr::sym("d"), Expr::Const(2))),
                    ],
                ),
                TensorSpec::input(
                    "sin",
                    vec![
                        dim("s", 6),
                        derived(Expr::floordiv(Expr::sym("d"), Expr::Const(2))),
                    ],
                ),
                TensorSpec::output(
                    "output",
                    vec![dim("b", 2), dim("s", 6), dim("h", 3), dim("d", 8)],
                ),
            ],
        )?
        .with_constraint(
            Expr::modulo(Expr::sym("d"), Expr::Const(2)),
            "rope needs an even head dimension",
        )?,
        make(
            Arrangement::new(
                "FA2: one program per query row-block; K/V column-blocks form the \
                 online-softmax loop",
                arr_sdpa,
            )
            .with_meta(Meta::AttentionBlocks { seq: "s", head: "d" }),
            app_sdpa("sdpa", false),
            vec![
                TensorSpec::input(
                    "query",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::input(
                    "key",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::input(
                    "value",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::output(
                    "output",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
            ],
        )?,
        make(
            Arrangement::new(
                "sdpa with an [s, s] additive score bias (causal/attention masks), \
                 broadcast over batch and heads",
                arr_sdpa_bias,
            )
            .with_meta(Meta::AttentionBlocks { seq: "s", head: "d" }),
            app_sdpa("sdpa_bias", true),
            vec![
                TensorSpec::input(
                    "query",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::input(
                    "key",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::input(
                    "value",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
                TensorSpec::input("bias", vec![dim("s", 5), dim("s", 5)]).with_pad(SDPA_MASK),
                TensorSpec::output(
                    "output",
                    vec![dim("b", 2), dim("h", 2), dim("s", 5), dim("d", 4)],
                ),
            ],
        )?,
    ])
}
