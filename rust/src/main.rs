//! `repro` — the leader entrypoint/CLI of the NineToothed reproduction.
//!
//! Subcommands:
//!   smoke         load + run the golden kernels, verify numerics
//!   validate      validate all arrangements (structure, goldens, plans)
//!   code-metrics  regenerate Table 2
//!   bench-kernels regenerate Fig 6 (single-kernel tasks)
//!   bench-e2e     regenerate Fig 7 (end-to-end inference)
//!   serve         run the kernel-serving coordinator demo workload, or
//!                 with --addr HOST:PORT serve it over TCP (length-prefixed
//!                 JSON frames; see docs/wire-protocol.md)
//!   stats         mixed burst + full observability snapshot (table,
//!                 --prometheus, --json)
//!   tune          pre-tune block sizes for a kernel/shape list and write
//!                 the on-disk tuning table (NT_TUNE / NT_TUNE_TABLE)
//!   lint          run the declaration verifier over the registry (--kernel
//!                 NAME for one, --corpus for the negative test corpus)
//!   events        inspect the flight-recorder NDJSON log (--file PATH,
//!                 --kind/--kernel/--client filters, --last N, --check)
//!   kernels       list the kernel registry (serving-deployment debugging)
//!   inspect       print manifest + launch-plan details

use std::sync::Arc;

use anyhow::Result;
use ninetoothed_repro::{
    arrange, artifacts_dir, cli::Args, harness,
    runtime::{Manifest, Registry, Runtime},
};

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("smoke") => smoke(),
        Some("validate") => validate(),
        Some("code-metrics") => harness::table2::run(&args),
        Some("bench-kernels") => harness::fig6::run(&args),
        Some("bench-e2e") => harness::fig7::run(&args),
        Some("serve") => harness::serve::run(&args),
        Some("stats") => harness::stats::run(&args),
        Some("tune") => harness::tune::run(&args),
        Some("lint") => harness::lint::run(&args),
        Some("events") => harness::events::run(&args),
        Some("kernels") => kernels_cmd(),
        Some("inspect") => inspect(),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            eprintln!(
                "usage: repro <command>\n\
                 \n\
                 commands:\n\
                 \x20 smoke          load + run golden kernels, verify numerics\n\
                 \x20 validate       validate arrangements (structure, goldens, launch plans)\n\
                 \x20 code-metrics   regenerate Table 2 (code complexity)\n\
                 \x20 bench-kernels  regenerate Fig 6 (single-kernel performance)\n\
                 \x20 bench-e2e      regenerate Fig 7 (end-to-end inference throughput)\n\
                 \x20 serve          run the kernel-serving coordinator demo, or serve it\n\
                 \x20                over TCP with --addr HOST:PORT (docs/wire-protocol.md)\n\
                 \x20 stats          mixed burst + observability snapshot (per-kernel\n\
                 \x20                metrics, trace waterfall; --prometheus / --json)\n\
                 \x20 tune           pre-tune block sizes and write the tuning table\n\
                 \x20                (--smoke, --table PATH, --kernels a,b,c; NT_TUNE)\n\
                 \x20 lint           run the declaration verifier (dataflow, shapes,\n\
                 \x20                coalesce audit, padding safety) over the registry\n\
                 \x20                (--kernel NAME, --corpus; docs/diagnostics.md)\n\
                 \x20 events         inspect the flight-recorder NDJSON log (--file PATH\n\
                 \x20                or NT_EVENT_LOG; --kind/--kernel/--client, --last N,\n\
                 \x20                --check; docs/observability.md)\n\
                 \x20 kernels        list the kernel registry (name, arity, arrangement,\n\
                 \x20                coalescible, loop-carried, native/artifact availability)\n\
                 \x20 inspect        print manifest and launch-plan details"
            );
            Ok(())
        }
    }
}

fn smoke() -> Result<()> {
    match (Manifest::load(&artifacts_dir()), Runtime::cpu()) {
        (Ok(manifest), Ok(runtime)) => {
            let registry = Registry::new(runtime, Arc::new(manifest));
            println!(
                "platform: {}",
                registry.runtime().map(Runtime::platform).unwrap_or_default()
            );
            harness::golden::check_all(&registry)?;
        }
        (manifest, runtime) => {
            if let Err(e) = manifest {
                println!("no AOT artifacts ({e:#})");
            }
            if let Err(e) = runtime {
                println!("no PJRT runtime ({e:#})");
            }
            println!("running the native tile-execution backend against the reference oracles:");
            harness::golden::check_native()?;
        }
    }
    println!("smoke OK");
    Ok(())
}

fn validate() -> Result<()> {
    match Manifest::load(&artifacts_dir()) {
        Ok(manifest) => {
            let arrangements = arrange::load_all(&manifest.raw)?;
            let mut goldens = 0;
            for a in &arrangements {
                a.validate_structure()?;
                goldens += a.check_goldens()?;
                println!("arrangement {:<12} params={} ok", a.kernel, a.params.len());
            }
            println!(
                "validated {} arrangements, {} golden evaluations",
                arrangements.len(),
                goldens
            );
            harness::validate::catalog_parity(&manifest)?;
        }
        Err(e) => {
            println!("no AOT manifest ({e:#}); validating the native kernel catalog:");
            harness::validate::native_catalog()?;
        }
    }
    Ok(())
}

/// `repro kernels` — the registry as a serving-deployment debugging view:
/// every `kernel::make`-declared definition with its derived contract,
/// plus whether an AOT artifact could shadow the native path.
fn kernels_cmd() -> Result<()> {
    let manifest = Manifest::load_or_builtin(&artifacts_dir());
    let defs = ninetoothed_repro::kernel::kernels();
    let yn = |b: bool| if b { "yes" } else { "no" };
    println!("kernel registry ({} definitions):", defs.len());
    println!(
        "  {:<11} {:>5}  {:<10} {:<6} {:<8} {:<12} {:<34} arrangement",
        "name", "arity", "coalesce", "native", "artifact", "loop-carried", "diagnostic"
    );
    for def in &defs {
        let artifact = manifest.kernels.iter().any(|k| k.name == def.name);
        let carries = match def.loop_carries() {
            Some(n) => format!("{n} carries"),
            None => "none".to_string(),
        };
        let diagnostic = ninetoothed_repro::kernel::verify::lowerability(def)
            .unwrap_or_else(|| "-".to_string());
        println!(
            "  {:<11} {:>5}  {:<10} {:<6} {:<8} {:<12} {:<34} {}",
            def.name,
            def.arity,
            yn(def.coalesce),
            yn(def.executable()),
            yn(artifact),
            carries,
            diagnostic,
            def.arrangement.summary
        );
    }
    println!(
        "\n(coalesce, native availability, the loop-carried register count and the \
         lowerability diagnostic are derived by kernel::make from the declaration — \
         nothing is asserted by hand)"
    );
    Ok(())
}

fn inspect() -> Result<()> {
    let manifest = Manifest::load_or_builtin(&artifacts_dir());
    println!("artifacts: {}", manifest.dir.display());
    println!("full-scale: {}", manifest.full);
    println!("kernels ({}):", manifest.kernels.len());
    for k in &manifest.kernels {
        let shapes: Vec<String> = k.args.iter().map(|a| format!("{:?}", a.shape)).collect();
        println!("  {:<10} {:<9} args={} flops={}", k.name, k.variant, shapes.join(","), k.flops);
    }
    if let Some(model) = &manifest.model {
        println!(
            "model: d={} L={} H={} ff={} vocab={} max_seq={} ({} weights)",
            model.d_model, model.n_layers, model.n_heads, model.d_ff, model.vocab_size,
            model.max_seq, model.weights.len()
        );
    }
    let native = ninetoothed_repro::exec::kernels();
    println!("registered kernel definitions ({}):", native.len());
    for k in native {
        println!(
            "  {:<10} arity={} ({})",
            k.name,
            k.arity,
            if k.executable() { "shape-polymorphic" } else { "declared; not natively lowerable" }
        );
    }
    Ok(())
}
