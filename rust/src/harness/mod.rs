//! The benchmark harness: one module per paper table/figure plus shared
//! validation utilities.  Each `run` prints the same rows/series the paper
//! reports (see DESIGN.md §5 for the experiment index).

pub mod events;
pub mod fig6;
pub mod fig7;
pub mod golden;
pub mod lint;
pub mod serve;
pub mod stats;
pub mod table2;
pub mod tune;
pub mod validate;

use std::path::PathBuf;

/// Repository root: the directory holding `artifacts/` (for locating the
/// Python kernel sources measured by Table 2).
pub fn repo_root() -> PathBuf {
    let mut dir = crate::artifacts_dir();
    dir.pop();
    dir
}
