//! Fig 7 — end-to-end model inference throughput (tokens/s) for the three
//! kernel backends at several output lengths, batch 2, input 32 tokens
//! (shapes per DESIGN.md §6 substitutions; `--full` artifacts enable the
//! paper's 128/512/2048 ladder).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::artifacts_dir;
use crate::benchkit::Table;
use crate::cli::Args;
use crate::inference::Engine;
use crate::runtime::{Manifest, Registry, Runtime};

pub struct E2eResult {
    pub variant: String,
    pub steps: usize,
    pub tokens_per_s: f64,
}

pub fn output_lengths(manifest: &Manifest, warmup_capped: bool) -> Vec<usize> {
    let model = manifest.model.as_ref();
    let cap = model.map(|m| m.max_seq - m.prompt).unwrap_or(64);
    let ladder: &[usize] = if manifest.full {
        &[128, 512, 2048]
    } else {
        &[16, 32, 64]
    };
    ladder
        .iter()
        .copied()
        .filter(|&s| s <= cap && (!warmup_capped || s <= 64))
        .collect()
}

pub fn run_all(registry: &Arc<Registry>, measured_iters: usize) -> Result<Vec<E2eResult>> {
    let manifest = registry.manifest_arc();
    let lengths = output_lengths(&manifest, false);
    let mut results = Vec::new();
    for variant in ["nt", "baseline", "ref"] {
        let engine = Engine::new(registry.clone(), variant)
            .with_context(|| format!("loading engine for {variant}"))?;
        let prompt = engine.synth_prompt(7);
        for &steps in &lengths {
            // paper protocol: one warmup iteration + averaged measured runs
            engine.generate(&prompt, steps)?;
            let mut tps = 0.0;
            for _ in 0..measured_iters {
                tps += engine.generate(&prompt, steps)?.tokens_per_s;
            }
            results.push(E2eResult {
                variant: variant.to_string(),
                steps,
                tokens_per_s: tps / measured_iters as f64,
            });
        }
    }
    Ok(results)
}

pub fn report(results: &[E2eResult]) -> String {
    let mut out = String::new();
    let mut lengths: Vec<usize> = results.iter().map(|r| r.steps).collect();
    lengths.sort_unstable();
    lengths.dedup();
    let mut table = Table::new(&["output len", "NineToothed tok/s", "Baseline tok/s", "PyTorch-ref tok/s", "NT vs base"]);
    let mut diffs = Vec::new();
    for &steps in &lengths {
        let get = |variant: &str| {
            results
                .iter()
                .find(|r| r.steps == steps && r.variant == variant)
                .map(|r| r.tokens_per_s)
        };
        let (nt, base, reference) = (get("nt"), get("baseline"), get("ref"));
        let rel = match (nt, base) {
            (Some(nt), Some(base)) if base > 0.0 => {
                let d = 100.0 * (nt - base) / base;
                diffs.push(d);
                format!("{d:+.2}%")
            }
            _ => "-".into(),
        };
        table.row(vec![
            steps.to_string(),
            nt.map(|v| format!("{v:.2}")).unwrap_or_default(),
            base.map(|v| format!("{v:.2}")).unwrap_or_default(),
            reference.map(|v| format!("{v:.2}")).unwrap_or_default(),
            rel,
        ]);
    }
    out.push_str(&table.render());
    if !diffs.is_empty() {
        let min = diffs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = diffs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let avg = diffs.iter().sum::<f64>() / diffs.len() as f64;
        out.push_str(&format!(
            "NT-vs-baseline throughput difference: min {min:+.2}%, max {max:+.2}%, avg {avg:+.2}%\n\
             (paper, DeepSeek-8B on A100: min -5.32%, max +0.33%, avg -1.79%)\n"
        ));
    }
    out
}

pub fn run(args: &Args) -> Result<()> {
    let manifest = Arc::new(Manifest::load(&artifacts_dir())?);
    let registry = Arc::new(Registry::new(Runtime::cpu()?, manifest));
    let iters = args.opt_usize("iters", 3);
    let model = registry
        .manifest()
        .model
        .as_ref()
        .context("no model in manifest")?;
    println!(
        "Fig 7: end-to-end inference (tiny-Llama d={} L={}, batch {}, input {} tokens, {iters} measured iterations)",
        model.d_model, model.n_layers, model.batch, model.prompt
    );
    let results = run_all(&registry, iters)?;
    println!("{}", report(&results));
    Ok(())
}
