//! `repro stats` — drive the coordinator with a short mixed burst
//! (mm / softmax / sdpa / add) and print the full observability snapshot:
//! global metrics, per-kernel/per-shape rows with plan-cache attribution,
//! the slowest traced requests as a span waterfall, pool gauges, and —
//! under `NT_PROFILE=1` — the per-instruction execution profiles.
//!
//! Flags: `--workers N` (default 2), `--requests N` (default 48),
//! `--prometheus` (emit Prometheus text exposition instead of the table),
//! `--json` (emit the snapshot as JSON).  `NT_TRACE_SAMPLE=k` samples
//! every k-th request into the trace ring.

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::harness::golden;
use crate::prng::SplitMix64;
use crate::runtime::Manifest;

/// The kernels the burst cycles through — the acceptance mix.
const BURST: &[&str] = &["mm", "softmax", "sdpa", "add"];

pub fn run(args: &Args) -> Result<()> {
    let requests = args.opt_usize("requests", 48);
    let mut config = CoordinatorConfig::default().from_env()?;
    config.workers = args.opt_positive("workers")?.unwrap_or(2);
    // native-only: the burst exercises the plan cache and coalescer, which
    // AOT artifacts would shadow
    let manifest = Arc::new(Manifest::builtin());
    let coordinator = Coordinator::start(manifest, config)?;

    let mut rng = SplitMix64::new(99);
    let mut receivers = Vec::with_capacity(requests);
    for i in 0..requests {
        let kernel = BURST[i % BURST.len()];
        let inputs = golden::native_task_inputs(kernel, &mut rng)?;
        receivers.push(coordinator.submit(kernel, "nt", inputs)?);
    }
    let mut ok = 0usize;
    for rx in receivers {
        rx.recv()??;
        ok += 1;
    }

    let snapshot = coordinator.obs_snapshot();
    if args.flag("prometheus") {
        print!("{}", snapshot.render_prometheus());
    } else if args.flag("json") {
        println!("{}", snapshot.to_json());
    } else {
        println!("completed {ok}/{requests} requests");
        print!("{}", snapshot.render_table());
    }
    coordinator.shutdown();
    Ok(())
}
