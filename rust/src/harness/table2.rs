//! Table 2 — code evaluation: LOC / LLOC / SLOC / G / η / N / V / D / MI
//! for the ten kernels in both DSL levels.
//!
//! Primary numbers are the AST-exact rows computed at AOT time
//! (python/compile/metrics.py, radon-equivalent definitions) and embedded
//! in the manifest; the Rust lexer-level suite (`crate::codemetrics`)
//! re-measures the same sources independently and disagreements beyond the
//! documented Halstead approximation are flagged.

use anyhow::{Context, Result};

use crate::benchkit::Table;
use crate::cli::Args;
use crate::codemetrics;
use crate::json::Json;
use crate::runtime::Manifest;
use crate::{artifacts_dir, harness::repo_root};

struct Row {
    kernel: String,
    variant: String,
    loc: i64,
    lloc: i64,
    sloc: i64,
    g: i64,
    eta: i64,
    n: i64,
    v: f64,
    d: f64,
    mi: f64,
}

fn manifest_rows(manifest: &Manifest) -> Result<Vec<Row>> {
    let metrics = manifest.raw.req("metrics")?;
    let mut rows = Vec::new();
    for r in metrics.arr("rows")? {
        rows.push(Row {
            kernel: r.str("kernel")?.to_string(),
            variant: r.str("variant")?.to_string(),
            loc: r.f64("loc")? as i64,
            lloc: r.f64("lloc")? as i64,
            sloc: r.f64("sloc")? as i64,
            g: r.f64("cyclomatic")? as i64,
            eta: r.f64("vocabulary")? as i64,
            n: r.f64("length")? as i64,
            v: r.f64("volume")?,
            d: r.f64("difficulty")?,
            mi: r.f64("mi")?,
        });
    }
    Ok(rows)
}

pub fn run(_args: &Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_dir())?;
    let rows = manifest_rows(&manifest)?;

    println!("Table 2: code evaluation (baseline = hand-written Pallas, the Triton role)");
    let mut table = Table::new(&[
        "kernel", "impl", "LOC", "LLOC", "SLOC", "G", "eta", "N", "V", "D", "MI",
    ]);
    for r in &rows {
        table.row(vec![
            r.kernel.clone(),
            if r.variant == "nt" { "NineToothed".into() } else { "Baseline".into() },
            r.loc.to_string(),
            r.lloc.to_string(),
            r.sloc.to_string(),
            r.g.to_string(),
            r.eta.to_string(),
            r.n.to_string(),
            format!("{:.2}", r.v),
            format!("{:.2}", r.d),
            format!("{:.2}", r.mi),
        ]);
    }
    println!("{}", table.render());

    // headline claims (paper §5.2.3 / §5.2.4)
    let mut v_ratios = Vec::new();
    let mut mi_wins = 0;
    let mut total = 0;
    for r in rows.iter().filter(|r| r.variant == "nt") {
        if let Some(b) = rows
            .iter()
            .find(|b| b.variant == "baseline" && b.kernel == r.kernel)
        {
            if b.v > 0.0 {
                v_ratios.push((r.kernel.clone(), 100.0 * r.v / b.v));
            }
            total += 1;
            if r.mi > b.mi {
                mi_wins += 1;
            }
        }
    }
    let min = v_ratios
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .context("no ratios")?;
    let max = v_ratios
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .context("no ratios")?;
    println!(
        "Halstead volume of NineToothed kernels: {:.2}% ({}) .. {:.2}% ({}) of the baseline's",
        min.1, min.0, max.1, max.0
    );
    println!(
        "(paper: 0.25% .. 56.33% of Triton's)  MI higher for NineToothed on {mi_wins}/{total} kernels (paper: all)"
    );

    // cross-check against the independent Rust lexer implementation
    println!("\ncross-check: Rust lexer suite vs AST-exact (LOC/SLOC/G must match):");
    let root = repo_root();
    let mut mismatches = 0;
    for r in &rows {
        let sub = if r.variant == "nt" { "nt" } else { "baseline" };
        let path = root
            .join("python/compile/kernels")
            .join(sub)
            .join(format!("{}.py", r.kernel));
        let Ok(source) = std::fs::read_to_string(&path) else {
            println!("  {}.{}: source not found, skipped", r.kernel, r.variant);
            continue;
        };
        let m = codemetrics::analyze(&codemetrics::measured_region(&source));
        let ok = m.loc as i64 == r.loc && m.sloc as i64 == r.sloc && m.cyclomatic as i64 == r.g;
        if !ok {
            mismatches += 1;
            println!(
                "  {}.{}: rust LOC={} SLOC={} G={} vs python LOC={} SLOC={} G={}",
                r.kernel, r.variant, m.loc, m.sloc, m.cyclomatic, r.loc, r.sloc, r.g
            );
        }
    }
    if mismatches == 0 {
        println!("  all kernels agree");
    }
    Ok(())
}

/// Verification entry shared with `cargo test`.
pub fn headline_holds(manifest: &Manifest) -> Result<bool> {
    let rows = manifest_rows(manifest)?;
    let nts: Vec<&Row> = rows.iter().filter(|r| r.variant == "nt").collect();
    let mut ok = true;
    for nt in nts {
        let base = rows
            .iter()
            .find(|b| b.variant == "baseline" && b.kernel == nt.kernel)
            .context("missing baseline row")?;
        // the paper's direction: NT maintains or improves MI on every kernel
        if nt.mi <= base.mi {
            ok = false;
        }
    }
    Ok(ok)
}

#[allow(dead_code)]
fn unused(_: &Json) {}
