//! Fig 6 — single-compute-kernel performance: the ten paper tasks, each
//! executed via the NineToothed-generated artifact, the hand-written
//! Pallas baseline artifact, and the pure-jnp reference ("PyTorch" series),
//! on the PJRT CPU substrate.  Reported per task: mean latency, derived
//! throughput, and the NT-vs-baseline relative difference (the paper's
//! -1.58%..+3.93% claim).

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::benchkit::{bench_for, fmt_duration, Table};
use crate::cli::Args;
use crate::prng::SplitMix64;
use crate::runtime::{HostTensor, Manifest, Registry, Runtime};
use crate::artifacts_dir;

pub struct TaskResult {
    pub name: String,
    pub variant: String,
    pub mean_s: f64,
    pub gflops: f64,
}

/// Build deterministic random inputs for a kernel task.
pub fn task_inputs(manifest: &Manifest, name: &str, seed: u64) -> Result<Vec<HostTensor>> {
    let art = manifest.kernel(name, "nt")?;
    let mut rng = SplitMix64::new(seed);
    Ok(art
        .args
        .iter()
        .map(|spec| {
            if spec.shape.is_empty() {
                // the addmm beta/alpha scalars
                HostTensor::f32(vec![], vec![0.5 + rng.uniform() as f32]).unwrap()
            } else {
                HostTensor::randn(spec.shape.clone(), &mut rng)
            }
        })
        .collect())
}

pub fn run_all(registry: &Registry, iters_time: Duration) -> Result<Vec<TaskResult>> {
    let manifest = registry.manifest();
    let mut results = Vec::new();
    for name in manifest.kernel_names() {
        if name.starts_with("model") {
            continue;
        }
        let inputs = task_inputs(manifest, &name, 42)?;
        let flops = manifest.kernel(&name, "nt")?.flops as f64;
        for variant in ["nt", "baseline", "ref"] {
            let exe = registry.kernel(&name, variant)?;
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let stats = bench_for(1, iters_time, || {
                exe.run_literals(&literals).expect("kernel execution");
            });
            results.push(TaskResult {
                name: name.clone(),
                variant: variant.to_string(),
                mean_s: stats.mean_s,
                gflops: flops / stats.mean_s / 1e9,
            });
        }
    }
    Ok(results)
}

pub fn report(results: &[TaskResult]) -> String {
    let mut out = String::new();
    let mut table = Table::new(&["task", "NineToothed", "Baseline", "PyTorch-ref", "NT vs base"]);
    let mut diffs = Vec::new();
    let names: Vec<String> = {
        let mut v: Vec<String> = results.iter().map(|r| r.name.clone()).collect();
        v.sort();
        v.dedup();
        v
    };
    for name in &names {
        let get = |variant: &str| {
            results
                .iter()
                .find(|r| &r.name == name && r.variant == variant)
                .map(|r| r.mean_s)
        };
        let (nt, base, reference) = (get("nt"), get("baseline"), get("ref"));
        let rel = match (nt, base) {
            // positive = NT slower than baseline
            (Some(nt), Some(base)) if base > 0.0 => {
                let d = 100.0 * (nt - base) / base;
                diffs.push((name.clone(), d));
                format!("{d:+.2}%")
            }
            _ => "-".to_string(),
        };
        table.row(vec![
            name.clone(),
            nt.map(fmt_duration).unwrap_or_default(),
            base.map(fmt_duration).unwrap_or_default(),
            reference.map(fmt_duration).unwrap_or_default(),
            rel,
        ]);
    }
    out.push_str(&table.render());
    if !diffs.is_empty() {
        let min = diffs.iter().min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
        let max = diffs.iter().max_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap();
        let avg = diffs.iter().map(|d| d.1).sum::<f64>() / diffs.len() as f64;
        out.push_str(&format!(
            "NT-vs-baseline latency difference: min {:+.2}% ({}), max {:+.2}% ({}), avg {:+.2}%\n\
             (paper, on A100/Triton: min -1.58%, max +3.93%, avg +0.37%)\n",
            min.1, min.0, max.1, max.0, avg
        ));
    }
    out
}

pub fn run(args: &Args) -> Result<()> {
    let manifest = Arc::new(Manifest::load(&artifacts_dir())?);
    let registry = Registry::new(Runtime::cpu()?, manifest);
    let secs = args.opt_usize("secs", 2);
    println!(
        "Fig 6: single-kernel tasks ({} scale, >= {secs}s per measurement)",
        if registry.manifest().full { "paper" } else { "scaled" }
    );
    let results = run_all(&registry, Duration::from_secs(secs as u64))?;
    println!("{}", report(&results));
    Ok(())
}
