//! `repro lint` — run the declaration verifier over the kernel registry
//! (or the negative corpus) and print a diagnostics table.
//!
//! Modes:
//!   lint                   verify every registered kernel (same as --all)
//!   lint --kernel NAME     verify one kernel
//!   lint --corpus          verify the negative corpus instead: every case
//!                          must fire exactly its intended NT-V* code, and
//!                          the command always exits non-zero (CI uses this
//!                          to prove the gate actually bites)
//!
//! Exit status is the contract: any diagnostic on a registered kernel —
//! warnings included — makes the command fail, so `lint --all` in CI means
//! every shipped declaration verifies completely clean.

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::kernel::{self, verify};

pub fn run(args: &Args) -> Result<()> {
    if args.flag("corpus") {
        return corpus();
    }
    let defs = kernel::kernels();
    let selected: Vec<_> = match args.opt("kernel") {
        Some(name) => {
            let hits: Vec<_> = defs.iter().filter(|d| d.name == name).cloned().collect();
            if hits.is_empty() {
                bail!("lint: no registered kernel named {name:?}");
            }
            hits
        }
        None => defs,
    };

    println!("declaration verifier ({} kernels):", selected.len());
    println!("  {:<11} {:<8} {:<22} note", "name", "verdict", "codes");
    let mut dirty = 0usize;
    for def in &selected {
        let report = verify::verify(def);
        let codes = report.codes();
        let codes_col = if codes.is_empty() {
            "-".to_string()
        } else {
            codes.iter().map(|c| c.as_str()).collect::<Vec<_>>().join(",")
        };
        let note = verify::lowerability(def).unwrap_or_else(|| "-".to_string());
        let verdict = if report.is_clean() { "clean" } else { "dirty" };
        println!("  {:<11} {:<8} {:<22} {}", def.name, verdict, codes_col, note);
        if !report.is_clean() {
            dirty += 1;
            println!("{}", report.render());
        }
    }
    if dirty > 0 {
        bail!("lint: {dirty} kernel declaration(s) carry verifier findings");
    }
    println!(
        "\nall declarations verify clean (dataflow, shapes, coalesce audit, padding safety)"
    );
    Ok(())
}

/// The negative corpus: print what each deliberately broken declaration
/// fires, check it is exactly the intended code, and always exit
/// non-zero — a lint that cannot reject its own corpus proves nothing.
fn corpus() -> Result<()> {
    let cases = verify::corpus::cases()?;
    println!("negative corpus ({} broken declarations):", cases.len());
    println!("  {:<12} {:<9} {:<9} summary", "case", "expected", "fired");
    let mut mismatched = 0usize;
    for case in &cases {
        let codes = case.report.codes();
        let fired = if codes.is_empty() {
            "(none)".to_string()
        } else {
            codes.iter().map(|c| c.as_str()).collect::<Vec<_>>().join(",")
        };
        let exact = codes == [case.expected];
        if !exact {
            mismatched += 1;
        }
        println!(
            "  {:<12} {:<9} {:<9} {}{}",
            case.name,
            case.expected.as_str(),
            fired,
            case.summary,
            if exact { "" } else { "  <-- MISMATCH" }
        );
    }
    if mismatched > 0 {
        bail!("lint --corpus: {mismatched} case(s) did not fire exactly their intended code");
    }
    bail!(
        "lint --corpus: all {} broken declarations correctly rejected (this mode always \
         exits non-zero — the corpus is the proof the gate bites)",
        cases.len()
    );
}
