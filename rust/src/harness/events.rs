//! `repro events` — inspect the flight recorder's NDJSON event log.
//!
//! Reads the rotated predecessor (`<path>.1`) first and then the live
//! file, so output is chronological across a rotation.  Filters stack:
//! `--kind admit`, `--kernel softmax`, `--client acme`; `--last N`
//! keeps only the newest N matching events; `--check` validates every
//! line parses as a JSON object (exit non-zero otherwise) — the CI
//! serving smoke runs it against the log a live server just wrote.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::json::Json;

pub fn run(args: &Args) -> Result<()> {
    let path = match args.opt("file").map(PathBuf::from).or_else(|| {
        std::env::var("NT_EVENT_LOG").ok().map(PathBuf::from)
    }) {
        Some(path) => path,
        None => bail!(
            "no event log given: pass --file PATH or set NT_EVENT_LOG \
             (the server writes it when started with the same knob)"
        ),
    };
    let kind = args.opt("kind");
    let kernel = args.opt("kernel");
    let client = args.opt("client");
    let last = args.opt_positive("last")?;
    let check = args.flag("check");

    let mut events: Vec<(usize, String, Option<Json>)> = Vec::new();
    let mut files = 0usize;
    for candidate in [crate::obs::events::rotated_path(&path), path.clone()] {
        if !candidate.exists() {
            continue;
        }
        files += 1;
        read_lines(&candidate, &mut events)?;
    }
    if files == 0 {
        bail!("event log {} does not exist (nor does its rotation)", path.display());
    }

    let mut bad = 0usize;
    let mut kept: Vec<&(usize, String, Option<Json>)> = Vec::new();
    for entry in &events {
        let (_, line, parsed) = entry;
        let Some(obj) = parsed else {
            bad += 1;
            eprintln!("unparseable event line: {line}");
            continue;
        };
        let field = |key: &str| obj.get(key).and_then(Json::as_str);
        if kind.is_some_and(|want| field("event") != Some(want)) {
            continue;
        }
        if kernel.is_some_and(|want| field("kernel") != Some(want)) {
            continue;
        }
        if client.is_some_and(|want| field("client_id") != Some(want)) {
            continue;
        }
        kept.push(entry);
    }
    if let Some(n) = last {
        if kept.len() > n {
            kept.drain(..kept.len() - n);
        }
    }
    for (_, line, _) in &kept {
        println!("{line}");
    }
    eprintln!(
        "{} event(s) shown of {} total ({} file(s)){}",
        kept.len(),
        events.len(),
        files,
        if bad > 0 { format!(", {bad} unparseable") } else { String::new() }
    );
    if check && bad > 0 {
        bail!("{bad} event line(s) failed to parse as JSON objects");
    }
    Ok(())
}

fn read_lines(path: &Path, out: &mut Vec<(usize, String, Option<Json>)>) -> Result<()> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening event log {}", path.display()))?;
    for (i, line) in BufReader::new(file).lines().enumerate() {
        let line = line.with_context(|| format!("reading {}", path.display()))?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = Json::parse(&line).ok().filter(|v| matches!(v, Json::Obj(_)));
        out.push((i, line, parsed));
    }
    Ok(())
}
