//! Cross-validation of the two algebra implementations: the Rust catalog
//! arrangements (paper Listings re-derived against `crate::tensor`) must
//! produce the same launch geometry as the manifest metadata exported by
//! the Python DSL.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::arrange::{self, catalog};
use crate::runtime::Manifest;

fn bindings(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Artifact-free validation: specialize every native kernel at its smoke
/// shapes and print the launch geometry the affine lowering produced.
pub fn native_catalog() -> Result<()> {
    let mut rng = crate::prng::SplitMix64::new(7);
    for kernel in crate::exec::kernels() {
        let Ok(inputs) = super::golden::native_task_inputs(&kernel.name, &mut rng) else {
            // no smoke inputs: declared-only kernels (conv2d awaits
            // non-affine lowering) report their probe diagnostics instead
            match kernel.probe_error() {
                Some(err) => println!("native {:<10} declared; not lowerable: {err}", kernel.name),
                None => println!("native {:<10} registered (no smoke inputs)", kernel.name),
            }
            continue;
        };
        let spec = kernel.specialize(&inputs)?;
        println!(
            "native {:<10} grid {:?} x {} programs, loop {:?}, outputs {:?}",
            kernel.name,
            spec.grid,
            spec.programs(),
            spec.loop_shape,
            spec.output_shapes
        );
    }
    Ok(())
}

/// Rename catalog symbols (`input_size_0`, ...) into the manifest's
/// parameter-name-based symbols for a kernel, then compare geometry.
pub fn catalog_parity(manifest: &Manifest) -> Result<()> {
    let metas = arrange::load_all(&manifest.raw)?;
    let find = |name: &str| {
        metas
            .iter()
            .find(|m| m.kernel == name)
            .with_context(|| format!("manifest lacks arrangement {name}"))
    };

    // --- add (Listing 3) ---------------------------------------------------
    {
        let meta = find("add")?;
        let rust = catalog::add()?;
        let n = 4097i64;
        let block = 256i64;
        let mut env = bindings(&[("BLOCK_SIZE", block)]);
        for t in ["input", "other", "output"] {
            env.insert(format!("{t}_size_0"), n);
        }
        let (grid, extents) = catalog::geometry(&rust, &env)?;
        // manifest symbols are tensor_N-based; map them by position
        let mut menv = bindings(&[("BLOCK_SIZE", block)]);
        for p in &meta.params {
            for (sym, _) in collect_size_syms(meta, &p.name) {
                menv.insert(sym, n);
            }
        }
        bind_meta_params(meta, &mut menv, block);
        let plan = meta.launch_plan(&menv)?;
        if plan.grid != grid {
            bail!("add grid mismatch: catalog {grid:?} vs manifest {:?}", plan.grid);
        }
        for (p, e) in plan.params.iter().zip(&extents) {
            if &p.padded_extents != e {
                bail!("add extent mismatch for {}: {:?} vs {e:?}", p.name, p.padded_extents);
            }
        }
        println!("catalog parity add: grid {grid:?} extents agree");
    }

    // --- mm (Listing 5) ------------------------------------------------------
    {
        let meta = find("mm")?;
        let rust = catalog::mm()?;
        let (m, k, n) = (70i64, 50i64, 90i64);
        let block = 32i64;
        let mut env = bindings(&[
            ("BLOCK_SIZE_M", block),
            ("BLOCK_SIZE_N", block),
            ("BLOCK_SIZE_K", block),
            ("input_size_0", m),
            ("input_size_1", k),
            ("other_size_0", k),
            ("other_size_1", n),
            ("output_size_0", m),
            ("output_size_1", n),
        ]);
        let (grid, extents) = catalog::geometry(&rust, &env)?;

        let mut menv = bindings(&[("BLOCK_SIZE_M", block), ("BLOCK_SIZE_N", block), ("BLOCK_SIZE_K", block)]);
        let dims = [(m, k), (k, n), (m, n)];
        for (p, (d0, d1)) in meta.params.iter().zip(dims) {
            let syms = collect_size_syms(meta, &p.name);
            anyhow::ensure!(syms.len() == 2, "mm param {} has {} size syms", p.name, syms.len());
            menv.insert(syms[0].0.clone(), d0);
            menv.insert(syms[1].0.clone(), d1);
        }
        bind_meta_params(meta, &mut menv, block);
        let plan = meta.launch_plan(&menv)?;
        if plan.grid != grid {
            bail!("mm grid mismatch: catalog {grid:?} vs manifest {:?}", plan.grid);
        }
        for (p, e) in plan.params.iter().zip(&extents) {
            if &p.padded_extents != e {
                bail!("mm extent mismatch for {}: {:?} vs {e:?}", p.name, p.padded_extents);
            }
        }
        env.insert("dummy".into(), 0);
        println!("catalog parity mm: grid {grid:?} extents agree");
    }

    // --- conv2d (Listing 8) ----------------------------------------------------
    {
        let meta = find("conv2d")?;
        let rust = catalog::conv2d()?;
        let (nn, c, h, w) = (2i64, 3i64, 10i64, 10i64);
        let (kk, r, s) = (4i64, 3i64, 3i64);
        let block = 16i64;
        let env = {
            let mut e = bindings(&[
                ("BLOCK_SIZE_M", block),
                ("BLOCK_SIZE_N", block),
                ("BLOCK_SIZE_K", block),
                ("input_size_0", nn),
                ("input_size_1", c),
                ("input_size_2", h),
                ("input_size_3", w),
                ("filter_size_0", kk),
                ("filter_size_1", c),
                ("filter_size_2", r),
                ("filter_size_3", s),
                ("output_size_0", nn),
                ("output_size_1", kk),
            ]);
            e.insert("output_size_2".into(), h - r + 1);
            e.insert("output_size_3".into(), w - s + 1);
            e
        };
        let (grid, _) = catalog::geometry(&rust, &env)?;

        let mut menv = bindings(&[("BLOCK_SIZE_M", block), ("BLOCK_SIZE_N", block), ("BLOCK_SIZE_K", block)]);
        let dims: [&[i64]; 3] = [&[nn, c, h, w], &[kk, c, r, s], &[nn, kk, h - r + 1, w - s + 1]];
        for (p, d) in meta.params.iter().zip(dims) {
            let syms = collect_size_syms(meta, &p.name);
            anyhow::ensure!(syms.len() == d.len());
            for ((sym, _), v) in syms.iter().zip(d) {
                menv.insert(sym.clone(), *v);
            }
        }
        bind_meta_params(meta, &mut menv, block);
        let plan = meta.launch_plan(&menv)?;
        if plan.grid != grid {
            bail!("conv2d grid mismatch: catalog {grid:?} vs manifest {:?}", plan.grid);
        }
        println!("catalog parity conv2d: grid {grid:?} agrees (implicit GEMM)");
    }

    Ok(())
}


/// Bind every meta-parameter symbol (block sizes — `BLOCK_SIZE*` or the
/// auto-generated `_ntc_block_*`) in the arrangement to `block`.
fn bind_meta_params(meta: &arrange::ArrangementMeta, env: &mut BTreeMap<String, i64>, block: i64) {
    for p in &meta.params {
        for e in &p.indices {
            for s in e.free_symbols() {
                if !s.starts_with("_ntv_") && !s.contains("_size_") {
                    env.entry(s).or_insert(block);
                }
            }
        }
        for (size, _) in p.levels.iter().flatten() {
            for s in size.free_symbols() {
                if !s.starts_with("_ntv_") && !s.contains("_size_") {
                    env.entry(s).or_insert(block);
                }
            }
        }
    }
}

/// Map manifest tensor-name prefixes to parameters.
///
/// The DSL auto-names tensors `tensor_<n>` with a global counter, so the
/// numerically-sorted prefixes correspond to the parameters in declaration
/// order (scalars included — they simply have no size symbols).  Returns
/// `<prefix>_size_<d>` symbols for the given parameter.
fn collect_size_syms(meta: &arrange::ArrangementMeta, name: &str) -> Vec<(String, usize)> {
    // gather every size symbol in the whole arrangement
    let mut all: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
    for p in &meta.params {
        for e in &p.indices {
            all.extend(e.free_symbols());
        }
        for (size, _) in p.levels.iter().flatten() {
            all.extend(size.free_symbols());
        }
    }
    let mut prefixes: Vec<(u64, String)> = all
        .iter()
        .filter_map(|s| {
            let (prefix, _) = s.split_once("_size_")?;
            let n: u64 = prefix.strip_prefix("tensor_")?.parse().ok()?;
            Some((n, prefix.to_string()))
        })
        .collect();
    prefixes.sort();
    prefixes.dedup();
    // zip prefixes with non-scalar params in order
    let non_scalar: Vec<&arrange::ParamMeta> =
        meta.params.iter().filter(|p| p.source_ndim > 0).collect();
    let idx = non_scalar
        .iter()
        .position(|p| p.name == name)
        .expect("param");
    let prefix = &prefixes[idx].1;
    let param = meta.params.iter().find(|p| p.name == name).expect("param");
    (0..param.source_ndim)
        .map(|d| (format!("{prefix}_size_{d}"), d))
        .collect()
}
