//! `repro serve` — the serving entry point, in two modes:
//!
//! * **demo** (default): drive the coordinator with a synthetic mixed
//!   workload and print the serving metrics (latency percentiles,
//!   batching factor, plan-cache hit rate, coalesced requests).
//! * **server** (`--addr HOST:PORT`): expose the coordinator over TCP —
//!   length-prefixed JSON frames, see `docs/wire-protocol.md` — and run
//!   until a wire `shutdown` op arrives, then drain gracefully and print
//!   the final stats table.  `cargo run --example client` drives it.
//!
//! Flags (all validated at startup; env fallbacks in parentheses):
//! `--addr HOST:PORT`, `--workers N`, `--requests N`, `--pool-threads N`
//! (`NT_POOL_THREADS`), `--coalesce-fanin N` (`NT_COALESCE_FANIN`),
//! `--plan-cache-cap N` (`NT_PLAN_CACHE_CAP`), `--queue-cap N`
//! (`NT_QUEUE_CAP`), `--shed-watermark N` (`NT_SHED_WATERMARK`).  The
//! wire timeouts are env-only: `NT_NET_READ_TIMEOUT_MS`,
//! `NT_NET_WRITE_TIMEOUT_MS`, `NT_NET_MAX_FRAME_MB`.

use std::sync::Arc;

use anyhow::Result;

use crate::artifacts_dir;
use crate::cli::Args;
use crate::coordinator::net::{NetConfig, Server};
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::exec::pool;
use crate::prng::SplitMix64;
use crate::runtime::{HostTensor, Manifest};

pub fn run(args: &Args) -> Result<()> {
    let manifest = Arc::new(Manifest::load_or_builtin(&artifacts_dir()));
    let requests = args.opt_usize("requests", 64);
    let mut config = CoordinatorConfig::default().from_env()?;
    config.workers = args.opt_positive("workers")?.unwrap_or(2);
    if let Some(v) = args.opt_positive("coalesce-fanin")? {
        config.coalesce_fanin = v;
    }
    if let Some(v) = args.opt_positive("plan-cache-cap")? {
        config.plan_cache_capacity = v;
    }
    if let Some(v) = args.opt_positive("queue-cap")? {
        config.queue_capacity = v;
    }
    if let Some(v) = args.opt_positive("shed-watermark")? {
        config.shed_watermark = Some(v);
    }
    config.validate()?;
    if let Some(v) = args.opt_positive("pool-threads")? {
        if !pool::init_global(v) {
            println!("(pool already initialized; --pool-threads {v} ignored)");
        }
    }

    if let Some(addr) = args.opt("addr") {
        return serve_tcp(manifest, config, addr);
    }
    println!(
        "starting coordinator: {} workers, {requests} requests, coalesce fan-in {}, \
         plan cache {} ({})",
        config.workers,
        config.coalesce_fanin,
        config.plan_cache_capacity,
        if manifest.kernels.is_empty() { "native backend" } else { "AOT artifacts" }
    );
    let coordinator = Coordinator::start(manifest.clone(), config.clone())?;

    // artifact slot when present; natively any shape works
    let slot = manifest
        .kernel("add", "nt")
        .map(|a| a.args[0].shape[0])
        .unwrap_or(65536);
    let softmax_shape = manifest
        .kernel("softmax", "nt")
        .map(|a| a.args[0].shape.clone())
        .unwrap_or_else(|_| vec![64, 256]);

    // warm each worker's lazy compile cache before the measured burst
    let mut rng0 = SplitMix64::new(1);
    let warm = HostTensor::randn(vec![slot], &mut rng0);
    for _ in 0..config.workers {
        let rx = coordinator.submit("add", "nt", vec![warm.clone(), warm.clone()])?;
        rx.recv()??;
    }

    let mut rng = SplitMix64::new(2024);
    let mut receivers = Vec::new();
    for i in 0..requests {
        match i % 3 {
            0 => {
                // variable-length adds exercise slot packing
                let n = 1024 + rng.below((slot / 8) as u64) as usize;
                let x = HostTensor::randn(vec![n], &mut rng);
                let y = HostTensor::randn(vec![n], &mut rng);
                receivers.push(("add", coordinator.submit("add", "nt", vec![x, y])?));
            }
            1 => {
                let n = 512 + rng.below((slot / 16) as u64) as usize;
                let x = HostTensor::randn(vec![n], &mut rng);
                receivers.push(("silu", coordinator.submit("silu", "nt", vec![x])?));
            }
            _ => {
                // same-shape softmaxes: natively these coalesce into
                // stacked launches AND hit one cached plan after the first
                let x = HostTensor::randn(softmax_shape.clone(), &mut rng);
                receivers.push(("softmax", coordinator.submit("softmax", "nt", vec![x])?));
            }
        }
    }

    let mut ok = 0;
    let mut max_batch = 1;
    for (kernel, rx) in receivers {
        let resp = rx.recv()??;
        ok += 1;
        max_batch = max_batch.max(resp.batch_size);
        if ok <= 3 {
            println!(
                "  {kernel}: batch={} queue={}µs exec={}µs out[0] len={}",
                resp.batch_size, resp.queue_us, resp.exec_us, resp.outputs[0].len()
            );
        }
    }
    println!("completed {ok}/{requests}; largest fused batch: {max_batch}");
    // the full observability snapshot (its global section is the former
    // metrics render): per-kernel rows, trace waterfall, pool gauges
    print!("{}", coordinator.obs_snapshot().render_table());
    coordinator.shutdown();
    Ok(())
}

/// Server mode: bind `addr`, serve wire requests until a `shutdown` op
/// arrives, drain, and print the final observability table.
fn serve_tcp(manifest: Arc<Manifest>, config: CoordinatorConfig, addr: &str) -> Result<()> {
    let net = NetConfig { addr: addr.to_string(), ..NetConfig::default() }.from_env()?;
    let coordinator = Arc::new(Coordinator::start(manifest, config.clone())?);
    let server = Server::start(coordinator.clone(), net)?;
    println!(
        "listening on {} ({} workers, queue {} / shed at {})",
        server.local_addr(),
        config.workers,
        config.queue_capacity,
        config.effective_shed_watermark(),
    );
    // blocks until a client sends {"op":"shutdown"}, then stops accepting,
    // flushes in-flight replies and joins the connection threads
    server.wait();
    // flush anything still queued and stop the workers
    coordinator.drain();
    println!("drained; final stats:");
    print!("{}", coordinator.obs_snapshot().render_table());
    Ok(())
}
