//! Golden-case verification: run AOT artifacts against input/output pairs
//! recorded by the Python oracle at export time.  This is the end-to-end
//! numerical check of the whole chain: DSL codegen -> HLO text -> PJRT
//! compile -> execute from Rust.
//!
//! [`check_native`] is the artifact-free analogue: the native
//! tile-execution backend checked against the in-crate reference oracles.

use anyhow::{bail, Result};

use crate::exec::{self, GridScheduler};
use crate::prng::SplitMix64;
use crate::runtime::{HostTensor, Registry};

const TOL: f32 = 2e-4;

/// Native-backend tolerance (ISSUE acceptance: max |diff| ≤ 1e-4).
const NATIVE_TOL: f32 = 1e-4;

/// Cross-check every native tile program against its reference oracle,
/// serial and pooled.  Returns the number of (kernel, scheduler) cases.
/// The builtin kernels `check_native` must always cover — a builtin
/// missing from the sweep (dropped fixtures, failed registration, or a
/// regression to non-executable) is a loud failure, while fixture-less
/// extras (conv2d until it is lowerable; runtime-registered custom
/// kernels) are skipped.
const GOLDEN_BUILTINS: &[&str] = &[
    "add",
    "silu",
    "gelu",
    "softmax",
    "rms_norm",
    "layer_norm",
    "mm",
    "bmm",
    "addmm",
    "rope",
    "sdpa",
    "sdpa_bias",
];

pub fn check_native() -> Result<usize> {
    let mut rng = SplitMix64::new(2025);
    let mut cases = 0;
    let mut covered: Vec<String> = Vec::new();
    for kernel in exec::kernels() {
        let Ok(inputs) = native_task_inputs(&kernel.name, &mut rng) else {
            continue;
        };
        if !kernel.executable() {
            bail!(
                "kernel {} has smoke inputs but derived non-executable: {}",
                kernel.name,
                kernel.probe_error().unwrap_or("unknown probe failure")
            );
        }
        let expected = exec::reference::run(&kernel.name, &inputs)?;
        for scheduler in [GridScheduler::serial(), GridScheduler::pooled(4)] {
            let got = kernel.run(&inputs, &scheduler)?;
            for (g, e) in got.iter().zip(&expected) {
                let diff = g.max_abs_diff(e)?;
                if diff > NATIVE_TOL {
                    bail!(
                        "native {} ({} threads): max|diff| = {diff} > {NATIVE_TOL}",
                        kernel.name,
                        scheduler.threads,
                    );
                }
                println!(
                    "native {}.{}t: max|diff| = {diff:.2e}",
                    kernel.name, scheduler.threads
                );
            }
            cases += 1;
        }
        covered.push(kernel.name.clone());
    }
    for name in GOLDEN_BUILTINS {
        if !covered.iter().any(|c| c == name) {
            bail!("builtin kernel {name} was not golden-checked (missing or not registered)");
        }
    }
    Ok(cases)
}

/// Deterministic inputs for a native kernel (edge-exercising odd sizes).
pub fn native_task_inputs(name: &str, rng: &mut SplitMix64) -> Result<Vec<HostTensor>> {
    Ok(match name {
        "add" => vec![
            HostTensor::randn(vec![1000], rng),
            HostTensor::randn(vec![1000], rng),
        ],
        "silu" => vec![HostTensor::randn(vec![777], rng)],
        "gelu" => vec![HostTensor::randn(vec![513], rng)],
        "softmax" => vec![HostTensor::randn(vec![7, 301], rng)],
        "rms_norm" => vec![HostTensor::randn(vec![5, 257], rng)],
        "layer_norm" => vec![HostTensor::randn(vec![6, 259], rng)],
        "mm" => vec![
            HostTensor::randn(vec![70, 50], rng),
            HostTensor::randn(vec![50, 90], rng),
        ],
        "bmm" => vec![
            HostTensor::randn(vec![3, 33, 17], rng),
            HostTensor::randn(vec![3, 17, 29], rng),
        ],
        "addmm" => vec![
            HostTensor::randn(vec![90], rng), // rank-1 bias: broadcast over rows
            HostTensor::randn(vec![70, 50], rng),
            HostTensor::randn(vec![50, 90], rng),
        ],
        "rope" => vec![
            HostTensor::randn(vec![2, 7, 3, 16], rng),
            HostTensor::randn(vec![7, 8], rng),
            HostTensor::randn(vec![7, 8], rng),
        ],
        // seq 100 is deliberately not a multiple of the 64-wide attention
        // blocks: two key/value loop steps, the second one padded
        "sdpa" => vec![
            HostTensor::randn(vec![2, 2, 100, 16], rng),
            HostTensor::randn(vec![2, 2, 100, 16], rng),
            HostTensor::randn(vec![2, 2, 100, 16], rng),
        ],
        "sdpa_bias" => vec![
            HostTensor::randn(vec![2, 2, 75, 8], rng),
            HostTensor::randn(vec![2, 2, 75, 8], rng),
            HostTensor::randn(vec![2, 2, 75, 8], rng),
            HostTensor::randn(vec![75, 75], rng),
        ],
        other => bail!("no native task inputs for kernel {other:?}"),
    })
}

pub fn check_all(registry: &Registry) -> Result<()> {
    let manifest = registry.manifest();
    if manifest.goldens.is_empty() {
        bail!("manifest has no golden cases — re-run `make artifacts`");
    }
    for case in manifest.goldens.clone() {
        let inputs: Vec<HostTensor> = case
            .inputs
            .iter()
            .map(|rel| HostTensor::from_f32_file(&manifest.artifact_path(rel), case.shape.clone()))
            .collect::<Result<_>>()?;
        let expected =
            HostTensor::from_f32_file(&manifest.artifact_path(&case.output), case.shape.clone())?;
        for variant in ["nt", "baseline", "ref"] {
            let exe = registry.kernel(&case.kernel, variant)?;
            let out = exe.run(&inputs)?;
            let diff = out[0].max_abs_diff(&expected)?;
            if diff > TOL {
                bail!(
                    "golden mismatch for {}.{}: max|diff| = {diff}",
                    case.kernel,
                    variant
                );
            }
            println!("golden {}.{variant}: max|diff| = {diff:.2e}", case.kernel);
        }
    }
    Ok(())
}
