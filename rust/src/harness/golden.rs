//! Golden-case verification: run AOT artifacts against input/output pairs
//! recorded by the Python oracle at export time.  This is the end-to-end
//! numerical check of the whole chain: DSL codegen -> HLO text -> PJRT
//! compile -> execute from Rust.

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, Registry};

const TOL: f32 = 2e-4;

pub fn check_all(registry: &Registry) -> Result<()> {
    let manifest = registry.manifest();
    if manifest.goldens.is_empty() {
        bail!("manifest has no golden cases — re-run `make artifacts`");
    }
    for case in manifest.goldens.clone() {
        let inputs: Vec<HostTensor> = case
            .inputs
            .iter()
            .map(|rel| HostTensor::from_f32_file(&manifest.artifact_path(rel), case.shape.clone()))
            .collect::<Result<_>>()?;
        let expected =
            HostTensor::from_f32_file(&manifest.artifact_path(&case.output), case.shape.clone())?;
        for variant in ["nt", "baseline", "ref"] {
            let exe = registry.kernel(&case.kernel, variant)?;
            let out = exe.run(&inputs)?;
            let diff = out[0].max_abs_diff(&expected)?;
            if diff > TOL {
                bail!(
                    "golden mismatch for {}.{}: max|diff| = {diff}",
                    case.kernel,
                    variant
                );
            }
            println!("golden {}.{variant}: max|diff| = {diff:.2e}", case.kernel);
        }
    }
    Ok(())
}
