//! `repro tune` — pre-tune a kernel/shape list and write the tuning table.
//!
//! Runs the [`crate::exec::Tuner`] directly (no coordinator): for each
//! task it prints the candidate space, the elected winner, and the search
//! cost, then persists every winner to the table so a later serving
//! process (`NT_TUNE=first_use NT_TUNE_TABLE=...`) restores them with
//! zero re-measurement.
//!
//! Flags:
//!   `--smoke`          only the `repro stats` burst shapes (the CI list)
//!   `--table PATH`     tuning-table path (default `NT_TUNE_TABLE`,
//!                      falling back to `tune_table.json`)
//!   `--kernels a,b,c`  restrict to the named kernels
//!
//! `NT_TUNE=exhaustive` disables the search's early exit; any other value
//! (or none) tunes first-use style.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::exec::{GridScheduler, PlanCache, TuneMode, Tuner};
use crate::harness::golden;
use crate::prng::SplitMix64;
use crate::runtime::HostTensor;

/// The tunable workload: the `repro stats` burst shapes first (so a table
/// written with `--smoke` warm-starts the stats burst exactly), then the
/// gated bench shapes.
fn tasks(smoke: bool, rng: &mut SplitMix64) -> Result<Vec<(String, Vec<HostTensor>)>> {
    let mut out = Vec::new();
    for kernel in ["mm", "softmax", "sdpa", "add"] {
        out.push((kernel.to_string(), golden::native_task_inputs(kernel, rng)?));
    }
    if !smoke {
        out.push((
            "mm".to_string(),
            vec![
                HostTensor::randn(vec![512, 512], rng),
                HostTensor::randn(vec![512, 512], rng),
            ],
        ));
        out.push((
            "sdpa".to_string(),
            (0..3).map(|_| HostTensor::randn(vec![1, 4, 256, 64], rng)).collect(),
        ));
    }
    Ok(out)
}

pub fn run(args: &Args) -> Result<()> {
    let mode = match TuneMode::from_env()? {
        // `repro tune` exists to tune: off would make it a no-op
        TuneMode::Off => TuneMode::FirstUse,
        mode => mode,
    };
    let table_path = args
        .opt("table")
        .map(PathBuf::from)
        .or_else(|| std::env::var("NT_TUNE_TABLE").ok().map(PathBuf::from))
        .unwrap_or_else(|| PathBuf::from("tune_table.json"));
    let only: Option<Vec<String>> =
        args.opt("kernels").map(|v| v.split(',').map(|k| k.trim().to_string()).collect());

    let plans = Arc::new(PlanCache::new(256));
    let tuner = Tuner::new(mode, Some(table_path.clone()), plans);
    let restored = tuner.restore();
    println!(
        "tuning table: {} (restored {restored} winner(s)); mode: {}",
        table_path.display(),
        mode.as_str()
    );

    let scheduler = GridScheduler::default();
    let mut rng = SplitMix64::new(99);
    let mut tuned = 0usize;
    for (kernel_name, inputs) in tasks(args.flag("smoke"), &mut rng)? {
        if let Some(only) = &only {
            if !only.contains(&kernel_name) {
                continue;
            }
        }
        let Some(kernel) = crate::exec::lookup(&kernel_name) else {
            println!("  {kernel_name:<8} unknown kernel, skipped");
            continue;
        };
        let shapes: Vec<&[usize]> = inputs.iter().map(|t| t.shape.as_slice()).collect();
        let sig = crate::obs::shape_sig(&shapes);
        let candidates = kernel.meta_candidates(&shapes)?;
        if candidates.len() <= 1 {
            println!("  {kernel_name:<8} {sig:<22} untunable (single candidate)");
            continue;
        }
        match tuner.maybe_tune(&kernel, "nt", &inputs, &scheduler)? {
            Some(outcome) => {
                let winner: Vec<String> =
                    outcome.winner.iter().map(|(k, v)| format!("{k}={v}")).collect();
                println!(
                    "  {kernel_name:<8} {sig:<22} candidates={} winner=#{} {} best={}µs \
                     measurements={} skipped={}",
                    outcome.candidates,
                    outcome.winner_index,
                    winner.join(" "),
                    outcome.best_us,
                    outcome.measurements,
                    outcome.skipped,
                );
                tuned += 1;
            }
            None => println!("  {kernel_name:<8} {sig:<22} warm (winner already installed)"),
        }
    }
    println!(
        "summary: tuned={tuned} measurements={} tune_ms={:.1} table={}",
        tuner.measurements(),
        tuner.tune_us_total() as f64 / 1000.0,
        table_path.display()
    );
    Ok(())
}
