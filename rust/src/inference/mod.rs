//! End-to-end autoregressive inference engine (paper §5.3.2 / Fig 7).
//!
//! Loads the tiny-Llama weights and the prefill/decode AOT artifacts for a
//! kernel variant, then runs greedy decoding with the KV cache
//! round-tripping through the fixed-shape decode step.  Python is not
//! involved: this is the L3 request path.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::prng::SplitMix64;
use crate::runtime::{Executable, HostTensor, Registry};

pub struct Engine {
    variant: String,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    /// weight literals, prebuilt once (the decode hot loop reuses them)
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub prompt_len: usize,
    pub max_seq: usize,
    pub vocab: usize,
}

#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub tokens: Vec<Vec<i32>>, // [batch][steps]
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub steps: usize,
    /// end-to-end tokens/second over generated tokens (the Fig 7 metric)
    pub tokens_per_s: f64,
}

impl Engine {
    pub fn new(registry: Arc<Registry>, variant: &str) -> Result<Engine> {
        let manifest = registry.manifest_arc();
        let model = manifest
            .model
            .as_ref()
            .context("manifest has no model section — re-run `make artifacts`")?;
        let prefill = registry.model_step("prefill", variant)?;
        let decode = registry.model_step("decode", variant)?;

        // load the weight blob and slice it per the manifest table
        let blob = std::fs::read(manifest.artifact_path(&model.weights_path))
            .context("reading weights.bin")?;
        let mut weights = Vec::with_capacity(model.weights.len());
        for entry in &model.weights {
            let bytes = blob
                .get(entry.offset..entry.offset + entry.nbytes)
                .with_context(|| format!("weight {} out of range", entry.name))?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let t = HostTensor::f32(entry.shape.clone(), data)?;
            weights.push(t.to_literal()?);
        }

        Ok(Engine {
            variant: variant.to_string(),
            prefill,
            decode,
            weights,
            batch: model.batch,
            prompt_len: model.prompt,
            max_seq: model.max_seq,
            vocab: model.vocab_size,
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    /// A deterministic synthetic prompt (the Fig 7 workload generator).
    pub fn synth_prompt(&self, seed: u64) -> Vec<i32> {
        let mut rng = SplitMix64::new(seed);
        (0..self.batch * self.prompt_len)
            .map(|_| rng.below(self.vocab as u64) as i32)
            .collect()
    }

    /// Greedy-decode `steps` tokens after prefilling `prompt`
    /// (row-major `[batch, prompt_len]`).
    pub fn generate(&self, prompt: &[i32], steps: usize) -> Result<DecodeResult> {
        if prompt.len() != self.batch * self.prompt_len {
            bail!(
                "prompt must be batch*prompt_len = {} tokens, got {}",
                self.batch * self.prompt_len,
                prompt.len()
            );
        }
        if self.prompt_len + steps > self.max_seq {
            bail!(
                "prompt {} + steps {} exceeds the compiled KV-cache capacity {}",
                self.prompt_len,
                steps,
                self.max_seq
            );
        }
        let tokens_lit = HostTensor::i32(
            vec![self.batch, self.prompt_len],
            prompt.to_vec(),
        )?
        .to_literal()?;

        // ---- prefill ---------------------------------------------------------
        let t0 = Instant::now();
        let mut inputs: Vec<&xla::Literal> = self.weights.iter().collect();
        inputs.push(&tokens_lit);
        let outs = self.prefill.run_literals(&inputs)?;
        let prefill_time = t0.elapsed();
        let (logits, mut cache_k, mut cache_v) = take3(outs)?;

        let mut tokens: Vec<Vec<i32>> = vec![Vec::with_capacity(steps); self.batch];
        let mut next = argmax_rows(&HostTensor::from_literal(&logits)?)?;
        for (b, t) in next.iter().enumerate() {
            tokens[b].push(*t);
        }

        // ---- decode loop ------------------------------------------------------
        let t0 = Instant::now();
        let mut pos = self.prompt_len as i32;
        for _ in 1..steps {
            let token_lit = HostTensor::i32(vec![self.batch], next.clone())?.to_literal()?;
            let pos_lit = xla::Literal::scalar(pos);
            let mut inputs: Vec<&xla::Literal> = self.weights.iter().collect();
            inputs.push(&token_lit);
            inputs.push(&pos_lit);
            inputs.push(&cache_k);
            inputs.push(&cache_v);
            let outs = self.decode.run_literals(&inputs)?;
            let (logits, ck, cv) = take3(outs)?;
            cache_k = ck;
            cache_v = cv;
            next = argmax_rows(&HostTensor::from_literal(&logits)?)?;
            for (b, t) in next.iter().enumerate() {
                tokens[b].push(*t);
            }
            pos += 1;
        }
        let decode_time = t0.elapsed();

        let generated = (steps * self.batch) as f64;
        let total = prefill_time.as_secs_f64() + decode_time.as_secs_f64();
        Ok(DecodeResult {
            tokens,
            prefill_time,
            decode_time,
            steps,
            tokens_per_s: generated / total,
        })
    }
}

fn take3(mut outs: Vec<xla::Literal>) -> Result<(xla::Literal, xla::Literal, xla::Literal)> {
    if outs.len() != 3 {
        bail!("model step returned {} outputs, expected 3", outs.len());
    }
    let c = outs.pop().unwrap();
    let b = outs.pop().unwrap();
    let a = outs.pop().unwrap();
    Ok((a, b, c))
}

fn argmax_rows(logits: &HostTensor) -> Result<Vec<i32>> {
    let data = logits.as_f32()?;
    if logits.shape.len() != 2 {
        bail!("logits must be 2-D, got {:?}", logits.shape);
    }
    let (rows, cols) = (logits.shape[0], logits.shape[1]);
    let mut out = Vec::with_capacity(rows);
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let mut best = 0usize;
        for (i, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = i;
            }
        }
        out.push(best as i32);
    }
    Ok(out)
}
