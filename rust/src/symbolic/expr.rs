//! Expression trees: construction, folding, substitution, evaluation,
//! interval bounds.  Semantics match Python exactly (floor division and
//! modulo follow Python's sign rules).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
// Atomically refcounted nodes: expressions are built/cloned at
// specialization time only (never in the execute hot path), and making
// them `Send + Sync` lets `kernel::KernelDef` — which stores symbolic
// shape specs — be shared across coordinator workers behind one `Arc`.
use std::sync::Arc;

#[derive(Debug)]
pub enum ExprError {
    Unbound(String),
    DivZero(String),
    Unbounded(String),
    NotConst(String),
}

impl fmt::Display for ExprError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExprError::Unbound(s) => write!(f, "unbound symbol {s:?}"),
            ExprError::DivZero(e) => write!(f, "division by zero in {e}"),
            ExprError::Unbounded(e) => write!(f, "cannot bound {e}"),
            ExprError::NotConst(e) => write!(f, "{e} is not constant"),
        }
    }
}

impl std::error::Error for ExprError {}

/// A symbolic integer expression.  Cheap to clone (`Arc` nodes).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Expr {
    Const(i64),
    Sym(Arc<str>),
    Add(Arc<Expr>, Arc<Expr>),
    Sub(Arc<Expr>, Arc<Expr>),
    Mul(Arc<Expr>, Arc<Expr>),
    FloorDiv(Arc<Expr>, Arc<Expr>),
    Mod(Arc<Expr>, Arc<Expr>),
    /// ceiling division — `cdiv(a, b)` in the manifest
    CeilDiv(Arc<Expr>, Arc<Expr>),
    Min(Arc<Expr>, Arc<Expr>),
    Max(Arc<Expr>, Arc<Expr>),
    Neg(Arc<Expr>),
}

/// Python floor division (rounds toward negative infinity).
pub fn py_floordiv(a: i64, b: i64) -> i64 {
    let q = a / b;
    if (a % b != 0) && ((a < 0) != (b < 0)) {
        q - 1
    } else {
        q
    }
}

/// Python modulo (result has the divisor's sign).
pub fn py_mod(a: i64, b: i64) -> i64 {
    let r = a % b;
    if r != 0 && ((r < 0) != (b < 0)) {
        r + b
    } else {
        r
    }
}

/// Python-semantics ceiling division, as the manifest's `cdiv` helper
/// (`-(-a // b)`).
pub fn py_cdiv(a: i64, b: i64) -> i64 {
    -py_floordiv(-a, b)
}

impl Expr {
    pub fn sym(name: &str) -> Expr {
        Expr::Sym(Arc::from(name))
    }

    pub fn constant(&self) -> Option<i64> {
        match self {
            Expr::Const(c) => Some(*c),
            _ => None,
        }
    }

    // -- folding constructors (mirror symbols.py `_fold`) ---------------------

    pub fn add(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) => Expr::Const(x + y),
            (Some(0), _) => b,
            (_, Some(0)) => a,
            _ => Expr::Add(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn sub(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) => Expr::Const(x - y),
            (_, Some(0)) => a,
            _ => Expr::Sub(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn mul(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) => Expr::Const(x * y),
            (Some(0), _) | (_, Some(0)) => Expr::Const(0),
            (Some(1), _) => b,
            (_, Some(1)) => a,
            _ => Expr::Mul(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn floordiv(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) if y != 0 => Expr::Const(py_floordiv(x, y)),
            (_, Some(1)) => a,
            _ => Expr::FloorDiv(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn modulo(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) if y != 0 => Expr::Const(py_mod(x, y)),
            (_, Some(1)) => Expr::Const(0),
            _ => Expr::Mod(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn cdiv(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) if y != 0 => Expr::Const(py_cdiv(x, y)),
            _ if a == b => Expr::Const(1), // structural identity, sizes are positive
            _ => Expr::CeilDiv(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn min2(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) => Expr::Const(x.min(y)),
            _ => Expr::Min(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn max2(a: Expr, b: Expr) -> Expr {
        match (a.constant(), b.constant()) {
            (Some(x), Some(y)) => Expr::Const(x.max(y)),
            _ => Expr::Max(Arc::new(a), Arc::new(b)),
        }
    }

    pub fn neg(a: Expr) -> Expr {
        match a.constant() {
            Some(x) => Expr::Const(-x),
            None => Expr::Neg(Arc::new(a)),
        }
    }

    // -- interrogation ---------------------------------------------------------

    pub fn free_symbols(&self) -> BTreeSet<String> {
        let mut set = BTreeSet::new();
        self.collect_symbols(&mut set);
        set
    }

    fn collect_symbols(&self, set: &mut BTreeSet<String>) {
        match self {
            Expr::Const(_) => {}
            Expr::Sym(s) => {
                set.insert(s.to_string());
            }
            Expr::Neg(a) => a.collect_symbols(set),
            Expr::Add(a, b)
            | Expr::Sub(a, b)
            | Expr::Mul(a, b)
            | Expr::FloorDiv(a, b)
            | Expr::Mod(a, b)
            | Expr::CeilDiv(a, b)
            | Expr::Min(a, b)
            | Expr::Max(a, b) => {
                a.collect_symbols(set);
                b.collect_symbols(set);
            }
        }
    }

    // -- evaluation --------------------------------------------------------------

    pub fn eval(&self, env: &BTreeMap<String, i64>) -> Result<i64, ExprError> {
        match self {
            Expr::Const(c) => Ok(*c),
            Expr::Sym(s) => env
                .get(s.as_ref())
                .copied()
                .ok_or_else(|| ExprError::Unbound(s.to_string())),
            Expr::Neg(a) => Ok(-a.eval(env)?),
            Expr::Add(a, b) => Ok(a.eval(env)? + b.eval(env)?),
            Expr::Sub(a, b) => Ok(a.eval(env)? - b.eval(env)?),
            Expr::Mul(a, b) => Ok(a.eval(env)? * b.eval(env)?),
            Expr::FloorDiv(a, b) => {
                let d = b.eval(env)?;
                if d == 0 {
                    return Err(ExprError::DivZero(self.to_string()));
                }
                Ok(py_floordiv(a.eval(env)?, d))
            }
            Expr::Mod(a, b) => {
                let d = b.eval(env)?;
                if d == 0 {
                    return Err(ExprError::DivZero(self.to_string()));
                }
                Ok(py_mod(a.eval(env)?, d))
            }
            Expr::CeilDiv(a, b) => {
                let d = b.eval(env)?;
                if d == 0 {
                    return Err(ExprError::DivZero(self.to_string()));
                }
                Ok(py_cdiv(a.eval(env)?, d))
            }
            Expr::Min(a, b) => Ok(a.eval(env)?.min(b.eval(env)?)),
            Expr::Max(a, b) => Ok(a.eval(env)?.max(b.eval(env)?)),
        }
    }

    /// Partial evaluation: substitute bound symbols, fold what folds.
    pub fn substitute(&self, env: &BTreeMap<String, Expr>) -> Expr {
        match self {
            Expr::Const(c) => Expr::Const(*c),
            Expr::Sym(s) => env.get(s.as_ref()).cloned().unwrap_or_else(|| self.clone()),
            Expr::Neg(a) => Expr::neg(a.substitute(env)),
            Expr::Add(a, b) => Expr::add(a.substitute(env), b.substitute(env)),
            Expr::Sub(a, b) => Expr::sub(a.substitute(env), b.substitute(env)),
            Expr::Mul(a, b) => Expr::mul(a.substitute(env), b.substitute(env)),
            Expr::FloorDiv(a, b) => Expr::floordiv(a.substitute(env), b.substitute(env)),
            Expr::Mod(a, b) => Expr::modulo(a.substitute(env), b.substitute(env)),
            Expr::CeilDiv(a, b) => Expr::cdiv(a.substitute(env), b.substitute(env)),
            Expr::Min(a, b) => Expr::min2(a.substitute(env), b.substitute(env)),
            Expr::Max(a, b) => Expr::max2(a.substitute(env), b.substitute(env)),
        }
    }

    // -- interval bounds (mirror of symbols.py `_bounds`) --------------------------

    /// Conservative interval of the expression given per-symbol ranges.
    /// Used to compute padded extents (the pad-and-crop launch plan).
    pub fn bounds(
        &self,
        ranges: &BTreeMap<String, (i64, i64)>,
    ) -> Result<(i64, i64), ExprError> {
        match self {
            Expr::Const(c) => Ok((*c, *c)),
            Expr::Sym(s) => ranges
                .get(s.as_ref())
                .copied()
                .ok_or_else(|| ExprError::Unbound(s.to_string())),
            Expr::Neg(a) => {
                let (lo, hi) = a.bounds(ranges)?;
                Ok((-hi, -lo))
            }
            Expr::Add(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                Ok((alo + blo, ahi + bhi))
            }
            Expr::Sub(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                Ok((alo - bhi, ahi - blo))
            }
            Expr::Mul(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                let p = [alo * blo, alo * bhi, ahi * blo, ahi * bhi];
                Ok((*p.iter().min().unwrap(), *p.iter().max().unwrap()))
            }
            Expr::FloorDiv(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                if blo <= 0 {
                    return Err(ExprError::Unbounded(self.to_string()));
                }
                let c = [
                    py_floordiv(alo, blo),
                    py_floordiv(alo, bhi),
                    py_floordiv(ahi, blo),
                    py_floordiv(ahi, bhi),
                ];
                Ok((*c.iter().min().unwrap(), *c.iter().max().unwrap()))
            }
            Expr::Mod(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                if blo <= 0 {
                    return Err(ExprError::Unbounded(self.to_string()));
                }
                if alo >= 0 {
                    Ok((0, ahi.min(bhi - 1)))
                } else {
                    Ok((-(bhi - 1), bhi - 1))
                }
            }
            Expr::CeilDiv(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                if blo <= 0 {
                    return Err(ExprError::Unbounded(self.to_string()));
                }
                let c = [
                    py_cdiv(alo, blo),
                    py_cdiv(alo, bhi),
                    py_cdiv(ahi, blo),
                    py_cdiv(ahi, bhi),
                ];
                Ok((*c.iter().min().unwrap(), *c.iter().max().unwrap()))
            }
            Expr::Min(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                Ok((alo.min(blo), ahi.min(bhi)))
            }
            Expr::Max(a, b) => {
                let (alo, ahi) = a.bounds(ranges)?;
                let (blo, bhi) = b.bounds(ranges)?;
                Ok((alo.max(blo), ahi.max(bhi)))
            }
        }
    }
}

impl fmt::Display for Expr {
    /// Renders with the same conventions as Python's `ast.unparse`
    /// (fully parenthesized where precedence demands it).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fn prec(e: &Expr) -> u8 {
            match e {
                Expr::Add(..) | Expr::Sub(..) => 1,
                Expr::Mul(..) | Expr::FloorDiv(..) | Expr::Mod(..) => 2,
                Expr::Neg(..) => 3,
                _ => 4,
            }
        }
        fn go(e: &Expr, parent: u8, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            let p = prec(e);
            let need = p < parent;
            if need {
                write!(f, "(")?;
            }
            match e {
                Expr::Const(c) => write!(f, "{c}")?,
                Expr::Sym(s) => write!(f, "{s}")?,
                Expr::Neg(a) => {
                    write!(f, "-")?;
                    go(a, 3, f)?;
                }
                Expr::Add(a, b) => {
                    go(a, 1, f)?;
                    write!(f, " + ")?;
                    go(b, 2, f)?;
                }
                Expr::Sub(a, b) => {
                    go(a, 1, f)?;
                    write!(f, " - ")?;
                    go(b, 2, f)?;
                }
                Expr::Mul(a, b) => {
                    go(a, 2, f)?;
                    write!(f, " * ")?;
                    go(b, 3, f)?;
                }
                Expr::FloorDiv(a, b) => {
                    go(a, 2, f)?;
                    write!(f, " // ")?;
                    go(b, 3, f)?;
                }
                Expr::Mod(a, b) => {
                    go(a, 2, f)?;
                    write!(f, " % ")?;
                    go(b, 3, f)?;
                }
                Expr::CeilDiv(a, b) => {
                    write!(f, "cdiv(")?;
                    go(a, 0, f)?;
                    write!(f, ", ")?;
                    go(b, 0, f)?;
                    write!(f, ")")?;
                }
                Expr::Min(a, b) => {
                    write!(f, "min(")?;
                    go(a, 0, f)?;
                    write!(f, ", ")?;
                    go(b, 0, f)?;
                    write!(f, ")")?;
                }
                Expr::Max(a, b) => {
                    write!(f, "max(")?;
                    go(a, 0, f)?;
                    write!(f, ", ")?;
                    go(b, 0, f)?;
                    write!(f, ")")?;
                }
            }
            if need {
                write!(f, ")")?;
            }
            Ok(())
        }
        go(self, 0, f)
    }
}
