//! Rust mirror of the NineToothed symbolic-expression algebra
//! (`python/compile/ninetoothed/symbols.py`).
//!
//! The AOT manifest carries every arranged parameter's index expressions
//! (source-to-target mapping, paper §3.2.2) and level-size expressions
//! (tile-to-program mapping, §3.2.1) as rendered Python expressions.  This
//! module parses, simplifies, evaluates and bounds them so the coordinator
//! can *independently* validate arrangements and compute launch plans —
//! grid sizes, padded extents, per-program offsets — without Python.

mod expr;
mod parser;

pub use expr::{Expr, ExprError};
pub use parser::parse;

#[cfg(test)]
mod tests;
