//! Unit + property tests for the symbolic mirror.  The property tests use
//! the in-repo PRNG (`crate::prng`) as the offline stand-in for proptest.

use std::collections::BTreeMap;

use super::{parse, Expr};
use crate::prng::SplitMix64;

fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

#[test]
fn parses_and_evaluates_basic() {
    let e = parse("a * 4 + b").unwrap();
    assert_eq!(e.eval(&env(&[("a", 3), ("b", 5)])).unwrap(), 17);
}

#[test]
fn parses_cdiv_min_max() {
    let e = parse("cdiv(a, 4) + min(a, 3) + max(a, 100)").unwrap();
    assert_eq!(e.eval(&env(&[("a", 10)])).unwrap(), 3 + 3 + 100);
}

#[test]
fn parses_floordiv_mod_precedence() {
    // (w // 5) % 3 == w // 5 % 3 under Python precedence
    let a = parse("(w // 5) % 3").unwrap();
    let b = parse("w // 5 % 3").unwrap();
    for w in 0..100 {
        let e = env(&[("w", w)]);
        assert_eq!(a.eval(&e).unwrap(), b.eval(&e).unwrap());
    }
}

#[test]
fn python_division_semantics() {
    let e = parse("a // b").unwrap();
    assert_eq!(e.eval(&env(&[("a", -7), ("b", 2)])).unwrap(), -4); // not -3
    let m = parse("a % b").unwrap();
    assert_eq!(m.eval(&env(&[("a", -7), ("b", 2)])).unwrap(), 1);
}

#[test]
fn unary_minus() {
    let e = parse("-a + -3").unwrap();
    assert_eq!(e.eval(&env(&[("a", 5)])).unwrap(), -8);
}

#[test]
fn folding_via_substitute() {
    let e = parse("a * b + c").unwrap();
    let sub: BTreeMap<String, Expr> = [
        ("a".to_string(), Expr::Const(0)),
        ("c".to_string(), Expr::sym("d")),
    ]
    .into_iter()
    .collect();
    let folded = e.substitute(&sub);
    assert_eq!(folded, Expr::sym("d"));
}

#[test]
fn unbound_symbol_errors() {
    let e = parse("a + b").unwrap();
    assert!(e.eval(&env(&[("a", 1)])).is_err());
}

#[test]
fn rejects_bad_syntax() {
    assert!(parse("a +").is_err());
    assert!(parse("(a").is_err());
    assert!(parse("foo(a, b)").is_err());
    assert!(parse("a ** b").is_err());
}

#[test]
fn display_roundtrip() {
    for src in [
        "a * 4 + b",
        "(a + b) * c",
        "cdiv(x_size_0, 64)",
        "(w // 5) % 3",
        "a - (b - c)",
        "-a * 3",
    ] {
        let e = parse(src).unwrap();
        let e2 = parse(&e.to_string()).unwrap();
        let vars = ["a", "b", "c", "w", "x_size_0"];
        let mut rng = SplitMix64::new(7);
        for _ in 0..50 {
            let mut bindings = BTreeMap::new();
            for v in vars {
                bindings.insert(v.to_string(), (rng.next_u64() % 97) as i64 + 1);
            }
            assert_eq!(e.eval(&bindings).unwrap(), e2.eval(&bindings).unwrap(), "{src}");
        }
    }
}

// ---------------------------------------------------------------------------
// property tests
// ---------------------------------------------------------------------------

/// Random expression generator for the property tests.
fn random_expr(rng: &mut SplitMix64, depth: usize) -> Expr {
    let vars = ["a", "b", "c"];
    if depth == 0 || rng.next_u64() % 4 == 0 {
        return if rng.next_u64() % 2 == 0 {
            Expr::Const((rng.next_u64() % 21) as i64 - 10)
        } else {
            Expr::sym(vars[(rng.next_u64() % 3) as usize])
        };
    }
    let a = random_expr(rng, depth - 1);
    let b = random_expr(rng, depth - 1);
    match rng.next_u64() % 7 {
        0 => Expr::add(a, b),
        1 => Expr::sub(a, b),
        2 => Expr::mul(a, b),
        3 => Expr::floordiv(a, Expr::max2(b, Expr::Const(1))),
        4 => Expr::modulo(a, Expr::max2(b, Expr::Const(1))),
        5 => Expr::min2(a, b),
        _ => Expr::max2(a, b),
    }
}

#[test]
fn prop_display_parse_roundtrip() {
    let mut rng = SplitMix64::new(42);
    for _ in 0..500 {
        let e = random_expr(&mut rng, 4);
        let parsed = parse(&e.to_string()).unwrap_or_else(|err| {
            panic!("failed to reparse {e}: {err}");
        });
        for trial in 0..10 {
            let bindings = env(&[
                ("a", (trial * 13 % 29) - 5),
                ("b", (trial * 7 % 23) - 3),
                ("c", trial),
            ]);
            assert_eq!(
                e.eval(&bindings).unwrap(),
                parsed.eval(&bindings).unwrap(),
                "mismatch for {e}"
            );
        }
    }
}

#[test]
fn prop_bounds_sound() {
    // bounds() must contain every concrete evaluation — the padding
    // soundness property the generated launch plans rely on.
    let mut rng = SplitMix64::new(9);
    for _ in 0..300 {
        let e = random_expr(&mut rng, 3);
        let mut ranges = BTreeMap::new();
        ranges.insert("a".to_string(), (0i64, 7i64));
        ranges.insert("b".to_string(), (1i64, 5i64));
        ranges.insert("c".to_string(), (2i64, 9i64));
        let Ok((lo, hi)) = e.bounds(&ranges) else {
            continue; // divisor range includes nonpositive values: skipped
        };
        for a in 0..=7 {
            for b in 1..=5 {
                for c in 2..=9 {
                    let bindings = env(&[("a", a), ("b", b), ("c", c)]);
                    let v = e.eval(&bindings).unwrap();
                    assert!(
                        lo <= v && v <= hi,
                        "{e}: value {v} outside [{lo}, {hi}] at a={a} b={b} c={c}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_substitute_commutes_with_eval() {
    let mut rng = SplitMix64::new(123);
    for _ in 0..300 {
        let e = random_expr(&mut rng, 3);
        // substitute a -> 3 then eval(b, c) must equal eval(a=3, b, c)
        let sub: BTreeMap<String, Expr> = [("a".to_string(), Expr::Const(3))].into_iter().collect();
        let subbed = e.substitute(&sub);
        let full = env(&[("a", 3), ("b", 4), ("c", 5)]);
        let partial = env(&[("b", 4), ("c", 5)]);
        match (e.eval(&full), subbed.eval(&partial)) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "{e}"),
            (Err(_), Err(_)) => {}
            (x, y) => panic!("divergent results for {e}: {x:?} vs {y:?}"),
        }
    }
}
