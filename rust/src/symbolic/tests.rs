//! Unit + property tests for the symbolic mirror.  The property tests use
//! the in-repo PRNG (`crate::prng`) as the offline stand-in for proptest.

use std::collections::BTreeMap;

use super::{parse, Expr};
use crate::prng::SplitMix64;

fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

#[test]
fn parses_and_evaluates_basic() {
    let e = parse("a * 4 + b").unwrap();
    assert_eq!(e.eval(&env(&[("a", 3), ("b", 5)])).unwrap(), 17);
}

#[test]
fn parses_cdiv_min_max() {
    let e = parse("cdiv(a, 4) + min(a, 3) + max(a, 100)").unwrap();
    assert_eq!(e.eval(&env(&[("a", 10)])).unwrap(), 3 + 3 + 100);
}

#[test]
fn parses_floordiv_mod_precedence() {
    // (w // 5) % 3 == w // 5 % 3 under Python precedence
    let a = parse("(w // 5) % 3").unwrap();
    let b = parse("w // 5 % 3").unwrap();
    for w in 0..100 {
        let e = env(&[("w", w)]);
        assert_eq!(a.eval(&e).unwrap(), b.eval(&e).unwrap());
    }
}

#[test]
fn python_division_semantics() {
    let e = parse("a // b").unwrap();
    assert_eq!(e.eval(&env(&[("a", -7), ("b", 2)])).unwrap(), -4); // not -3
    let m = parse("a % b").unwrap();
    assert_eq!(m.eval(&env(&[("a", -7), ("b", 2)])).unwrap(), 1);
}

#[test]
fn unary_minus() {
    let e = parse("-a + -3").unwrap();
    assert_eq!(e.eval(&env(&[("a", 5)])).unwrap(), -8);
}

#[test]
fn folding_via_substitute() {
    let e = parse("a * b + c").unwrap();
    let sub: BTreeMap<String, Expr> = [
        ("a".to_string(), Expr::Const(0)),
        ("c".to_string(), Expr::sym("d")),
    ]
    .into_iter()
    .collect();
    let folded = e.substitute(&sub);
    assert_eq!(folded, Expr::sym("d"));
}

#[test]
fn unbound_symbol_errors() {
    let e = parse("a + b").unwrap();
    assert!(e.eval(&env(&[("a", 1)])).is_err());
}

#[test]
fn rejects_bad_syntax() {
    assert!(parse("a +").is_err());
    assert!(parse("(a").is_err());
    assert!(parse("foo(a, b)").is_err());
    assert!(parse("a ** b").is_err());
}

#[test]
fn display_roundtrip() {
    for src in [
        "a * 4 + b",
        "(a + b) * c",
        "cdiv(x_size_0, 64)",
        "(w // 5) % 3",
        "a - (b - c)",
        "-a * 3",
    ] {
        let e = parse(src).unwrap();
        let e2 = parse(&e.to_string()).unwrap();
        let vars = ["a", "b", "c", "w", "x_size_0"];
        let mut rng = SplitMix64::new(7);
        for _ in 0..50 {
            let mut bindings = BTreeMap::new();
            for v in vars {
                bindings.insert(v.to_string(), (rng.next_u64() % 97) as i64 + 1);
            }
            assert_eq!(e.eval(&bindings).unwrap(), e2.eval(&bindings).unwrap(), "{src}");
        }
    }
}

// ---------------------------------------------------------------------------
// parser edge cases
// ---------------------------------------------------------------------------

#[test]
fn precedence_mul_binds_tighter_than_add() {
    let e = parse("a + b * c - d").unwrap();
    // a + (b*c) - d, left-associative additive chain
    assert_eq!(e.eval(&env(&[("a", 1), ("b", 2), ("c", 3), ("d", 4)])).unwrap(), 3);
    let f = parse("(a + b) * (c - d)").unwrap();
    assert_eq!(f.eval(&env(&[("a", 1), ("b", 2), ("c", 3), ("d", 4)])).unwrap(), -3);
}

#[test]
fn precedence_multiplicative_left_associative() {
    // Python: 100 // 7 % 5 * 2 == (((100 // 7) % 5) * 2) == 8
    let e = parse("100 // 7 % 5 * 2").unwrap();
    assert_eq!(e.eval(&BTreeMap::new()).unwrap(), 8);
    // additive chain: 10 - 4 - 3 == 3 (left associative, not 9)
    let f = parse("10 - 4 - 3").unwrap();
    assert_eq!(f.eval(&BTreeMap::new()).unwrap(), 3);
}

#[test]
fn cdiv_floordiv_roundtrip_identity() {
    // cdiv(a, b) == -((-a) // b) for every a, all positive b (the
    // manifest's cdiv helper definition)
    let cdiv = parse("cdiv(a, b)").unwrap();
    let neg = parse("-(-a // b)").unwrap();
    for a in -25..=25 {
        for b in 1..=7 {
            let e = env(&[("a", a), ("b", b)]);
            assert_eq!(cdiv.eval(&e).unwrap(), neg.eval(&e).unwrap(), "a={a} b={b}");
        }
    }
    // and floor/ceil bracket the rational quotient: cdiv - floordiv ∈ {0, 1}
    let floor = parse("a // b").unwrap();
    for a in -25..=25 {
        for b in 1..=7 {
            let e = env(&[("a", a), ("b", b)]);
            let d = cdiv.eval(&e).unwrap() - floor.eval(&e).unwrap();
            assert!(d == 0 || d == 1, "a={a} b={b}: {d}");
            assert_eq!(d == 0, a % b == 0, "a={a} b={b}");
        }
    }
}

#[test]
fn cdiv_display_parse_roundtrip() {
    let e = parse("cdiv(cdiv(n, B), 2) * B + cdiv(m, 4)").unwrap();
    let e2 = parse(&e.to_string()).unwrap();
    for n in [0, 1, 63, 64, 65] {
        let b = env(&[("n", n), ("B", 16), ("m", 10)]);
        assert_eq!(e.eval(&b).unwrap(), e2.eval(&b).unwrap(), "n={n}");
    }
}

#[test]
fn unary_minus_binds_like_python() {
    // Python parses -a // b as (-a) // b, which differs from -(a // b)
    let e = parse("-a // b").unwrap();
    assert_eq!(e.eval(&env(&[("a", 7), ("b", 2)])).unwrap(), -4);
    let f = parse("-(a // b)").unwrap();
    assert_eq!(f.eval(&env(&[("a", 7), ("b", 2)])).unwrap(), -3);
    // double negation and unary minus of a call
    let g = parse("--a").unwrap();
    assert_eq!(g.eval(&env(&[("a", 5)])).unwrap(), 5);
    let h = parse("-cdiv(a, 2)").unwrap();
    assert_eq!(h.eval(&env(&[("a", 5)])).unwrap(), -3);
    // unary minus in the middle of an additive chain: a - -b
    let i = parse("a - -b").unwrap();
    assert_eq!(i.eval(&env(&[("a", 1), ("b", 2)])).unwrap(), 3);
}

#[test]
fn malformed_inputs_error_with_position() {
    for (src, expect_pos_at_most) in [
        ("", 0),
        ("+", 0),
        ("a +", 3),
        ("a + * b", 4),
        ("(a", 2),
        ("a)", 2),
        ("cdiv(a)", 7),
        ("cdiv(a, b, c)", 13),
        ("cdiv(a; b)", 7),
        ("unknown_fn(a, b)", 16),
        ("a ** b", 5),
        ("a $ b", 2),
        ("1.5", 2),
        ("99999999999999999999999", 23),
    ] {
        let err = parse(src).unwrap_err();
        assert!(
            err.pos <= expect_pos_at_most,
            "{src:?}: error position {} past {expect_pos_at_most}",
            err.pos
        );
        // errors carry the offending source for diagnostics
        assert!(err.to_string().contains(&format!("{src:?}")), "{src:?}: {err}");
    }
}

#[test]
fn whitespace_and_identifiers() {
    let e = parse("  _ntv_x0   *  2\t+ x_size_0 ").unwrap();
    assert_eq!(
        e.eval(&env(&[("_ntv_x0", 4), ("x_size_0", 1)])).unwrap(),
        9
    );
    // identifiers may contain digits after the first character
    assert!(parse("a1b2").is_ok());
    // ...but may not start with one ("1a" parses the 1, then chokes)
    assert!(parse("1a").is_err());
}

// ---------------------------------------------------------------------------
// property tests
// ---------------------------------------------------------------------------

/// Random expression generator for the property tests.
fn random_expr(rng: &mut SplitMix64, depth: usize) -> Expr {
    let vars = ["a", "b", "c"];
    if depth == 0 || rng.next_u64() % 4 == 0 {
        return if rng.next_u64() % 2 == 0 {
            Expr::Const((rng.next_u64() % 21) as i64 - 10)
        } else {
            Expr::sym(vars[(rng.next_u64() % 3) as usize])
        };
    }
    let a = random_expr(rng, depth - 1);
    let b = random_expr(rng, depth - 1);
    match rng.next_u64() % 7 {
        0 => Expr::add(a, b),
        1 => Expr::sub(a, b),
        2 => Expr::mul(a, b),
        3 => Expr::floordiv(a, Expr::max2(b, Expr::Const(1))),
        4 => Expr::modulo(a, Expr::max2(b, Expr::Const(1))),
        5 => Expr::min2(a, b),
        _ => Expr::max2(a, b),
    }
}

#[test]
fn prop_display_parse_roundtrip() {
    let mut rng = SplitMix64::new(42);
    for _ in 0..500 {
        let e = random_expr(&mut rng, 4);
        let parsed = parse(&e.to_string()).unwrap_or_else(|err| {
            panic!("failed to reparse {e}: {err}");
        });
        for trial in 0..10 {
            let bindings = env(&[
                ("a", (trial * 13 % 29) - 5),
                ("b", (trial * 7 % 23) - 3),
                ("c", trial),
            ]);
            assert_eq!(
                e.eval(&bindings).unwrap(),
                parsed.eval(&bindings).unwrap(),
                "mismatch for {e}"
            );
        }
    }
}

#[test]
fn prop_bounds_sound() {
    // bounds() must contain every concrete evaluation — the padding
    // soundness property the generated launch plans rely on.
    let mut rng = SplitMix64::new(9);
    for _ in 0..300 {
        let e = random_expr(&mut rng, 3);
        let mut ranges = BTreeMap::new();
        ranges.insert("a".to_string(), (0i64, 7i64));
        ranges.insert("b".to_string(), (1i64, 5i64));
        ranges.insert("c".to_string(), (2i64, 9i64));
        let Ok((lo, hi)) = e.bounds(&ranges) else {
            continue; // divisor range includes nonpositive values: skipped
        };
        for a in 0..=7 {
            for b in 1..=5 {
                for c in 2..=9 {
                    let bindings = env(&[("a", a), ("b", b), ("c", c)]);
                    let v = e.eval(&bindings).unwrap();
                    assert!(
                        lo <= v && v <= hi,
                        "{e}: value {v} outside [{lo}, {hi}] at a={a} b={b} c={c}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_substitute_commutes_with_eval() {
    let mut rng = SplitMix64::new(123);
    for _ in 0..300 {
        let e = random_expr(&mut rng, 3);
        // substitute a -> 3 then eval(b, c) must equal eval(a=3, b, c)
        let sub: BTreeMap<String, Expr> = [("a".to_string(), Expr::Const(3))].into_iter().collect();
        let subbed = e.substitute(&sub);
        let full = env(&[("a", 3), ("b", 4), ("c", 5)]);
        let partial = env(&[("b", 4), ("c", 5)]);
        match (e.eval(&full), subbed.eval(&partial)) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "{e}"),
            (Err(_), Err(_)) => {}
            (x, y) => panic!("divergent results for {e}: {x:?} vs {y:?}"),
        }
    }
}
