//! Parser for the Python-expression strings the AOT manifest carries
//! (the output of `ast.unparse` over the DSL's expression trees).
//!
//! Grammar (precedence low to high):
//!
//! ```text
//! expr    := term (('+' | '-') term)*
//! term    := unary (('*' | '//' | '%') unary)*
//! unary   := '-' unary | atom
//! atom    := INT | NAME | NAME '(' expr (',' expr)* ')' | '(' expr ')'
//! ```

use std::fmt;

use super::expr::Expr;

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
    pub src: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "expression parse error at byte {}: {} in {:?}",
            self.pos, self.msg, self.src
        )
    }
}

impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Expr, ParseError> {
    let mut p = P { src, bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let e = p.expr()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing input"));
    }
    Ok(e)
}

struct P<'a> {
    src: &'a str,
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into(), src: self.src.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(tok.as_bytes()) {
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.term()?;
        loop {
            self.skip_ws();
            if self.eat("+") {
                let rhs = self.term()?;
                lhs = Expr::add(lhs, rhs);
            } else if self.peek() == Some(b'-') {
                self.pos += 1;
                let rhs = self.term()?;
                lhs = Expr::sub(lhs, rhs);
            } else {
                return Ok(lhs);
            }
        }
    }

    fn term(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary()?;
        loop {
            self.skip_ws();
            if self.eat("//") {
                let rhs = self.unary()?;
                lhs = Expr::floordiv(lhs, rhs);
            } else if self.eat("*") {
                let rhs = self.unary()?;
                lhs = Expr::mul(lhs, rhs);
            } else if self.eat("%") {
                let rhs = self.unary()?;
                lhs = Expr::modulo(lhs, rhs);
            } else {
                return Ok(lhs);
            }
        }
    }

    fn unary(&mut self) -> Result<Expr, ParseError> {
        self.skip_ws();
        if self.peek() == Some(b'-') {
            self.pos += 1;
            let inner = self.unary()?;
            return Ok(Expr::neg(inner));
        }
        self.atom()
    }

    fn atom(&mut self) -> Result<Expr, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'(') => {
                self.pos += 1;
                let e = self.expr()?;
                self.skip_ws();
                if self.peek() != Some(b')') {
                    return Err(self.err("expected ')'"));
                }
                self.pos += 1;
                Ok(e)
            }
            Some(c) if c.is_ascii_digit() => {
                let start = self.pos;
                while matches!(self.peek(), Some(d) if d.is_ascii_digit()) {
                    self.pos += 1;
                }
                let text = &self.src[start..self.pos];
                text.parse::<i64>()
                    .map(Expr::Const)
                    .map_err(|_| self.err("integer overflow"))
            }
            Some(c) if c == b'_' || c.is_ascii_alphabetic() => {
                let start = self.pos;
                while matches!(self.peek(), Some(d) if d == b'_' || d.is_ascii_alphanumeric()) {
                    self.pos += 1;
                }
                let name = &self.src[start..self.pos];
                self.skip_ws();
                if self.peek() == Some(b'(') {
                    self.pos += 1;
                    let mut args = vec![self.expr()?];
                    loop {
                        self.skip_ws();
                        match self.peek() {
                            Some(b',') => {
                                self.pos += 1;
                                args.push(self.expr()?);
                            }
                            Some(b')') => {
                                self.pos += 1;
                                break;
                            }
                            _ => return Err(self.err("expected ',' or ')'")),
                        }
                    }
                    if args.len() != 2 {
                        return Err(self.err("calls take exactly two arguments"));
                    }
                    let b = args.pop().unwrap();
                    let a = args.pop().unwrap();
                    match name {
                        "cdiv" => Ok(Expr::cdiv(a, b)),
                        "min" => Ok(Expr::min2(a, b)),
                        "max" => Ok(Expr::max2(a, b)),
                        other => Err(self.err(&format!("unknown function {other:?}"))),
                    }
                } else {
                    Ok(Expr::sym(name))
                }
            }
            _ => Err(self.err("unexpected character")),
        }
    }
}
