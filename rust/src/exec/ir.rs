//! The tile-program IR: a register machine over [`Tile`]s mirroring the
//! `ntl` operations the catalog application functions use (paper §3.3) —
//! load/store, zeros, dot, exp, max, sum, broadcast, element-wise
//! arithmetic — plus a single **loop-carried** loop construct for the
//! sub-tile sequences that arrangements like mm/bmm/sdpa hand to the
//! application function.
//!
//! A [`TileProgram`] expresses the *serial* per-program semantics of the
//! paper; the grid scheduler (`super::scheduler`) runs it once per grid
//! cell, exactly as generated Triton code would be launched.
//!
//! # Loop-carried registers
//!
//! [`Instr::Loop`] declares which registers carry state across its
//! iterations (`carried`).  Everything else assigned inside the body is
//! **iteration-local**: the interpreter clears those registers after
//! every pass, and [`TileProgram::validate`] statically rejects programs
//! that rely on undeclared persistence (reading a body-local before it is
//! rewritten, or overwriting a pre-loop register without carrying it).
//! This is what lets an application express the online-softmax recurrence
//! of flash attention — running maximum, running denominator, rescaled
//! accumulator — as explicit carries, and what lets structural analyses
//! (coalescibility, `repro kernels`) see exactly which state crosses
//! iterations.

use anyhow::{anyhow, bail, Result};

use super::gemm::{gemm_rows_parallel, INTRA_PAR_MIN_MADDS};
use super::tile::{naive_dot_forced, BinOp, ReduceOp, Tile, UnaryOp};
use super::view::ParamView;
use crate::obs::ProfileReport;
use crate::runtime::HostTensor;

pub type Reg = usize;

#[derive(Debug, Clone)]
pub enum Instr {
    /// Load the current sub-tile of a parameter into a register.
    Load { dst: Reg, param: usize },
    /// A zero tile shaped like a parameter's application block
    /// (`ntl.zeros(output.shape)`).
    Zeros { dst: Reg, like_param: usize },
    /// A scalar constant tile (shape `[1]`).
    Const { dst: Reg, value: f32 },
    Unary { dst: Reg, a: Reg, op: UnaryOp },
    Binary { dst: Reg, a: Reg, b: Reg, op: BinOp },
    /// Keep-dims reduction; `axis: None` reduces all axes.
    Reduce { dst: Reg, a: Reg, axis: Option<usize>, op: ReduceOp },
    /// 2-D matrix product.
    Dot { dst: Reg, a: Reg, b: Reg },
    /// Fused multiply-accumulate: `acc += dot(a_param, b_param)` over the
    /// current sub-tiles.  When both views lower to dense in-range
    /// windows the blocked GEMM consumes the source tensors directly (no
    /// materialized tiles); padded edge tiles fall back to gather.  This
    /// is how the mm/bmm k-loop avoids the load-materialize-dot-add
    /// round trip per iteration.
    DotAcc { acc: Reg, a_param: usize, b_param: usize },
    /// Broadcast register `a` to the block shape of a parameter.
    Broadcast { dst: Reg, a: Reg, like_param: usize },
    /// 2-D matrix transpose (`ntl.trans`) — flash attention's
    /// `dot(q, trans(k))` score product.
    Transpose { dst: Reg, a: Reg },
    /// A tile shaped like a parameter's block holding `0.0` where the
    /// current sub-tile reads in-range source elements and `value` where
    /// it reads padding.  Applications add it (with a large negative
    /// `value`) to attention scores so padded key rows can never win the
    /// online softmax — the IR analogue of the `mask ? score : -inf`
    /// select a hand-written Triton kernel performs.
    PadMask { dst: Reg, like_param: usize, value: f32 },
    /// The concrete extent of a parameter's application block along
    /// `axis`, as a scalar tile (the `query.shape[-1]` of the Python
    /// sdpa application — resolved per specialization, so one program
    /// serves every head dimension).
    BlockDim { dst: Reg, param: usize, axis: usize },
    /// Split a tile into two equal halves along `axis` (the `x[:half]` /
    /// `x[half:]` idiom of the rope application; extent must be even).
    SplitHalf { lo: Reg, hi: Reg, a: Reg, axis: usize },
    /// Concatenate two tiles along `axis` (`ntl.cat`).
    Concat { dst: Reg, a: Reg, b: Reg, axis: usize },
    /// Copy `src` into `dst` — how a loop body updates its carried
    /// registers (`m = m_new` at the end of an online-softmax step).
    Assign { dst: Reg, src: Reg },
    /// Iterate the body once per sub-tile (the `for k in range(...)` of
    /// the mm and sdpa applications).  Loops do not nest.
    ///
    /// `carried` registers keep their value across iterations (the mm
    /// accumulator, sdpa's running max / running sum / accumulator);
    /// every other register assigned in the body is cleared after each
    /// pass, so undeclared cross-iteration state is an execution error
    /// (and a validation error) instead of silent implicit persistence.
    Loop { carried: Vec<Reg>, body: Vec<Instr> },
    /// Store a register into the current sub-tile of a parameter.
    Store { param: usize, src: Reg },
}

impl Instr {
    /// Index into [`crate::obs::INSTR_KINDS`] — the profiler's
    /// per-instruction-kind accumulator slot.
    pub fn kind_index(&self) -> usize {
        match self {
            Instr::Load { .. } => 0,
            Instr::Zeros { .. } => 1,
            Instr::Const { .. } => 2,
            Instr::Unary { .. } => 3,
            Instr::Binary { .. } => 4,
            Instr::Reduce { .. } => 5,
            Instr::Dot { .. } => 6,
            Instr::DotAcc { .. } => 7,
            Instr::Broadcast { .. } => 8,
            Instr::Transpose { .. } => 9,
            Instr::PadMask { .. } => 10,
            Instr::BlockDim { .. } => 11,
            Instr::SplitHalf { .. } => 12,
            Instr::Concat { .. } => 13,
            Instr::Assign { .. } => 14,
            Instr::Loop { .. } => 15,
            Instr::Store { .. } => 16,
        }
    }

    /// Registers this instruction reads / writes, and parameters it
    /// references (loops report none; their body is walked separately).
    ///
    /// This is the structured metadata the `kernel::verify` analyses walk
    /// — dataflow, padding taint and the coalescibility race audit all
    /// consume instructions through this single accessor instead of
    /// re-matching the enum per analysis.
    pub fn effects(&self) -> (Vec<Reg>, Vec<Reg>, Vec<usize>) {
        match self {
            Instr::Load { dst, param } => (vec![], vec![*dst], vec![*param]),
            Instr::Zeros { dst, like_param } => (vec![], vec![*dst], vec![*like_param]),
            Instr::Const { dst, .. } => (vec![], vec![*dst], vec![]),
            Instr::Unary { dst, a, .. } => (vec![*a], vec![*dst], vec![]),
            Instr::Binary { dst, a, b, .. } => (vec![*a, *b], vec![*dst], vec![]),
            Instr::Reduce { dst, a, .. } => (vec![*a], vec![*dst], vec![]),
            Instr::Dot { dst, a, b } => (vec![*a, *b], vec![*dst], vec![]),
            Instr::DotAcc { acc, a_param, b_param } => {
                (vec![*acc], vec![*acc], vec![*a_param, *b_param])
            }
            Instr::Broadcast { dst, a, like_param } => (vec![*a], vec![*dst], vec![*like_param]),
            Instr::Transpose { dst, a } => (vec![*a], vec![*dst], vec![]),
            Instr::PadMask { dst, like_param, .. } => (vec![], vec![*dst], vec![*like_param]),
            Instr::BlockDim { dst, param, .. } => (vec![], vec![*dst], vec![*param]),
            Instr::SplitHalf { lo, hi, a, .. } => (vec![*a], vec![*lo, *hi], vec![]),
            Instr::Concat { dst, a, b, .. } => (vec![*a, *b], vec![*dst], vec![]),
            Instr::Assign { dst, src } => (vec![*src], vec![*dst], vec![]),
            Instr::Loop { .. } => (vec![], vec![], vec![]),
            Instr::Store { param, src } => (vec![*src], vec![], vec![*param]),
        }
    }
}

/// Every register assigned anywhere in `instrs` (loop bodies included).
fn written_regs(instrs: &[Instr], out: &mut Vec<Reg>) {
    for instr in instrs {
        if let Instr::Loop { body, .. } = instr {
            written_regs(body, out);
        } else {
            out.extend(instr.effects().1);
        }
    }
}

#[derive(Debug, Clone)]
pub struct TileProgram {
    pub name: &'static str,
    /// number of registers the program uses
    pub regs: usize,
    pub instrs: Vec<Instr>,
}

impl TileProgram {
    /// Static sanity checks: register/parameter bounds, loop nesting,
    /// stores target outputs only, and the loop-carry discipline — every
    /// register must be assigned before it is read, carried registers
    /// must be initialized before their loop, and a loop body may only
    /// overwrite a pre-loop register by declaring it as a carry (the old
    /// implicit-persistence behaviour is rejected, not silently honored).
    pub fn validate(&self, n_params: usize, is_output: &[bool]) -> Result<()> {
        use std::collections::BTreeSet;

        struct LoopScope<'a> {
            carried: &'a BTreeSet<Reg>,
            /// registers initialized before the loop was entered
            pre: &'a BTreeSet<Reg>,
        }

        fn walk(
            instrs: &[Instr],
            regs: usize,
            n_params: usize,
            is_output: &[bool],
            init: &mut BTreeSet<Reg>,
            scope: Option<&LoopScope<'_>>,
        ) -> Result<()> {
            for instr in instrs {
                if let Instr::Loop { carried, body } = instr {
                    if scope.is_some() {
                        bail!("tile programs do not support nested loops");
                    }
                    let carried_set: BTreeSet<Reg> = carried.iter().copied().collect();
                    for &c in carried {
                        if c >= regs {
                            bail!("register {c} out of range (program has {regs})");
                        }
                        if !init.contains(&c) {
                            bail!("loop-carried register {c} must be initialized before the loop");
                        }
                    }
                    let pre = init.clone();
                    let mut body_init = init.clone();
                    let body_scope = LoopScope { carried: &carried_set, pre: &pre };
                    walk(body, regs, n_params, is_output, &mut body_init, Some(&body_scope))?;
                    // only the declared carries survive the loop (they were
                    // initialized before it, so `init` is already correct);
                    // body-locals are cleared by the interpreter
                    continue;
                }
                let (reads, writes, params) = instr.effects();
                for r in reads {
                    if r >= regs {
                        bail!("register {r} out of range (program has {regs})");
                    }
                    if !init.contains(&r) {
                        bail!(
                            "register {r} is read before it is assigned{}",
                            if scope.is_some() {
                                " (iteration-local values do not persist across loop \
                                 iterations — declare a loop carry)"
                            } else {
                                ""
                            }
                        );
                    }
                }
                for p in params {
                    if p >= n_params {
                        bail!("parameter {p} out of range (program has {n_params})");
                    }
                }
                if let Instr::Store { param, .. } = instr {
                    if !is_output.get(*param).copied().unwrap_or(false) {
                        bail!("store to non-output parameter {param}");
                    }
                }
                for w in writes {
                    if w >= regs {
                        bail!("register {w} out of range (program has {regs})");
                    }
                    if let Some(s) = scope {
                        if s.pre.contains(&w) && !s.carried.contains(&w) {
                            bail!(
                                "register {w} is assigned inside the loop but initialized \
                                 outside it — declare it as a loop carry"
                            );
                        }
                    }
                    init.insert(w);
                }
            }
            Ok(())
        }
        let mut init = BTreeSet::new();
        walk(&self.instrs, self.regs, n_params, is_output, &mut init, None)
    }

    /// Structural bounds checks only: register/parameter indices in
    /// range, no nested loops, stores target output parameters.  The
    /// dataflow discipline (read-before-assign, the carry rules) is *not*
    /// checked here — standalone programs get it from
    /// [`TileProgram::validate`], while declarations going through
    /// `kernel::make` get the richer `kernel::verify` pass, which reports
    /// the same violations under stable `NT-V*` diagnostic codes instead
    /// of bailing at the first one.
    pub fn validate_structure(&self, n_params: usize, is_output: &[bool]) -> Result<()> {
        fn walk(
            instrs: &[Instr],
            regs: usize,
            n_params: usize,
            is_output: &[bool],
            in_loop: bool,
        ) -> Result<()> {
            for instr in instrs {
                if let Instr::Loop { carried, body } = instr {
                    if in_loop {
                        bail!("tile programs do not support nested loops");
                    }
                    for &c in carried {
                        if c >= regs {
                            bail!("register {c} out of range (program has {regs})");
                        }
                    }
                    walk(body, regs, n_params, is_output, true)?;
                    continue;
                }
                let (reads, writes, params) = instr.effects();
                for r in reads.iter().chain(writes.iter()) {
                    if *r >= regs {
                        bail!("register {r} out of range (program has {regs})");
                    }
                }
                for p in params {
                    if p >= n_params {
                        bail!("parameter {p} out of range (program has {n_params})");
                    }
                }
                if let Instr::Store { param, .. } = instr {
                    if !is_output.get(*param).copied().unwrap_or(false) {
                        bail!("store to non-output parameter {param}");
                    }
                }
            }
            Ok(())
        }
        walk(&self.instrs, self.regs, n_params, is_output, false)
    }

    /// Total number of loop-carried registers across the program's loops
    /// (`Some(0)` = loops with no carries, `None` = straight-line —
    /// sequential non-nested loops are legal, so the counts add).
    /// Surfaced by `repro kernels` so the carried capability of a served
    /// kernel is inspectable.
    pub fn loop_carries(&self) -> Option<usize> {
        let mut any = false;
        let mut total = 0;
        for instr in &self.instrs {
            if let Instr::Loop { carried, .. } = instr {
                any = true;
                total += carried.len();
            }
        }
        any.then_some(total)
    }
}

/// Where a parameter's data lives during execution.
pub enum ParamData<'a> {
    In(&'a HostTensor),
    /// Outputs are written through the scheduler's writer closure; the
    /// shape is needed for bounds/strides only (held by the view).
    Out,
}

/// Execute a tile program for one grid cell.
///
/// `write(param, flat_offset, value)` receives every in-range output
/// element the cell produces.  Distinct cells produce distinct offsets
/// (§3.2.1 non-overlap), which the scheduler relies on.
///
/// `intra_threads` is the worker budget heavy instructions (`DotAcc`)
/// may split across *within* this cell — the scheduler hands the whole
/// pool to each cell when the grid itself is too small to fill it, so a
/// big single-tile GEMM still parallelizes.
///
/// `profile` is the plan's [`ProfileReport`]; per-instruction wall time
/// is recorded only when it is present *and* enabled, so the disabled
/// path costs one branch per instruction.
#[allow(clippy::too_many_arguments)]
pub fn exec_cell(
    program: &TileProgram,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    intra_threads: usize,
    profile: Option<&ProfileReport>,
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    let mut regs: Vec<Option<Tile>> = vec![None; program.regs];
    run_block(
        &program.instrs,
        &mut regs,
        views,
        data,
        cell,
        loop_shape,
        None,
        intra_threads,
        profile,
        write,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_block(
    instrs: &[Instr],
    regs: &mut Vec<Option<Tile>>,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    sub: Option<&[usize]>,
    intra_threads: usize,
    profile: Option<&ProfileReport>,
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    // register reads borrow — every op produces a fresh output tile, so
    // no clone is needed on the hot path
    fn get(regs: &[Option<Tile>], r: Reg) -> Result<&Tile> {
        regs[r]
            .as_ref()
            .ok_or_else(|| anyhow!("read of uninitialized register {r}"))
    }
    // effective sub-tile coordinates for a parameter: parameters without
    // loop levels see none, and a looped parameter accessed *outside*
    // the loop sees sub-tile 0
    fn param_sub<'a>(
        views: &[ParamView],
        param: usize,
        sub: Option<&'a [usize]>,
    ) -> std::borrow::Cow<'a, [usize]> {
        use std::borrow::Cow;
        let v = &views[param];
        if v.loop_shape.is_empty() {
            return Cow::Borrowed(&[]);
        }
        match sub {
            Some(s) if !s.is_empty() => Cow::Borrowed(s),
            _ => Cow::Owned(vec![0usize; v.loop_shape.len()]),
        }
    }
    let prof = profile.filter(|p| p.is_enabled());
    for instr in instrs {
        let t0 = prof.map(|_| std::time::Instant::now());
        match instr {
            Instr::Load { dst, param } => {
                let tensor = match &data[*param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("load from output parameter {param}"),
                };
                let s = param_sub(views, *param, sub);
                regs[*dst] = Some(views[*param].gather(tensor, cell, &s)?);
            }
            Instr::Zeros { dst, like_param } => {
                regs[*dst] = Some(Tile::zeros(views[*like_param].block_shape.clone()));
            }
            Instr::Const { dst, value } => {
                regs[*dst] = Some(Tile::scalar(*value));
            }
            Instr::Unary { dst, a, op } => {
                let t = get(regs, *a)?.unary(*op);
                regs[*dst] = Some(t);
            }
            Instr::Binary { dst, a, b, op } => {
                let t = get(regs, *a)?.binary(get(regs, *b)?, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Reduce { dst, a, axis, op } => {
                let t = get(regs, *a)?.reduce(*axis, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Dot { dst, a, b } => {
                let t = get(regs, *a)?.dot(get(regs, *b)?)?;
                regs[*dst] = Some(t);
            }
            Instr::DotAcc { acc, a_param, b_param } => {
                let ta = match &data[*a_param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("dot_acc reads output parameter {a_param}"),
                };
                let tb = match &data[*b_param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("dot_acc reads output parameter {b_param}"),
                };
                let sub_a = param_sub(views, *a_param, sub);
                let sub_b = param_sub(views, *b_param, sub);
                let acc_tile = regs[*acc]
                    .as_mut()
                    .ok_or_else(|| anyhow!("read of uninitialized register {acc}"))?;
                dot_acc(
                    acc_tile,
                    &views[*a_param],
                    ta,
                    &sub_a,
                    &views[*b_param],
                    tb,
                    &sub_b,
                    cell,
                    intra_threads,
                )?;
            }
            Instr::Broadcast { dst, a, like_param } => {
                let t = get(regs, *a)?.broadcast_to(&views[*like_param].block_shape)?;
                regs[*dst] = Some(t);
            }
            Instr::Transpose { dst, a } => {
                let t = get(regs, *a)?.transpose()?;
                regs[*dst] = Some(t);
            }
            Instr::PadMask { dst, like_param, value } => {
                let s = param_sub(views, *like_param, sub);
                regs[*dst] = Some(views[*like_param].pad_mask(cell, &s, *value));
            }
            Instr::BlockDim { dst, param, axis } => {
                let v = &views[*param];
                let Some(&extent) = v.block_shape.get(*axis) else {
                    bail!(
                        "block_dim axis {axis} out of range for parameter {} (block {:?})",
                        v.name,
                        v.block_shape
                    );
                };
                regs[*dst] = Some(Tile::scalar(extent as f32));
            }
            Instr::SplitHalf { lo, hi, a, axis } => {
                let (first, second) = get(regs, *a)?.split_half(*axis)?;
                regs[*lo] = Some(first);
                regs[*hi] = Some(second);
            }
            Instr::Concat { dst, a, b, axis } => {
                let t = get(regs, *a)?.concat(get(regs, *b)?, *axis)?;
                regs[*dst] = Some(t);
            }
            Instr::Assign { dst, src } => {
                let t = get(regs, *src)?.clone();
                regs[*dst] = Some(t);
            }
            Instr::Loop { carried, body } => {
                // iteration-local registers: assigned in the body, not
                // declared as carries — cleared after every pass so state
                // can only flow across iterations through the carries
                let mut locals: Vec<Reg> = Vec::new();
                written_regs(body, &mut locals);
                locals.sort_unstable();
                locals.dedup();
                locals.retain(|r| !carried.contains(r));
                let n: usize = loop_shape.iter().product::<usize>().max(1);
                let mut coords = vec![0usize; loop_shape.len()];
                for _ in 0..n {
                    run_block(
                        body,
                        regs,
                        views,
                        data,
                        cell,
                        loop_shape,
                        Some(&coords),
                        intra_threads,
                        profile,
                        write,
                    )?;
                    for &r in &locals {
                        regs[r] = None;
                    }
                    for d in (0..loop_shape.len()).rev() {
                        coords[d] += 1;
                        if coords[d] < loop_shape[d] {
                            break;
                        }
                        coords[d] = 0;
                    }
                }
            }
            Instr::Store { param, src } => {
                let tile = get(regs, *src)?;
                let s = param_sub(views, *param, sub);
                views[*param].scatter_with(tile, cell, &s, |off, v| write(*param, off, v))?;
            }
        }
        // Loop bodies record their own instructions through the recursive
        // call; attributing the whole loop again would double-count.
        if let (Some(p), Some(t0)) = (prof, t0) {
            if !matches!(instr, Instr::Loop { .. }) {
                p.record_instr(instr.kind_index(), t0.elapsed().as_nanos() as u64);
            }
        }
    }
    Ok(())
}

/// `acc += A x B` for one (cell, sub) pair: direct strided reads through
/// the blocked GEMM when both views expose dense in-range windows,
/// gather fallback at padded edges (the pad value — 0 for matmul inputs
/// — contributes nothing to the product).  `intra_threads > 1` splits
/// the accumulator's rows across scoped workers when the product is big
/// enough to amortize the spawns.
#[allow(clippy::too_many_arguments)]
fn dot_acc(
    acc: &mut Tile,
    va: &ParamView,
    ta: &HostTensor,
    sub_a: &[usize],
    vb: &ParamView,
    tb: &HostTensor,
    sub_b: &[usize],
    cell: &[i64],
    intra_threads: usize,
) -> Result<()> {
    if va.block_shape.len() != 2 || vb.block_shape.len() != 2 {
        bail!(
            "dot_acc needs rank-2 blocks, got {:?} ({}) x {:?} ({})",
            va.block_shape,
            va.name,
            vb.block_shape,
            vb.name
        );
    }
    let (m, k) = (va.block_shape[0], va.block_shape[1]);
    let (kb, n) = (vb.block_shape[0], vb.block_shape[1]);
    if k != kb || acc.shape != [m, n] {
        bail!(
            "dot_acc shape mismatch: acc {:?} += {:?} ({}) x {:?} ({})",
            acc.shape,
            va.block_shape,
            va.name,
            vb.block_shape,
            vb.name
        );
    }
    if naive_dot_forced() {
        // oracle mode: the exact pre-microkernel gather + naive-dot + add
        let t = va.gather(ta, cell, sub_a)?.dot_naive(&vb.gather(tb, cell, sub_b)?)?;
        *acc = acc.binary(&t, BinOp::Add)?;
        return Ok(());
    }
    let threads = if m * n * k >= INTRA_PAR_MIN_MADDS { intra_threads.max(1) } else { 1 };
    let da = ta.as_f32()?;
    let db = tb.as_f32()?;
    match (va.dense_window(cell, sub_a), vb.dense_window(cell, sub_b)) {
        (Some((ao, asr)), Some((bo, bsr))) => {
            gemm_rows_parallel(
                threads,
                m,
                n,
                k,
                da,
                ao,
                asr[0],
                asr[1],
                db,
                bo,
                bsr[0],
                bsr[1],
                &mut acc.data,
            );
        }
        _ => {
            let tile_a = va.gather(ta, cell, sub_a)?;
            let tile_b = vb.gather(tb, cell, sub_b)?;
            gemm_rows_parallel(
                threads,
                m,
                n,
                k,
                &tile_a.data,
                0,
                k as isize,
                1,
                &tile_b.data,
                0,
                n as isize,
                1,
                &mut acc.data,
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn program(regs: usize, instrs: Vec<Instr>) -> TileProgram {
        TileProgram { name: "test", regs, instrs }
    }

    #[test]
    fn validate_accepts_carried_accumulator() {
        // the migrated mm form: acc is declared as a carry
        let p = program(
            1,
            vec![
                Instr::Zeros { dst: 0, like_param: 2 },
                Instr::Loop {
                    carried: vec![0],
                    body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
                },
                Instr::Store { param: 2, src: 0 },
            ],
        );
        p.validate(3, &[false, false, true]).unwrap();
    }

    #[test]
    fn validate_rejects_undeclared_carry() {
        // the pre-migration implicit-persistence form: acc updated in the
        // body without being declared — must be rejected, not honored
        let p = program(
            1,
            vec![
                Instr::Zeros { dst: 0, like_param: 2 },
                Instr::Loop {
                    carried: vec![],
                    body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
                },
                Instr::Store { param: 2, src: 0 },
            ],
        );
        let err = p.validate(3, &[false, false, true]).unwrap_err();
        assert!(format!("{err:#}").contains("loop carry"), "{err:#}");
    }

    #[test]
    fn validate_rejects_uninitialized_carry_and_reads() {
        // carry never initialized before the loop
        let p = program(
            1,
            vec![Instr::Loop {
                carried: vec![0],
                body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
            }],
        );
        let err = p.validate(3, &[false, false, true]).unwrap_err();
        assert!(format!("{err:#}").contains("initialized before the loop"), "{err:#}");
        // straight-line read-before-assign
        let p = program(2, vec![Instr::Unary { dst: 1, a: 0, op: UnaryOp::Exp }]);
        let err = p.validate(1, &[true]).unwrap_err();
        assert!(format!("{err:#}").contains("before it is assigned"), "{err:#}");
    }

    #[test]
    fn validate_rejects_cross_iteration_body_local() {
        // reg 1 is written by the body and read at the top of the next
        // iteration — under carried-loop semantics that read sees a
        // cleared register, and validation catches it statically
        let p = program(
            3,
            vec![
                Instr::Zeros { dst: 0, like_param: 1 },
                Instr::Loop {
                    carried: vec![0],
                    body: vec![
                        Instr::Unary { dst: 2, a: 1, op: UnaryOp::Exp },
                        Instr::Load { dst: 1, param: 0 },
                    ],
                },
                Instr::Store { param: 1, src: 0 },
            ],
        );
        let err = p.validate(2, &[false, true]).unwrap_err();
        assert!(format!("{err:#}").contains("before it is assigned"), "{err:#}");
    }

    #[test]
    fn validate_still_rejects_nested_loops_and_bad_stores() {
        let p = program(
            1,
            vec![
                Instr::Zeros { dst: 0, like_param: 0 },
                Instr::Loop {
                    carried: vec![0],
                    body: vec![Instr::Loop { carried: vec![], body: vec![] }],
                },
            ],
        );
        assert!(format!("{:#}", p.validate(1, &[true]).unwrap_err()).contains("nested"));
        let p = program(
            1,
            vec![Instr::Zeros { dst: 0, like_param: 0 }, Instr::Store { param: 0, src: 0 }],
        );
        assert!(format!("{:#}", p.validate(1, &[false]).unwrap_err()).contains("non-output"));
    }

    #[test]
    fn loop_carries_reports_the_carried_count() {
        let p = program(
            1,
            vec![
                Instr::Zeros { dst: 0, like_param: 2 },
                Instr::Loop {
                    carried: vec![0],
                    body: vec![Instr::DotAcc { acc: 0, a_param: 0, b_param: 1 }],
                },
                Instr::Store { param: 2, src: 0 },
            ],
        );
        assert_eq!(p.loop_carries(), Some(1));
        let p = program(0, vec![]);
        assert_eq!(p.loop_carries(), None);
    }
}
