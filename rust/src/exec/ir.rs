//! The tile-program IR: a register machine over [`Tile`]s mirroring the
//! `ntl` operations the catalog application functions use (paper §3.3) —
//! load/store, zeros, dot, exp, max, sum, broadcast, element-wise
//! arithmetic — plus a single loop construct for the sub-tile sequences
//! that arrangements like mm/bmm hand to the application function.
//!
//! A [`TileProgram`] expresses the *serial* per-program semantics of the
//! paper; the grid scheduler (`super::scheduler`) runs it once per grid
//! cell, exactly as generated Triton code would be launched.

use anyhow::{anyhow, bail, Result};

use super::tile::{BinOp, ReduceOp, Tile, UnaryOp};
use super::view::ParamView;
use crate::runtime::HostTensor;

pub type Reg = usize;

#[derive(Debug, Clone)]
pub enum Instr {
    /// Load the current sub-tile of a parameter into a register.
    Load { dst: Reg, param: usize },
    /// A zero tile shaped like a parameter's application block
    /// (`ntl.zeros(output.shape)`).
    Zeros { dst: Reg, like_param: usize },
    /// A scalar constant tile (shape `[1]`).
    Const { dst: Reg, value: f32 },
    Unary { dst: Reg, a: Reg, op: UnaryOp },
    Binary { dst: Reg, a: Reg, b: Reg, op: BinOp },
    /// Keep-dims reduction; `axis: None` reduces all axes.
    Reduce { dst: Reg, a: Reg, axis: Option<usize>, op: ReduceOp },
    /// 2-D matrix product.
    Dot { dst: Reg, a: Reg, b: Reg },
    /// Broadcast register `a` to the block shape of a parameter.
    Broadcast { dst: Reg, a: Reg, like_param: usize },
    /// Iterate the body once per sub-tile (the `for k in range(...)` of
    /// the mm application).  Loops do not nest.
    Loop { body: Vec<Instr> },
    /// Store a register into the current sub-tile of a parameter.
    Store { param: usize, src: Reg },
}

#[derive(Debug, Clone)]
pub struct TileProgram {
    pub name: &'static str,
    /// number of registers the program uses
    pub regs: usize,
    pub instrs: Vec<Instr>,
}

impl TileProgram {
    /// Static sanity checks: register bounds, parameter bounds, loop
    /// nesting, stores target outputs only.
    pub fn validate(&self, n_params: usize, is_output: &[bool]) -> Result<()> {
        fn walk(
            instrs: &[Instr],
            regs: usize,
            n_params: usize,
            is_output: &[bool],
            in_loop: bool,
        ) -> Result<()> {
            for instr in instrs {
                let (rs, ps): (Vec<Reg>, Vec<usize>) = match instr {
                    Instr::Load { dst, param } => (vec![*dst], vec![*param]),
                    Instr::Zeros { dst, like_param } => (vec![*dst], vec![*like_param]),
                    Instr::Const { dst, .. } => (vec![*dst], vec![]),
                    Instr::Unary { dst, a, .. } => (vec![*dst, *a], vec![]),
                    Instr::Binary { dst, a, b, .. } => (vec![*dst, *a, *b], vec![]),
                    Instr::Reduce { dst, a, .. } => (vec![*dst, *a], vec![]),
                    Instr::Dot { dst, a, b } => (vec![*dst, *a, *b], vec![]),
                    Instr::Broadcast { dst, a, like_param } => {
                        (vec![*dst, *a], vec![*like_param])
                    }
                    Instr::Loop { body } => {
                        if in_loop {
                            bail!("tile programs do not support nested loops");
                        }
                        walk(body, regs, n_params, is_output, true)?;
                        (vec![], vec![])
                    }
                    Instr::Store { param, src } => {
                        if !is_output.get(*param).copied().unwrap_or(false) {
                            bail!("store to non-output parameter {param}");
                        }
                        (vec![*src], vec![*param])
                    }
                };
                for r in rs {
                    if r >= regs {
                        bail!("register {r} out of range (program has {regs})");
                    }
                }
                for p in ps {
                    if p >= n_params {
                        bail!("parameter {p} out of range (program has {n_params})");
                    }
                }
            }
            Ok(())
        }
        walk(&self.instrs, self.regs, n_params, is_output, false)
    }
}

/// Where a parameter's data lives during execution.
pub enum ParamData<'a> {
    In(&'a HostTensor),
    /// Outputs are written through the scheduler's writer closure; the
    /// shape is needed for bounds/strides only (held by the view).
    Out,
}

/// Execute a tile program for one grid cell.
///
/// `write(param, flat_offset, value)` receives every in-range output
/// element the cell produces.  Distinct cells produce distinct offsets
/// (§3.2.1 non-overlap), which the scheduler relies on.
pub fn exec_cell(
    program: &TileProgram,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    let mut regs: Vec<Option<Tile>> = vec![None; program.regs];
    let no_sub: Vec<usize> = Vec::new();
    run_block(
        &program.instrs,
        &mut regs,
        views,
        data,
        cell,
        loop_shape,
        None,
        &no_sub,
        write,
    )
}

#[allow(clippy::too_many_arguments)]
fn run_block(
    instrs: &[Instr],
    regs: &mut Vec<Option<Tile>>,
    views: &[ParamView],
    data: &[ParamData<'_>],
    cell: &[i64],
    loop_shape: &[usize],
    sub: Option<&[usize]>,
    no_sub: &[usize],
    write: &mut dyn FnMut(usize, usize, f32),
) -> Result<()> {
    // register reads borrow — every op produces a fresh output tile, so
    // no clone is needed on the hot path
    fn get(regs: &[Option<Tile>], r: Reg) -> Result<&Tile> {
        regs[r]
            .as_ref()
            .ok_or_else(|| anyhow!("read of uninitialized register {r}"))
    }
    // sub-tile coordinates for a parameter: parameters without loop levels
    // always see sub-tile 0
    fn param_sub<'a>(
        views: &[ParamView],
        param: usize,
        sub: Option<&'a [usize]>,
        no_sub: &'a [usize],
    ) -> &'a [usize] {
        if views[param].loop_shape.is_empty() {
            no_sub
        } else {
            sub.unwrap_or(no_sub)
        }
    }
    for instr in instrs {
        match instr {
            Instr::Load { dst, param } => {
                let tensor = match &data[*param] {
                    ParamData::In(t) => *t,
                    ParamData::Out => bail!("load from output parameter {param}"),
                };
                let s = param_sub(views, *param, sub, no_sub);
                if !views[*param].loop_shape.is_empty() && s.is_empty() {
                    // a looped parameter loaded outside the loop: sub-tile 0
                    let zeros = vec![0usize; views[*param].loop_shape.len()];
                    regs[*dst] = Some(views[*param].gather(tensor, cell, &zeros)?);
                } else {
                    regs[*dst] = Some(views[*param].gather(tensor, cell, s)?);
                }
            }
            Instr::Zeros { dst, like_param } => {
                regs[*dst] = Some(Tile::zeros(views[*like_param].block_shape.clone()));
            }
            Instr::Const { dst, value } => {
                regs[*dst] = Some(Tile::scalar(*value));
            }
            Instr::Unary { dst, a, op } => {
                let t = get(regs, *a)?.unary(*op);
                regs[*dst] = Some(t);
            }
            Instr::Binary { dst, a, b, op } => {
                let t = get(regs, *a)?.binary(get(regs, *b)?, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Reduce { dst, a, axis, op } => {
                let t = get(regs, *a)?.reduce(*axis, *op)?;
                regs[*dst] = Some(t);
            }
            Instr::Dot { dst, a, b } => {
                let t = get(regs, *a)?.dot(get(regs, *b)?)?;
                regs[*dst] = Some(t);
            }
            Instr::Broadcast { dst, a, like_param } => {
                let t = get(regs, *a)?.broadcast_to(&views[*like_param].block_shape)?;
                regs[*dst] = Some(t);
            }
            Instr::Loop { body } => {
                let n: usize = loop_shape.iter().product::<usize>().max(1);
                let mut coords = vec![0usize; loop_shape.len()];
                for _ in 0..n {
                    run_block(
                        body, regs, views, data, cell, loop_shape, Some(&coords), no_sub, write,
                    )?;
                    for d in (0..loop_shape.len()).rev() {
                        coords[d] += 1;
                        if coords[d] < loop_shape[d] {
                            break;
                        }
                        coords[d] = 0;
                    }
                }
            }
            Instr::Store { param, src } => {
                let tile = get(regs, *src)?;
                let s = param_sub(views, *param, sub, no_sub);
                views[*param].scatter_with(tile, cell, s, |off, v| write(*param, off, v))?;
            }
        }
    }
    Ok(())
}
